"""Chrome ``trace_event`` span recording (DESIGN.md §10).

Spans are appended host-side as plain dicts (one append per event, no
formatting until export) and written as the JSON object format
``{"traceEvents": [...]}`` that ``chrome://tracing`` / Perfetto load
directly.  Two process tracks:

* ``pid=0`` **engine** — ``tid=0`` carries the per-step phase spans
  (plan / chunks / dispatch / sync / sample / host nested under each
  ``step`` span by containment), ``tid=1`` the executor dispatch detail;
* ``pid=1`` **requests** — one thread per request id, carrying that
  request's lifecycle: ``submit`` instant → ``queue_wait`` span →
  ``prefill[lo:hi)`` span per admission chunk → one ``decode`` span
  (first decode token → finish) → ``finish`` instant.  Gaps between
  prefill chunks are real: they are the steps the budget spent on other
  rows, which is exactly what makes the PR-4 chunked admission and the
  PR-3 overlap pipeline visible on a timeline.

All timestamps come from one ``perf_counter_ns`` origin captured at
construction; ``ts``/``dur`` are microseconds as the format requires.
Spans measure *host-side dispatch-to-return* intervals — device work
dispatched asynchronously shows up in the step's ``sync`` phase (the
point the engine blocks fetching sampled tokens), never as an extra
device synchronization.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional

PID_ENGINE = 0
PID_REQUESTS = 1
TID_STEPS = 0
TID_EXEC = 1


class Tracer:
    def __init__(self, clock_ns=time.perf_counter_ns):
        self._clock = clock_ns
        self._t0 = clock_ns()
        self.events: List[Dict[str, Any]] = []
        self._named_threads = set()
        self._named_procs = set()
        self._process_meta(PID_ENGINE, "engine")
        self._process_meta(PID_REQUESTS, "requests")
        self._thread_meta(PID_ENGINE, TID_STEPS, "engine steps")
        self._thread_meta(PID_ENGINE, TID_EXEC, "executor dispatch")

    # ------------------------------------------------------------------
    def now_us(self) -> float:
        return (self._clock() - self._t0) / 1e3

    def _process_meta(self, pid: int, name: str) -> None:
        if pid in self._named_procs:
            return
        self._named_procs.add(pid)
        self.events.append({"ph": "M", "name": "process_name",
                            "pid": pid, "tid": 0, "args": {"name": name}})

    def _thread_meta(self, pid: int, tid: int, name: str) -> None:
        if (pid, tid) in self._named_threads:
            return
        self._named_threads.add((pid, tid))
        self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    def request_track(self, rid: int) -> int:
        """Ensure request ``rid`` has a named thread; returns its tid."""
        self._thread_meta(PID_REQUESTS, rid, f"request {rid}")
        return rid

    # ------------------------------------------------------------------
    def complete(self, name: str, pid: int, tid: int, ts_us: float,
                 dur_us: float, args: Optional[Dict[str, Any]] = None
                 ) -> None:
        ev = {"ph": "X", "name": name, "pid": pid, "tid": tid,
              "ts": ts_us, "dur": max(0.0, dur_us)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, pid: int, tid: int,
                ts_us: Optional[float] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"ph": "i", "name": name, "pid": pid, "tid": tid,
              "ts": self.now_us() if ts_us is None else ts_us, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    # ------------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
