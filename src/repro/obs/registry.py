"""Dependency-free metrics registry: counters, gauges, log-bucketed
histograms, and pull-time collectors (DESIGN.md §10).

Two kinds of metric feed one namespaced snapshot:

* **declared** metrics — :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` objects created up front (so a schema's key set
  never depends on which code paths happened to fire) and updated inline
  on the hot path.  Updates are plain attribute arithmetic — no locks,
  no allocation for counters/gauges, O(1) bucket math for histograms.
* **collectors** — callables registered per namespace and invoked only
  at :meth:`MetricsRegistry.snapshot` time, for state that already lives
  elsewhere (KV occupancy tables, expert-pool counters, the jit cache).
  Pull-based collection is what keeps telemetry off the decode hot path:
  reading a device-resident counter happens once per snapshot, never per
  step.

A namespace's declared keys and collector keys must be disjoint
(asserted at snapshot), so the same metric can never be reported from
two sources with two values.
"""
from __future__ import annotations

import json
import math
from typing import Any, Callable, Dict, List, Optional


class Counter:
    """Monotonic accumulator (float or int)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def add(self, v=1) -> None:
        self.value += v


class Gauge:
    """Last-write-wins point value (numbers or short strings)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = v


class Histogram:
    """Log2-bucketed histogram of non-negative samples.

    ``observe`` costs one ``frexp`` plus a dict increment; the snapshot
    reports count/sum/min/max plus bucket-interpolated p50/p95 (each
    bucket spans one power of two, so quantile estimates are within 2x —
    good enough for latency triage; exact tails come from the trace).
    """

    __slots__ = ("count", "sum", "min", "max", "buckets")

    def __init__(self):
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        # bucket b holds samples in [2**(b-1), 2**b); b=None→0 for v<=0
        b = math.frexp(v)[1] if v > 0 else 0
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def _quantile(self, q: float) -> float:
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for b in sorted(self.buckets):
            n = self.buckets[b]
            if seen + n >= target:
                lo = 0.0 if b <= 0 else float(2 ** (b - 1))
                hi = float(2 ** b)
                frac = (target - seen) / n
                return min(max(lo + frac * (hi - lo), self.min), self.max)
            seen += n
        return self.max

    def snapshot(self) -> Dict[str, Any]:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p95": 0.0, "buckets": {}}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self._quantile(0.5), "p95": self._quantile(0.95),
                "buckets": {str(k): v for k, v in
                            sorted(self.buckets.items())}}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Namespaced metric store: ``(namespace, key) -> metric``, plus
    per-namespace pull-time collectors."""

    def __init__(self):
        self._metrics: Dict[str, Dict[str, Any]] = {}
        self._kinds: Dict[tuple, str] = {}
        self._collectors: Dict[str, Callable[[], Dict[str, Any]]] = {}

    # ------------------------------------------------------------------
    def _declare(self, kind: str, ns: str, key: str):
        space = self._metrics.setdefault(ns, {})
        if key in space:
            have = self._kinds[(ns, key)]
            if have != kind:
                raise ValueError(f"{ns}.{key} already declared as {have}")
            return space[key]
        m = _KINDS[kind]()
        space[key] = m
        self._kinds[(ns, key)] = kind
        return m

    def counter(self, ns: str, key: str) -> Counter:
        return self._declare("counter", ns, key)

    def gauge(self, ns: str, key: str) -> Gauge:
        return self._declare("gauge", ns, key)

    def histogram(self, ns: str, key: str) -> Histogram:
        return self._declare("histogram", ns, key)

    def register_collector(self, ns: str,
                           fn: Callable[[], Dict[str, Any]]) -> None:
        """Register (or replace — last attached engine wins) the pull
        source for namespace ``ns``."""
        self._collectors[ns] = fn

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Nested ``{namespace: {key: value}}`` view: declared metric
        values merged with freshly-pulled collector output."""
        out: Dict[str, Dict[str, Any]] = {}
        for ns, space in self._metrics.items():
            out[ns] = {k: (m.snapshot() if isinstance(m, Histogram)
                           else m.value) for k, m in space.items()}
        for ns, fn in self._collectors.items():
            collected = fn()
            space = out.setdefault(ns, {})
            overlap = set(space) & set(collected)
            assert not overlap, \
                f"namespace {ns!r}: declared and collected keys overlap " \
                f"({sorted(overlap)})"
            space.update(collected)
        return out


# ----------------------------------------------------------------------
_LEGACY_PREFIX = {"engine": "", "kv": "kv_", "offload": "offload_"}


def flatten_legacy(snapshot: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
    """Project a namespaced snapshot onto the legacy flat ``stats()``
    dict: ``engine.steps`` → ``steps``, ``kv.pages_free`` →
    ``kv_pages_free``, ``offload.bytes_h2d`` → ``offload_bytes_h2d``,
    anything else → ``<ns>_<key>``.  Namespaces map through disjoint
    prefixes, so a collision means a schema bug — asserted, not papered
    over."""
    flat: Dict[str, Any] = {}
    for ns, space in snapshot.items():
        prefix = _LEGACY_PREFIX.get(ns, f"{ns}_")
        for key, val in space.items():
            name = f"{prefix}{key}"
            assert name not in flat, \
                f"legacy flattening collision on {name!r} (from {ns}.{key})"
            flat[name] = val
    return flat


def _sanitize(obj):
    """Make a snapshot JSON-serializable: numpy scalars → python,
    arrays/tuples → lists, non-finite floats → None, unknown objects →
    repr (metrics files must never fail to write because a collector
    leaked an exotic value)."""
    if isinstance(obj, dict):
        return {str(k): _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, bool) or obj is None or isinstance(obj, str):
        return obj
    if isinstance(obj, (int, float)):
        if isinstance(obj, float) and not math.isfinite(obj):
            return None
        return obj
    if hasattr(obj, "item") and not getattr(obj, "ndim", 0):
        return _sanitize(obj.item())
    if hasattr(obj, "tolist"):
        return _sanitize(obj.tolist())
    return repr(obj)


def metrics_document(snapshot: Dict[str, Dict[str, Any]],
                     mode: Optional[Dict[str, Any]] = None
                     ) -> Dict[str, Any]:
    """The ``--metrics-json`` file layout (validated by
    ``tools/check_metrics_schema.py``)."""
    from repro.obs.schema import SCHEMA_VERSION
    return {"schema_version": SCHEMA_VERSION,
            "mode": _sanitize(mode or {}),
            "metrics": _sanitize(snapshot)}


def write_metrics_json(path, snapshot, mode=None) -> None:
    doc = metrics_document(snapshot, mode)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
