"""The telemetry plane's stable, namespaced metrics schema (DESIGN.md §10).

One place defines what every engine/plane/KV-layout combination emits, so
three consumers can never drift: the snapshot tests
(``tests/test_obs.py``) assert engines emit EXACTLY these keys, the CI
validator (``tools/check_metrics_schema.py``) checks ``--metrics-json``
files against them, and DESIGN.md §10's schema table is generated from
this module's docstrings of record.

Metric names are ``namespace.key``.  Namespaces:

* ``engine``   — step/token/request lifecycle counts (collector-backed).
* ``kv``       — KV occupancy, from the slot/page manager (collector).
* ``offload``  — expert-streaming traffic counters (collector; only on
  offloaded engines — the counters the engine already fetches).
* ``jit``      — process-wide engine-executable cache
  (``transformer.cached_jit_stats``, minus the unserializable keys).
* ``step``     — per-engine-step phase breakdown (declared counters +
  wall-clock histogram; only when timing is enabled).
* ``exec``     — executor dispatch phases, per plane (declared when an
  observer is attached; the packed planes split mixer/MoE/staging).
* ``request``  — per-request lifecycle aggregates (declared with timing).
* ``roofline`` — measured-vs-predicted accounting (gauges; set when a
  roofline accountant is attached).
* ``spec``     — token-level draft-and-verify accounting (declared only
  when speculation is wired: per-round proposed/accepted histograms,
  cumulative acceptance rate, h2d bytes per accepted token —
  DESIGN.md §11).
* ``prefix``   — radix prefix-cache accounting (collector; only when the
  engine has a ``prefix_cache_pages`` budget): lookup/hit counters and
  the index's node/page population (DESIGN.md §13).
* ``kv_host``  — KV host-swap / preemption accounting (collector; only
  when preemption is on): host pool occupancy, swap traffic bytes and
  the preempt/resume/recompute lifecycle counts (DESIGN.md §13).
* ``faults``   — fault-injection + terminal-status accounting
  (collector; always present on ContinuousEngine so chaos and clean
  runs share one schema — all fire counts are zero without an
  injector; DESIGN.md §14).

The legacy flat ``ContinuousEngine.stats()`` dict is a *projection* of
this schema (``repro.obs.flatten_legacy``): ``engine.*`` keys flatten
bare, ``kv.*`` → ``kv_*``, ``offload.*`` → ``offload_*`` and every other
namespace → ``<ns>_<key>`` — collisions are structurally impossible
because namespaces flatten through disjoint prefixes (asserted).
"""
from __future__ import annotations

from typing import Dict, FrozenSet

SCHEMA_VERSION = 1

ENGINE_KEYS = frozenset({
    "steps", "joins", "evictions", "finished", "waiting", "running",
    "tokens", "tokens_per_step", "decode_tokens", "queue_rejected",
})

KV_KEYS_DENSE = frozenset({
    "layout", "slots_in_use", "slots_free", "positions_reserved",
    "peak_positions_reserved", "positions_live", "slot_lengths",
})

KV_KEYS_PAGED = frozenset({
    "layout", "slots_in_use", "slots_free", "peak_positions_reserved",
    "positions_live", "slot_lengths", "slot_pages", "pages_total",
    "pages_free", "pages_in_use", "pages_peak_in_use",
    "pages_peak_committed", "pages_reserved_unallocated", "page_size",
})

OFFLOAD_KEYS = frozenset({
    "hits", "spec_hits", "demand_loads", "spec_loads", "bytes_h2d",
    "bytes_per_token",
})

JIT_KEYS = frozenset({"builds", "hits", "entries"})

# per-step phase breakdown: plan build / prefill chunks / decode dispatch
# / kernel wait (the device sync) / host-side sampling / bookkeeping
STEP_KEYS = frozenset({
    "timed", "plan_ns", "chunk_ns", "dispatch_ns", "sync_ns",
    "sample_ns", "host_ns", "wall_ms",
})

# executor dispatch phases differ by plane — the packed_pipelined plane
# is the only one with a separate speculative-staging dispatch
EXEC_KEYS_BY_PLANE: Dict[str, FrozenSet[str]] = {
    "plain": frozenset({"dispatch_ns"}),
    "packed_vectorized": frozenset({"embed_ns", "block_ns", "head_ns"}),
    "packed_pipelined": frozenset({"embed_ns", "mixer_ns", "moe_ns",
                                   "stage_ns", "head_ns"}),
}

REQUEST_KEYS = frozenset({
    "submitted", "finished", "queue_wait_steps", "gen_tokens",
})

ROOFLINE_KEYS = frozenset({
    "hw", "windows", "window_steps", "measured_tok_s", "predicted_tok_s",
    "delta_ratio", "measured_h2d_bytes_per_token",
    "naive_h2d_bytes_per_token", "h2d_savings_ratio", "context_len",
    # per-layer-kind state-plane traffic (DESIGN.md §12): fixed-size
    # recurrent carries (read+write, flat in context) and the shared
    # encoder-KV cross-read — both set at attach time per config
    "rec_state_bytes_per_token", "enc_kv_read_bytes_per_token",
    # prefix-reuse + preemption traffic (DESIGN.md §13): cumulative KV
    # swap bytes normalized by decode tokens, and the cumulative prompt
    # tokens whose prefill a prefix hit skipped
    "kv_swap_bytes_per_token", "prefix_hit_tokens",
})

SPEC_KEYS = frozenset({
    "rounds", "proposed", "accepted", "acceptance_rate",
    "bytes_h2d_per_accepted",
})

# prefix-cache accounting (DESIGN.md §13): engine-side hit counters
# (bumped only on successful admission — lookups retry while stalled)
# plus the index's own population/eviction counters
PREFIX_KEYS = frozenset({
    "lookups", "hit_tokens", "prefills_skipped", "nodes", "cached_pages",
    "inserted_pages", "evicted_pages",
})

# host-swap / preemption accounting (DESIGN.md §13): the HostPagePool's
# budget + traffic counters plus the engine/scheduler lifecycle counts
KV_HOST_KEYS = frozenset({
    "pages_total", "pages_in_use", "peak_pages_in_use", "swap_out_bytes",
    "swap_in_bytes", "preemptions", "resumes", "recomputes", "swapped_now",
})

# fault-injection + request-lifecycle accounting (DESIGN.md §14): the
# injector's per-site fire counts (all zero on a fault-free engine — the
# namespace is always present so chaos and clean runs share one schema),
# the executor's fetch retry/degrade ladder, NaN quarantines, and the
# terminal-status census over every request the engine has ever seen
FAULTS_KEYS = frozenset({
    "enabled", "injected", "fired_expert_fetch", "fired_swap_out",
    "fired_swap_in", "fired_page_pool", "fired_nan_logits",
    "fired_slow_step", "fetch_retries", "fetch_degraded",
    "nan_quarantined", "completed", "cancelled", "deadline_exceeded",
    "rejected", "failed",
})

HISTOGRAM_FIELDS = frozenset({"count", "sum", "min", "max", "p50", "p95",
                              "buckets"})


def expected_namespaces(*, kv_layout: str = "dense", offloaded: bool = False,
                        timing: bool = True, plane: str = "plain",
                        roofline: bool = True, speculative: bool = False,
                        prefix_cache: bool = False, kv_host: bool = False,
                        faults: bool = True
                        ) -> Dict[str, FrozenSet[str]]:
    """The exact ``{namespace: key set}`` a ContinuousEngine snapshot
    carries for one engine/plane/KV-layout combination — what the
    snapshot tests and the CI validator both check against."""
    out = {
        "engine": ENGINE_KEYS,
        "kv": KV_KEYS_PAGED if kv_layout == "paged" else KV_KEYS_DENSE,
        "jit": JIT_KEYS,
    }
    if offloaded:
        out["offload"] = OFFLOAD_KEYS
    if speculative:
        out["spec"] = SPEC_KEYS
    if prefix_cache:
        out["prefix"] = PREFIX_KEYS
    if kv_host:
        out["kv_host"] = KV_HOST_KEYS
    if faults:
        out["faults"] = FAULTS_KEYS
    if timing:
        out["step"] = STEP_KEYS
        out["request"] = REQUEST_KEYS
        out["exec"] = EXEC_KEYS_BY_PLANE[plane]
        if roofline:
            out["roofline"] = ROOFLINE_KEYS
    return out
