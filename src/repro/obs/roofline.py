"""Measured-vs-predicted roofline accounting (DESIGN.md §10).

Closes the loop the ROADMAP's auto-tuning item needs: per window of
engine steps, the accountant compares

* **measured** decode tokens/s (host wall clock over the window, decode
  emissions only — prefill-chunk tokens are admission work, not decode
  throughput) against ``core.cost_model.tokens_per_second(...)`` driven
  by the SAME window's measured cache statistics — the paper's Table-2
  methodology turned into a live metric.  ``roofline.delta_ratio`` =
  measured / predicted; on the calibrated GPU targets it should approach
  1.0, on this CPU host it quantifies exactly how far the software stack
  is from the modeled hardware bound.
* **measured** h2d bytes/token against the *naive-offloading* roofline
  (streaming every expert of every MoE layer per token) —
  ``roofline.h2d_savings_ratio`` is the traffic the LRU + speculative
  machinery saves, the paper's central claim as a first-class metric.

Hot-path discipline: the per-step feed is two integer adds.  Transfer
counters are fetched from the device only at window boundaries — and
they are the same small ``PoolState.counts`` array the engines already
fetch for ``stats()``, so telemetry introduces no new device-resident
data and at most one extra tiny fetch per ``window`` steps.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

from repro.core import cost_model


class RooflineAccountant:
    """Windowed measured-vs-predicted accounting over one engine.

    ``h2d_counts_fn`` returns cumulative ``(hits, spec_hits,
    demand_loads, spec_loads)``; ``None`` for engines with no expert
    streaming (the prediction then carries zero transfer terms and the
    h2d fields stay 0).
    """

    def __init__(self, registry, cfg, *, hw: str = "t4",
                 window: int = 32, expert_bits: int = 16,
                 attn_bits: int = 16, expert_bytes: float = 0.0,
                 h2d_counts_fn: Optional[Callable[[], Tuple[int, int, int,
                                                            int]]] = None):
        self.cfg = cfg
        self.hw = cost_model.HARDWARE[hw]
        self.window = max(1, int(window))
        self.expert_bits = expert_bits
        self.attn_bits = attn_bits
        self.expert_bytes = expert_bytes
        self._counts_fn = h2d_counts_fn
        self._last_counts = (0, 0, 0, 0)
        self._tokens = 0
        self._wall_ns = 0
        self._steps = 0
        self._ctx_sum = 0.0
        # cumulative (never windowed) prefix-reuse/preemption traffic
        # (DESIGN.md §13): fed by the engine's swap/hit paths, reported
        # at flush normalized by cumulative decode tokens
        self._swap_bytes = 0
        self._hit_tokens = 0
        self._tokens_cum = 0
        g = registry.gauge
        self._g = {k: g("roofline", k) for k in
                   ("hw", "windows", "window_steps", "measured_tok_s",
                    "predicted_tok_s", "delta_ratio",
                    "measured_h2d_bytes_per_token",
                    "naive_h2d_bytes_per_token", "h2d_savings_ratio",
                    "context_len", "rec_state_bytes_per_token",
                    "enc_kv_read_bytes_per_token",
                    "kv_swap_bytes_per_token", "prefix_hit_tokens")}
        self._g["hw"].set(hw)
        self._g["window_steps"].set(self.window)
        self._g["windows"].set(0)
        # per-layer-kind state-plane traffic terms (DESIGN.md §12),
        # static per config: the rec plane is read AND written each
        # token but never grows; the shared encoder KV is the xattn
        # cross-read at zero decoded context — both flat in context_len
        self._g["rec_state_bytes_per_token"].set(
            2.0 * cost_model.recurrent_state_bytes(cfg))
        self._g["enc_kv_read_bytes_per_token"].set(
            cost_model.kv_read_bytes_per_token(cfg, 0.0))
        for k in ("measured_tok_s", "predicted_tok_s", "delta_ratio",
                  "measured_h2d_bytes_per_token",
                  "naive_h2d_bytes_per_token", "h2d_savings_ratio",
                  "context_len", "kv_swap_bytes_per_token",
                  "prefix_hit_tokens"):
            self._g[k].set(0.0)
        self._windows = 0

    # ------------------------------------------------------------------
    # prefix-reuse + preemption traffic (DESIGN.md §13) — cumulative
    def add_swap_bytes(self, nbytes: int) -> None:
        """One KV swap-out or swap-in staging transfer."""
        self._swap_bytes += int(nbytes)

    def add_prefix_hit(self, n_tokens: int) -> None:
        """Prompt tokens whose prefill a cache hit skipped."""
        self._hit_tokens += int(n_tokens)
        self._g["prefix_hit_tokens"].set(self._hit_tokens)

    # ------------------------------------------------------------------
    def step(self, n_decode_tokens: int, wall_ns: int,
             context_len: float) -> None:
        """Feed one engine step (host data only); closes a window every
        ``window`` steps."""
        self._tokens += n_decode_tokens
        self._wall_ns += wall_ns
        self._ctx_sum += context_len * n_decode_tokens
        self._steps += 1
        if self._steps >= self.window:
            self.flush()

    def flush(self) -> None:
        """Close the current window (also called at end-of-run so short
        runs still report)."""
        if not self._steps or not self._tokens or not self._wall_ns:
            self._steps = self._tokens = self._wall_ns = 0
            self._ctx_sum = 0.0
            return
        tokens, wall_s = self._tokens, self._wall_ns / 1e9
        self._tokens_cum += tokens
        self._g["kv_swap_bytes_per_token"].set(
            self._swap_bytes / max(1, self._tokens_cum))
        ctx = self._ctx_sum / max(1, tokens)
        measured = tokens / wall_s

        d_counts = (0, 0, 0, 0)
        if self._counts_fn is not None:
            now = tuple(int(c) for c in self._counts_fn())
            d_counts = tuple(n - l for n, l in
                             zip(now, self._last_counts))
            self._last_counts = now
        hits, spec_hits, demand, spec = d_counts
        ts = cost_model.TokenStats(
            demand_loads=demand / tokens, spec_loads=spec / tokens,
            hits=hits / tokens, spec_hits=spec_hits / tokens)
        predicted = cost_model.tokens_per_second(
            self.cfg, self.hw, ts, self.expert_bits, self.attn_bits,
            context_len=ctx)

        h2d_per_tok = (demand + spec) * self.expert_bytes / tokens
        naive = 0.0
        if self.cfg.moe is not None and self.expert_bytes:
            naive = (self.cfg.moe_layer_count * self.cfg.moe.num_experts
                     * self.expert_bytes)

        self._windows += 1
        self._g["windows"].set(self._windows)
        self._g["measured_tok_s"].set(measured)
        self._g["predicted_tok_s"].set(predicted)
        self._g["delta_ratio"].set(measured / max(1e-12, predicted))
        self._g["measured_h2d_bytes_per_token"].set(h2d_per_tok)
        self._g["naive_h2d_bytes_per_token"].set(naive)
        self._g["h2d_savings_ratio"].set(
            naive / h2d_per_tok if h2d_per_tok > 0 else 0.0)
        self._g["context_len"].set(ctx)
        self._steps = self._tokens = self._wall_ns = 0
        self._ctx_sum = 0.0

    # ------------------------------------------------------------------
    def add_window(self, n_tokens: int, wall_s: float, *,
                   demand_loads: int = 0, spec_loads: int = 0,
                   hits: int = 0, spec_hits: int = 0,
                   context_len: float = 0.0) -> None:
        """One-shot accounting for batch-1 generate loops (the offload
        engine feeds a whole generation as one window from the stats it
        already computed — zero extra fetches)."""
        if n_tokens <= 0 or wall_s <= 0:
            return
        self._tokens = n_tokens
        self._wall_ns = int(wall_s * 1e9)
        self._ctx_sum = context_len * n_tokens
        self._steps = self.window  # force the flush path
        if self._counts_fn is None:
            # route the caller-supplied counts through the delta logic
            self._counts_fn = lambda: (hits, spec_hits, demand_loads,
                                       spec_loads)
            self._last_counts = (0, 0, 0, 0)
            self.flush()
            self._counts_fn = None
        else:
            self.flush()
