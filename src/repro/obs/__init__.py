"""Unified telemetry plane (DESIGN.md §10): ONE registry feeds the
end-of-run summary, the ``--metrics-json`` snapshot and the Chrome
trace, so no two outputs can ever disagree.

:class:`Telemetry` is the facade engines wire through:

* a :class:`~repro.obs.registry.MetricsRegistry` (always present — even
  ``Telemetry.off()`` serves the pull-time collectors that back the
  legacy ``stats()`` shim);
* an optional :class:`~repro.obs.tracing.Tracer` (``trace=True``)
  recording per-request lifecycle spans and per-step phase spans as
  Chrome ``trace_event`` JSON;
* an optional :class:`~repro.obs.roofline.RooflineAccountant`
  (``timing=True``) comparing measured tokens/s and h2d bytes against
  ``core.cost_model`` predictions per step window.

Hot-path contract (tested: ``tests/test_obs.py``, asserted in CI by the
serve_bench ``telemetry_overhead`` scenario): telemetry is host-side
only — it never touches the rng stream, never adds a device
synchronization beyond the counters engines already fetch, generated
tokens are bitwise identical with telemetry on or off, and full tracing
costs <5% decode tokens/s on the mixed serving workload.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                flatten_legacy, metrics_document,
                                write_metrics_json)
from repro.obs.roofline import RooflineAccountant
from repro.obs.schema import EXEC_KEYS_BY_PLANE, SCHEMA_VERSION
from repro.obs import tracing
from repro.obs.tracing import Tracer

__all__ = ["Telemetry", "MetricsRegistry", "Tracer", "RooflineAccountant",
           "Counter", "Gauge", "Histogram", "ExecPhases", "StepTimer",
           "SpecMetrics", "flatten_legacy", "metrics_document",
           "write_metrics_json", "jit_cache_metrics", "SCHEMA_VERSION"]

_STEP_PHASES = ("plan", "chunk", "dispatch", "sync", "sample", "host")


def jit_cache_metrics() -> Dict[str, int]:
    """``jit`` namespace collector: the process-wide engine-executable
    cache counters (``transformer.cached_jit_stats`` minus the
    unserializable key list)."""
    from repro.models import transformer as T
    s = T.cached_jit_stats()
    return {"builds": s["builds"], "hits": s["hits"],
            "entries": s["entries"]}


class ExecPhases:
    """Executor dispatch-phase accumulator (``exec`` namespace): the
    executor calls :meth:`begin` once per step and :meth:`mark` after
    each dispatch segment; each mark adds the elapsed interval to that
    phase's counter.  Phase names are plane-specific
    (``schema.EXEC_KEYS_BY_PLANE``) so the packed pipeline's separate
    staging dispatch is measurable."""

    __slots__ = ("_counters", "_t", "_clock")

    def __init__(self, registry: MetricsRegistry, plane: str,
                 clock_ns=time.perf_counter_ns):
        self._clock = clock_ns
        self._counters = {key[:-len("_ns")]: registry.counter("exec", key)
                          for key in EXEC_KEYS_BY_PLANE[plane]}
        self._t = 0

    def begin(self) -> None:
        self._t = self._clock()

    def mark(self, phase: str) -> None:
        now = self._clock()
        self._counters[phase].add(now - self._t)
        self._t = now


class StepTimer:
    """One engine step's phase breakdown; collected by
    :meth:`Telemetry.step_end` into the ``step`` counters/histogram and
    (when tracing) into nested ``step``/phase spans."""

    __slots__ = ("t0", "marks", "_t", "_clock", "index")

    def __init__(self, index: int, clock_ns):
        self._clock = clock_ns
        self.index = index
        self.t0 = clock_ns()
        self._t = self.t0
        self.marks: List[Tuple[str, int, int]] = []  # (phase, t_start, t_end)

    def mark(self, phase: str) -> None:
        now = self._clock()
        self.marks.append((phase, self._t, now))
        self._t = now


class SpecMetrics:
    """``spec`` namespace (DESIGN.md §11): speculation accounting shared
    by both engines.  Declared at wiring time (the full key set exists
    before any round runs — ``schema.SPEC_KEYS``): per-round
    ``proposed``/``accepted`` histograms, a ``rounds`` counter, the
    cumulative ``acceptance_rate`` gauge, and ``bytes_h2d_per_accepted``
    — measured h2d traffic divided by tokens the verify chunks emitted
    (stays 0.0 on engines with resident experts)."""

    __slots__ = ("rounds", "h_proposed", "h_accepted", "g_rate", "g_bytes",
                 "proposed_total", "accepted_total", "emitted_total",
                 "bytes_total")

    def __init__(self, registry: MetricsRegistry):
        self.rounds = registry.counter("spec", "rounds")
        self.h_proposed = registry.histogram("spec", "proposed")
        self.h_accepted = registry.histogram("spec", "accepted")
        self.g_rate = registry.gauge("spec", "acceptance_rate")
        self.g_bytes = registry.gauge("spec", "bytes_h2d_per_accepted")
        self.g_rate.set(0.0)
        self.g_bytes.set(0.0)
        self.proposed_total = 0
        self.accepted_total = 0
        self.emitted_total = 0
        self.bytes_total = 0.0

    def round(self, proposed: int, accepted: int) -> None:
        """One verify round: ``proposed`` = k_eff draft tokens offered,
        ``accepted`` = length of the matching prefix (the round emitted
        ``accepted + 1`` tokens — prefix plus the target's bonus)."""
        self.rounds.add(1)
        self.h_proposed.observe(proposed)
        self.h_accepted.observe(accepted)
        self.proposed_total += int(proposed)
        self.accepted_total += int(accepted)
        self.emitted_total += int(accepted) + 1
        self.g_rate.set(self.accepted_total / max(1, self.proposed_total))

    def add_bytes(self, bytes_h2d: float) -> None:
        """Fold one generation's measured h2d bytes into the
        per-accepted-token gauge."""
        self.bytes_total += float(bytes_h2d)
        self.g_bytes.set(self.bytes_total / max(1, self.emitted_total))


class Telemetry:
    """The facade: ``timing`` enables per-step/per-request measurement
    (+ roofline), ``trace`` additionally records Chrome trace spans.
    ``Telemetry.off()`` keeps only the pull-time registry — the zero-
    cost mode every engine owns by default so ``stats()`` always
    works."""

    def __init__(self, *, timing: bool = True, trace: bool = False,
                 roofline_hw: str = "t4", roofline_window: int = 32,
                 clock_ns=time.perf_counter_ns):
        self.registry = MetricsRegistry()
        self.timing = timing
        self.clock_ns = clock_ns
        self.tracer: Optional[Tracer] = Tracer(clock_ns) if trace else None
        self.roofline: Optional[RooflineAccountant] = None
        self.roofline_hw = roofline_hw
        self.roofline_window = roofline_window
        self._step: Dict[str, Any] = {}
        self._req: Dict[str, Any] = {}
        self._req_ts: Dict[int, Dict[str, float]] = {}

    @classmethod
    def off(cls) -> "Telemetry":
        return cls(timing=False, trace=False)

    # ------------------------------------------------------------------
    # schema declaration (engines call at wiring time so snapshots carry
    # the full key set even before any step ran)
    def declare_step_schema(self) -> None:
        r = self.registry
        self._step = {"timed": r.counter("step", "timed"),
                      "wall_ms": r.histogram("step", "wall_ms")}
        for p in _STEP_PHASES:
            self._step[p] = r.counter("step", f"{p}_ns")

    def declare_request_schema(self) -> None:
        r = self.registry
        self._req = {"submitted": r.counter("request", "submitted"),
                     "finished": r.counter("request", "finished"),
                     "queue_wait_steps": r.histogram("request",
                                                     "queue_wait_steps"),
                     "gen_tokens": r.histogram("request", "gen_tokens")}

    def attach_roofline(self, cfg, *, expert_bits: int = 16,
                        attn_bits: int = 16, expert_bytes: float = 0.0,
                        h2d_counts_fn=None) -> None:
        self.roofline = RooflineAccountant(
            self.registry, cfg, hw=self.roofline_hw,
            window=self.roofline_window, expert_bits=expert_bits,
            attn_bits=attn_bits, expert_bytes=expert_bytes,
            h2d_counts_fn=h2d_counts_fn)

    def exec_observer(self, plane: str) -> Optional[ExecPhases]:
        if not self.timing:
            return None
        return ExecPhases(self.registry, plane, self.clock_ns)

    # ------------------------------------------------------------------
    # per-step phases
    def step_begin(self, index: int) -> Optional[StepTimer]:
        if not self.timing:
            return None
        return StepTimer(index, self.clock_ns)

    def step_end(self, st: Optional[StepTimer], *, n_decode: int = 0,
                 n_chunks: int = 0, context_len: float = 0.0) -> None:
        if st is None:
            return
        end = st.marks[-1][2] if st.marks else st._t
        wall_ns = end - st.t0
        self._step["timed"].add(1)
        self._step["wall_ms"].observe(wall_ns / 1e6)
        for phase, t_lo, t_hi in st.marks:
            self._step[phase].add(t_hi - t_lo)
        if self.tracer is not None:
            tr = self.tracer
            base = tr._t0
            tr.complete(f"step {st.index}", tracing.PID_ENGINE,
                        tracing.TID_STEPS, (st.t0 - base) / 1e3,
                        wall_ns / 1e3,
                        args={"decode_rows": n_decode, "chunks": n_chunks})
            for phase, t_lo, t_hi in st.marks:
                if t_hi > t_lo:
                    tr.complete(phase, tracing.PID_ENGINE,
                                tracing.TID_STEPS, (t_lo - base) / 1e3,
                                (t_hi - t_lo) / 1e3)
        if self.roofline is not None and n_decode:
            self.roofline.step(n_decode, wall_ns, context_len)

    # ------------------------------------------------------------------
    # request lifecycle
    def req_submitted(self, rid: int, step: int) -> None:
        if not self.timing:
            return
        self._req["submitted"].add(1)
        ts = {"submit": self.clock_ns()}
        self._req_ts[rid] = ts
        if self.tracer is not None:
            tid = self.tracer.request_track(rid)
            self.tracer.instant("submit", tracing.PID_REQUESTS, tid,
                                args={"step": step})

    def req_admitted(self, rid: int, waited_steps: int) -> None:
        if not self.timing:
            return
        self._req["queue_wait_steps"].observe(waited_steps)
        now = self.clock_ns()
        ts = self._req_ts.setdefault(rid, {"submit": now})
        ts["admitted"] = now
        if self.tracer is not None:
            tid = self.tracer.request_track(rid)
            base = self.tracer._t0
            self.tracer.complete(
                "queue_wait", tracing.PID_REQUESTS, tid,
                (ts["submit"] - base) / 1e3, (now - ts["submit"]) / 1e3,
                args={"steps": waited_steps})

    def req_chunk(self, rid: int, lo: int, hi: int, t0_ns: int) -> None:
        if self.tracer is None:
            return
        now = self.clock_ns()
        tid = self.tracer.request_track(rid)
        base = self.tracer._t0
        self.tracer.complete(f"prefill[{lo}:{hi})", tracing.PID_REQUESTS,
                             tid, (t0_ns - base) / 1e3, (now - t0_ns) / 1e3,
                             args={"tokens": hi - lo})

    def req_decode_start(self, rid: int) -> None:
        if not self.timing:
            return
        ts = self._req_ts.get(rid)
        if ts is not None and "decode" not in ts:
            ts["decode"] = self.clock_ns()

    def req_finished(self, rid: int, n_tokens: int, reason: str) -> None:
        if not self.timing:
            return
        self._req["finished"].add(1)
        self._req["gen_tokens"].observe(n_tokens)
        ts = self._req_ts.pop(rid, None)
        if self.tracer is None or ts is None:
            return
        now = self.clock_ns()
        tid = self.tracer.request_track(rid)
        base = self.tracer._t0
        t_dec = ts.get("decode", now)
        self.tracer.complete("decode", tracing.PID_REQUESTS, tid,
                             (t_dec - base) / 1e3, (now - t_dec) / 1e3,
                             args={"tokens": n_tokens, "reason": reason})
        self.tracer.instant("finish", tracing.PID_REQUESTS, tid,
                            args={"tokens": n_tokens, "reason": reason})

    # ------------------------------------------------------------------
    # outputs — all three views read the SAME registry
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        if self.roofline is not None:
            self.roofline.flush()  # short runs still report a window
        return self.registry.snapshot()

    def legacy_flat(self) -> Dict[str, Any]:
        return flatten_legacy(self.snapshot())

    def write_metrics(self, path, mode: Optional[Dict[str, Any]] = None
                      ) -> None:
        write_metrics_json(path, self.snapshot(), mode)

    def write_trace(self, path) -> None:
        assert self.tracer is not None, \
            "trace output needs Telemetry(trace=True)"
        self.tracer.write(path)
