"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tiny-moe --steps 200

On this CPU host it trains the *reduced* variant of the selected arch
(or a trainable config like ``tiny-moe`` at full size); on a real TPU
fleet the same entry point lowers the identical ``train_step`` onto the
production mesh (see ``--production-mesh`` which requires enough devices).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.data.pipeline import DataConfig, PackedDataset
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-moe", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant of the arch")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--corpus-bytes", type=int, default=4_000_000)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or cfg.vocab_size > 100_000 or cfg.d_model > 1024:
        cfg = cfg.reduced()
        print(f"[train] using reduced variant: {cfg.name}")
    ds = PackedDataset(DataConfig(seq_len=args.seq_len,
                                  batch_size=args.batch_size,
                                  max_bytes=args.corpus_bytes,
                                  seed=args.seed))
    params = T.init_model(jax.random.key(args.seed), cfg)
    n = T.count_params_analytic(cfg)
    print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
          f"{args.steps} steps x {args.batch_size}x{args.seq_len} tokens")
    opt = O.OptimizerConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                            total_steps=args.steps)
    tcfg = trainer.TrainerConfig(steps=args.steps, log_every=10,
                                 eval_every=max(50, args.steps // 4),
                                 checkpoint_path=args.checkpoint,
                                 checkpoint_every=args.steps // 2 if args.checkpoint else 0)
    params, _, hist = trainer.train(
        params, cfg, opt, ds.batches(), tcfg,
        eval_batches=lambda: ds.eval_batches(4))
    if args.checkpoint:
        from repro.checkpoint.checkpointer import save
        save(args.checkpoint, params, meta={"arch": cfg.name,
                                            "steps": args.steps})
        print(f"[train] saved {args.checkpoint}")
    print(f"[train] final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
