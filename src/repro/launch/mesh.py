"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing the
single real CPU device; only ``dryrun.py`` forces 512 placeholder devices.
"""
from __future__ import annotations

import math

import jax


def _mesh(shape, axes):
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            f"dryrun.py (which sets xla_force_host_platform_device_count)")
    kw = {}
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5; older jax is
        # all-Auto by default, which is exactly what we request here
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devs[:n], **kw)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_smoke_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess sharding tests (8 fake devices)."""
    return _mesh((n_data, n_model), ("data", "model"))


def mesh_context(mesh):
    """Context manager installing ``mesh`` as the ambient mesh:
    ``jax.set_mesh`` on jax >= 0.6, the legacy ``with mesh:`` global-mesh
    context on older jax (where ``sharding/specs._current_mesh`` reads it
    back via ``thread_resources``)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
