import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks device count on init.
"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes with 512 placeholder host devices.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # subprocess/combo

Per combo: jit(step).lower(ShapeDtypeStructs-with-shardings).compile(),
then record ``memory_analysis()`` (proves it fits), ``cost_analysis()``,
and the while-trip-scaled roofline terms parsed from the compiled HLO
(launch/roofline.py) into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``
— the source of truth for EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, SKIPS,
                           config_for_shape)
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import transformer as T
from repro.sharding import specs as SP
from repro.training import optimizer as O
from repro.training.trainer import make_train_step

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# gradient-accumulation factor per arch for train_4k (activation memory
# knob; chosen so memory_analysis peak fits 16GB/chip — EXPERIMENTS.md)
MICROBATCHES = {
    "smollm-360m": 2,
    # NB: global_batch/(micro*data_shards) must stay a positive integer.
    # 8 micro (2 samples/chip/microbatch): §Perf iteration 5 — halves the
    # per-microbatch FSDP gather traffic; fits after iterations 3-4 freed
    # ~4GB/chip.
    "command-r-plus-104b": 8,
    "mixtral-8x7b": 8,
    "recurrentgemma-9b": 8,
    "granite-moe-1b-a400m": 2,
    "stablelm-1.6b": 2,
    "qwen1.5-4b": 4,
    "phi-3-vision-4.2b": 4,
    "whisper-medium": 2,
    "xlstm-1.3b": 4,
}


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=jax.sharding.NamedSharding(mesh, spec))


def _with_shardings(shape_tree, spec_tree, mesh):
    from jax.sharding import PartitionSpec as P

    return jax.tree.map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp),
        shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _batch_structs(cfg, mesh, B, S, *, labels: bool):
    b_ax = SP.batch_spec(mesh, B)
    from jax.sharding import PartitionSpec as P

    out = {"tokens": _sds((B, S), jnp.int32, mesh, P(b_ax, None))}
    if labels:
        out["labels"] = _sds((B, S), jnp.int32, mesh, P(b_ax, None))
    if cfg.is_encoder_decoder:
        out["audio_embeds"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16, mesh, P(b_ax, None, None))
    if cfg.num_image_tokens:
        out["image_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model),
                                   jnp.bfloat16, mesh, P(b_ax, None, None))
    return out


SEQ_SHARD_TRAIN = {"command-r-plus-104b"}
# bf16 AdamW moments for the largest config (EXPERIMENTS.md precision note)
BF16_MOMENTS = {"command-r-plus-104b"}


def build_lowering(arch: str, shape_name: str, mesh):
    """Returns (lowered, meta) for the right step fn for this shape kind."""
    cfg = config_for_shape(arch, shape_name)
    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    if shp.kind == "train" and arch in SEQ_SHARD_TRAIN:
        cfg = cfg.replace(act_seq_shard=True)
    if cfg.moe is not None:
        n_batch_shards = mesh.size // mesh.shape["model"]
        cfg = cfg.replace(moe_dispatch_groups=n_batch_shards)

    params_shapes = jax.eval_shape(
        lambda: T.init_model(jax.random.key(0), cfg))
    # decode: pure-TP weights when the TP shard fits comfortably (FSDP
    # would re-gather every weight every token — §Perf mixtral decode);
    # fall back to FSDP for params too big for a single chip's HBM.
    serve_tp_only = False
    if shp.kind == "decode":
        tp_bytes = 2 * T.count_params_analytic(cfg) / mesh.shape["model"]
        serve_tp_only = tp_bytes < 8e9
    meta0 = {"serve_tp_only": serve_tp_only}
    pspecs = SP.param_spec_tree(cfg, mesh, params_shapes,
                                serve_tp_only=serve_tp_only)
    params_in = _with_shardings(params_shapes, pspecs, mesh)

    meta = {"arch": arch, "shape": shape_name, "kind": shp.kind,
            "global_batch": B, "seq_len": S,
            "n_params": T.count_params_analytic(cfg), **meta0}

    if shp.kind == "train":
        micro = MICROBATCHES.get(arch, 1)
        # each microbatch must still split over every batch shard
        n_batch_shards = mesh.size // mesh.shape["model"]
        micro = max(1, min(micro, B // n_batch_shards))
        meta["microbatches"] = micro
        meta["act_seq_shard"] = cfg.act_seq_shard
        opt_cfg = O.OptimizerConfig(
            moment_dtype="bfloat16" if arch in BF16_MOMENTS else "float32")
        meta["moment_dtype"] = opt_cfg.moment_dtype
        opt_shapes = jax.eval_shape(lambda p: O.init_opt_state(p, opt_cfg),
                                    params_shapes)
        from jax.sharding import PartitionSpec as P

        ospecs = {"mu": pspecs, "nu": pspecs, "step": P()}
        opt_in = _with_shardings(opt_shapes, ospecs, mesh)
        batch_in = _batch_structs(cfg, mesh, B, S, labels=True)
        step = make_train_step(cfg, opt_cfg, microbatches=micro, remat=True)
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            params_in, opt_in, batch_in)
        meta["tokens_per_step"] = B * S
        return lowered, meta

    if shp.kind == "prefill":
        batch_in = _batch_structs(cfg, mesh, B, S, labels=False)

        def prefill_step(params, batch):
            return T.prefill(params, cfg, batch, S)

        # pin the output decode-state sharding (otherwise XLA may leave
        # the 27GB/chip KV stack unsharded on non-TP-divisible head counts)
        state_shapes = jax.eval_shape(lambda: T.init_decode_state(cfg, B, S))
        sspecs = SP.decode_state_spec_tree(cfg, mesh, B, state_shapes)
        sshard = jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp), sspecs,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        lowered = jax.jit(prefill_step,
                          out_shardings=(None, sshard)).lower(
            params_in, batch_in)
        meta["tokens_per_step"] = B * S
        return lowered, meta

    # decode: one new token against a seq_len-deep cache
    state_shapes = jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, S))
    sspecs = SP.decode_state_spec_tree(cfg, mesh, B, state_shapes)
    state_in = _with_shardings(state_shapes, sspecs, mesh)
    from jax.sharding import PartitionSpec as P

    b_ax = SP.batch_spec(mesh, B)
    tok_in = _sds((B, 1), jnp.int32, mesh, P(b_ax, None))

    def serve_step(params, state, tokens):
        logits, new_state = T.decode_step(params, cfg, state, tokens)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], new_state

    lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
        params_in, state_in, tok_in)
    meta["tokens_per_step"] = B
    return lowered, meta


def _tpu_peak_adjustment(meta, mesh, mem):
    """XLA-CPU upcasts every bf16 matmul to f32, materializing an f32
    shadow copy of each weight next to its bf16 argument (verified by
    buffer dump: f32 stacks exactly 2x their bf16 args).  TPUs execute
    bf16 matmuls natively, so for serve-TP decode we also report the peak
    with that shadow removed.  Train combos are left unadjusted (their
    f32 buffers include legitimate master/grad copies)."""
    if not meta.get("serve_tp_only"):
        return {}
    bf16_params = 2 * meta["n_params"] / mesh.shape["model"]
    shadow = 2.0 * bf16_params  # the f32 copy
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    return {"cpu_f32_weight_shadow_bytes": shadow,
            "peak_estimate_tpu_bytes": max(0.0, peak - shadow)}


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: Path
            ) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.perf_counter()
    with mesh_context(mesh):
        lowered, meta = build_lowering(arch, shape_name, mesh)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    print(mem)
    ca = roofline.xla_cost_analysis(compiled)
    print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    cfg = config_for_shape(arch, shape_name)
    hlo = compiled.as_text()
    rep = roofline.analyze(hlo, n_dev, default_trips=max(1, cfg.n_periods))
    mf = roofline.model_flops(cfg, meta["tokens_per_step"],
                              "train" if meta["kind"] == "train" else "serve")

    result = {
        **meta,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "cost_analysis": {"flops": ca.get("flops"),
                          "bytes_accessed": ca.get("bytes accessed")},
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
            **_tpu_peak_adjustment(meta, mesh, mem),
        },
        "roofline": rep.to_dict(),
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(1.0, rep.flops * n_dev),
        "hlo_collective_ops": rep.coll_count,
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    fn = out_dir / f"{arch}__{shape_name}__{mesh_name}.json"
    fn.write_text(json.dumps(result, indent=1))
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
          f"compile={t_compile:.1f}s bottleneck={rep.bottleneck} "
          f"t=({rep.t_compute:.4f},{rep.t_memory:.4f},"
          f"{rep.t_collective:.4f})s")
    return result


def run_all(out_dir: Path, multi_pod_list=(False, True), archs=None,
            shapes=None) -> int:
    """Each combo in a subprocess (isolation + bounded memory)."""
    archs = archs or ASSIGNED_ARCHS
    shapes = shapes or list(INPUT_SHAPES)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in multi_pod_list:
                if (arch, shape) in SKIPS:
                    run_one(arch, shape, mp, out_dir)  # writes skip marker
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--out", str(out_dir)]
                if mp:
                    cmd.append("--multi-pod")
                r = subprocess.run(cmd, capture_output=True, text=True)
                tail = (r.stdout + r.stderr).strip().splitlines()[-3:]
                if r.returncode != 0:
                    failures.append((arch, shape, mp))
                    print(f"[dryrun] FAIL {arch} x {shape} mp={mp}:")
                    print("\n".join(tail))
                else:
                    print("\n".join(t for t in tail if "[dryrun]" in t))
    print(f"[dryrun] done, {len(failures)} failures: {failures}")
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    out = Path(args.out)
    if args.all:
        sys.exit(run_all(out))
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    skip = (args.arch, args.shape)
    if skip in SKIPS:
        print(f"[dryrun] SKIP {skip}: {SKIPS[skip]}")
        out.mkdir(parents=True, exist_ok=True)
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        (out / f"{args.arch}__{args.shape}__{mesh_name}.json").write_text(
            json.dumps({"arch": args.arch, "shape": args.shape,
                        "mesh": mesh_name, "status": "skipped",
                        "reason": SKIPS[skip]}, indent=1))
        return
    run_one(args.arch, args.shape, args.multi_pod, out)


if __name__ == "__main__":
    main()
