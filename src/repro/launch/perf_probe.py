import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf-iteration workbench: compile one (arch x shape x mesh) and break
the roofline terms down to the responsible HLO ops (with jax op metadata),
so each hillclimb hypothesis can be checked against the actual schedule.

    PYTHONPATH=src python -m repro.launch.perf_probe --arch X --shape Y \
        [--multi-pod] [--top 15]
"""
import argparse
import math
import re
from collections import defaultdict

import jax

from repro.configs import INPUT_SHAPES, config_for_shape
from repro.launch import roofline as R
from repro.launch.dryrun import build_lowering
from repro.launch.mesh import make_production_mesh, mesh_context

_META_RE = re.compile(r'op_name="([^"]+)"')


def top_ops(text, n_devices, default_trips, top=15):
    comps, entry = R.parse_hlo(text)
    mult = R._multipliers(comps, entry, default_trips)
    shapes = {}
    for comp in comps.values():
        for inst in comp.instructions:
            shapes[inst.name] = R._parse_dims(inst.typestr)
    colls, mems = [], []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        for inst in comp.instructions:
            meta = _META_RE.search(inst.line)
            label = meta.group(1) if meta else inst.name
            if any(inst.opcode.startswith(c) for c in R.COLLECTIVES):
                out_b = R._parse_shape(inst.typestr)
                g = R._group_size(inst.line, n_devices)
                eff = out_b * (g - 1) / max(g, 1)
                if inst.opcode.startswith("all-reduce"):
                    eff *= 2
                colls.append((m * eff, m, inst.opcode, inst.typestr.split("{")[0],
                              g, label))
            elif not comp.is_fusion_body and inst.opcode in R.COUNT_BYTE_OPS:
                b = R._parse_shape(inst.typestr)
                mems.append((m * b, m, inst.opcode,
                             inst.typestr.split("{")[0], label))
    colls.sort(reverse=True)
    mems.sort(reverse=True)
    print(f"\n== top {top} collectives (bytes x mult) ==")
    for b, m, op, ty, g, label in colls[:top]:
        print(f"{b/1e9:9.2f} GB  x{m:6.0f}  {op:18s} g={g:<4d} {ty:28s} {label[:70]}")
    print(f"\n== top {top} memory ops (output bytes x mult) ==")
    for b, m, op, ty, label in mems[:top]:
        print(f"{b/1e9:9.2f} GB  x{m:6.0f}  {op:18s} {ty:28s} {label[:70]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh_context(mesh):
        lowered, meta = build_lowering(args.arch, args.shape, mesh)
        compiled = lowered.compile()
    txt = compiled.as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(txt)
    cfg = config_for_shape(args.arch, args.shape)
    rep = R.analyze(txt, mesh.size, default_trips=max(1, cfg.n_periods))
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    print(f"terms: compute={rep.t_compute:.4f}s memory={rep.t_memory:.4f}s "
          f"collective={rep.t_collective:.4f}s bottleneck={rep.bottleneck}")
    print(f"peak mem/chip {peak/1e9:.2f} GB  (temp {mem.temp_size_in_bytes/1e9:.2f})")
    print(f"collectives by type: "
          f"{ {k: round(v/1e9,1) for k, v in rep.coll_by_type.items()} } GB")
    top_ops(txt, mesh.size, max(1, cfg.n_periods), args.top)


if __name__ == "__main__":
    main()
