"""Serving launcher — both serving modes:

* plain batched serving (fits-in-memory):
    PYTHONPATH=src python -m repro.launch.serve --arch tiny-moe \
        --prompt "def main(" --max-new 64
* the paper's offloaded interactive mode (MoE archs):
    ... --offload [--quantize] [--cache-size 4] [--num-speculative 2]

With ``--offload`` the engine reports cache statistics and the cost-model
tokens/s projection for the paper's four hardware targets.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.offload_engine import OffloadEngine
from repro.data.pipeline import decode_bytes, encode_text
from repro.models import transformer as T
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampler import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-moe", choices=list_archs())
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--prompt", action="append", default=None)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--offload", action="store_true")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--cache-size", type=int, default=None)
    ap.add_argument("--num-speculative", type=int, default=None)
    ap.add_argument("--sampler", default="greedy",
                    choices=["greedy", "categorical", "topk"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.vocab_size > 100_000 or cfg.d_model > 1024:
        cfg = cfg.reduced()
        print(f"[serve] using reduced variant: {cfg.name}")
    rng = jax.random.key(args.seed)
    if args.checkpoint:
        from repro.checkpoint.checkpointer import restore
        tmpl = jax.eval_shape(lambda: T.init_model(rng, cfg))
        params = restore(args.checkpoint, tmpl)
    else:
        params = T.init_model(rng, cfg)
    prompts = args.prompt or ["def main(", "import os\n"]
    enc = [encode_text(p) % cfg.vocab_size for p in prompts]

    if args.offload:
        if cfg.moe is None:
            raise SystemExit("--offload targets MoE archs (the paper's "
                             "technique needs routed experts); dense archs "
                             "use naive streaming — see DESIGN.md §5")
        from repro.configs.base import OffloadSpec
        spec = cfg.offload or OffloadSpec()
        if args.cache_size or args.num_speculative:
            spec = dataclasses.replace(
                spec,
                cache_size=args.cache_size or spec.cache_size,
                num_speculative=args.num_speculative or spec.num_speculative)
        eng = OffloadEngine(params, cfg, spec, quantized=args.quantize)
        for p, e in zip(prompts, enc):
            out, stats = eng.generate(e[None], args.max_new)
            print(f"--- prompt {p!r}")
            print("gen:", repr(decode_bytes(out[0])))
            print(f"stats: hit_ratio={stats.hit_ratio:.3f} "
                  f"demand={stats.demand_loads} spec_hits={stats.spec_hits} "
                  f"spec_loads={stats.spec_loads} "
                  f"h2d={stats.bytes_h2d/1e6:.1f}MB")
            for hw in ("t4", "3060", "3080m", "a100"):
                print(f"  {hw:6s}: {eng.throughput_estimate(stats, hw):.2f} "
                      f"tok/s (cost model @ {cfg.name} scale)")
        if eng.size_report:
            print("quantized sizes:", {k: f"{v/1e6:.1f}MB"
                                       for k, v in eng.size_report.items()})
        return

    eng = ServeEngine(params, cfg, SamplerConfig(kind=args.sampler))
    reqs = [Request(e, args.max_new) for e in enc]
    for p, r in zip(prompts, eng.serve_batch(reqs, seed=args.seed)):
        print(f"--- prompt {p!r}\ngen: {decode_bytes(np.array(r.completed))!r}")


if __name__ == "__main__":
    main()
