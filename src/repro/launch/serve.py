"""Serving launcher — all three serving modes:

* plain batched serving (fits-in-memory, static batch):
    PYTHONPATH=src python -m repro.launch.serve --arch tiny-moe \
        --prompt "def main(" --max-new 64
* continuous batching with simulated request arrivals (DESIGN.md §4):
    ... --continuous [--n-requests 16] [--arrival-rate 0.5] \
        [--max-slots 4] [--slot-len 256] [--policy overlap]
* the paper's offloaded interactive mode (MoE archs):
    ... --offload [--quantize] [--cache-size 4] [--num-speculative 2]
  (--quantize runs REAL packed execution: HQQ-packed experts streamed
  through the device buffer pool, DESIGN.md §6)
* continuous batching + offloading composed (packed pool shared across
  the running batch):
    ... --continuous --offload --quantize

With ``--offload`` the engine reports cache statistics and the cost-model
tokens/s projection for the paper's four hardware targets.  With
``--continuous`` requests arrive over time (seeded Bernoulli per decode
step), join the running batch as slots free up, and stream per-request.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.offload_engine import OffloadEngine
from repro.data.pipeline import decode_bytes, encode_text
from repro.models import transformer as T
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampler import SamplerConfig


def resolve_offload_spec(spec, cache_size=None, num_speculative=None):
    """Overlay CLI offload overrides on an arch's :class:`OffloadSpec`.

    ``None`` means "flag not given"; 0 is a real value — the paper's k=0
    (no cache) and no-speculation ablations must not silently fall back
    to the arch defaults (``args.cache_size or spec.cache_size`` did
    exactly that — regression-tested in ``tests/test_serve_cli.py``).
    """
    if cache_size is None and num_speculative is None:
        return spec
    return dataclasses.replace(
        spec,
        cache_size=spec.cache_size if cache_size is None else cache_size,
        num_speculative=(spec.num_speculative if num_speculative is None
                         else num_speculative))


def resolve_top_k(cfg, top_k_override):
    """MELINOE-style router top-k override: serve an MoE arch with
    fewer experts per token than it was trained with — each dropped
    expert is h2d traffic the offloaded decode never pays.

    ``None`` means "flag not given" (arch default top_k); 0 or negative
    is an explicit error, NOT a fall-through to the default (the same
    or-truthiness trap :func:`resolve_offload_spec` guards — ``k or
    cfg.moe.top_k`` would silently undo an explicit ``0``).  Values
    above the arch's top_k clamp down to it: the router can't route to
    more experts than it scores.
    """
    if top_k_override is None:
        return cfg
    if cfg.moe is None:
        raise ValueError(
            f"--top-k-override targets MoE routing; {cfg.name} is dense")
    k = int(top_k_override)
    if k <= 0:
        raise ValueError(
            f"--top-k-override must be >= 1 (got {k}); every token "
            f"routes to at least one expert")
    k = min(k, cfg.moe.top_k)
    return cfg.replace(moe=dataclasses.replace(cfg.moe, top_k=k))


def resolve_draft(draft_config, num_draft_tokens):
    """CLI speculation flags -> ``(draft_config_name, k)``.

    Speculation is enabled iff a draft config was given AND k resolves
    >= 1; ``--num-draft-tokens`` defaults to 4 when a draft is set but
    the count flag is absent.  ``None`` means "flag not given"; 0 is a
    real value — ``--num-draft-tokens 0`` must disable speculation, not
    fall back to the default k (the same or-truthiness trap
    :func:`resolve_offload_spec` guards; regression-tested in
    ``tests/test_serve_cli.py``).
    """
    if draft_config is None:
        return None, 0
    k = 4 if num_draft_tokens is None else int(num_draft_tokens)
    if k <= 0:
        return None, 0
    return draft_config, k


def resolve_kv_features(prefix_cache, preemption, kv_host_pages):
    """CLI prefix-cache / preemption flags -> ``(prefix_cache_pages,
    preemption_bool, kv_host_pages)`` for the ContinuousEngine.

    ``None`` means "flag not given"; 0 is a real value — ``--prefix-cache
    0`` is the no-cache ablation and ``--kv-host-pages 0`` the
    recompute-only-preemption ablation, and neither may silently fall
    back to a default (the or-truthiness trap
    :func:`resolve_offload_spec` guards; regression-tested in
    ``tests/test_serve_cli.py``).
    """
    pc = 0 if prefix_cache is None else int(prefix_cache)
    if pc < 0:
        raise ValueError(f"--prefix-cache must be >= 0 pages (got {pc}); "
                         f"0 disables the cache")
    pre = preemption == "on"
    if kv_host_pages is not None and not pre:
        raise ValueError(
            "--kv-host-pages sizes the swap pool preemption stages "
            "pages into; add --preemption on (0 with preemption on is "
            "the recompute-only ablation)")
    hp = 0 if kv_host_pages is None else int(kv_host_pages)
    if hp < 0:
        raise ValueError(f"--kv-host-pages must be >= 0 (got {hp}); "
                         f"0 means every preemption recomputes")
    return pc, pre, hp


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", "--config", dest="arch", default="tiny-moe",
                    choices=list_archs(),
                    help="zoo config id (--config is an alias: any "
                         "registry arch serves through the same "
                         "per-layer-kind state planes, DESIGN.md §12)")
    ap.add_argument("--top-k-override", type=int, default=None,
                    metavar="K",
                    help="route each token to min(K, arch top_k) experts "
                         "instead of the arch default — fewer routed "
                         "experts = fewer expert loads over the h2d bus "
                         "in offloaded decode (0/negative is an error, "
                         "not a fall-back to the default)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--prompt", action="append", default=None)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--offload", action="store_true")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--cache-size", type=int, default=None)
    ap.add_argument("--num-speculative", type=int, default=None)
    ap.add_argument("--draft-config", default=None, choices=list_archs(),
                    help="token-level draft-and-verify decoding "
                         "(DESIGN.md §11): a dense arch sharing the "
                         "target's vocab proposes tokens the target "
                         "verifies in one chunk — greedy output is "
                         "bitwise identical to non-speculative decode")
    ap.add_argument("--num-draft-tokens", type=int, default=None,
                    help="draft tokens proposed per verify round "
                         "(default 4 when --draft-config is set; 0 "
                         "disables speculation)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching with simulated arrivals")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="P(new request arrives) per decode step")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--slot-len", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: admit prompts C tokens per "
                         "step so long prompts never stall in-flight "
                         "decodes (bitwise-identical outputs; "
                         "DESIGN.md §8)")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-step token cap (decode rows + prefill "
                         "chunks); default max_slots + prefill_chunk")
    ap.add_argument("--kv-page", type=int, default=None,
                    help="block-paged KV (DESIGN.md §9): page size in "
                         "positions; slots allocate pages on demand and "
                         "decode attention is sliced to the live page "
                         "horizon instead of paying slot_len every step")
    ap.add_argument("--kv-pages-total", type=int, default=None,
                    help="shared page-pool size (default: full "
                         "provisioning, max_slots * ceil(slot_len/"
                         "kv_page)); smaller pools gate admission on "
                         "actual KV need instead of slot count")
    ap.add_argument("--prefix-cache", type=int, default=None,
                    metavar="PAGES",
                    help="radix prefix caching (DESIGN.md §13, needs "
                         "--kv-page): keep up to PAGES immutable full "
                         "pages of finished prompts; requests hitting a "
                         "cached prefix adopt those pages and prefill "
                         "only from the divergence point (0 disables — "
                         "a real ablation, not a fall-back)")
    ap.add_argument("--preemption", default="off", choices=["off", "on"],
                    help="preempt-instead-of-refuse admission (DESIGN.md "
                         "§13, needs --kv-page): reserve only the "
                         "prompt's pages, swap the lowest-priority "
                         "victim out when the pool runs dry, resume it "
                         "bitwise later")
    ap.add_argument("--kv-host-pages", type=int, default=None, metavar="N",
                    help="host-side swap pool budget in pages (needs "
                         "--preemption on): preempted KV stages d2h into "
                         "it and back on resume; 0 drops KV and resumes "
                         "by recompute (a real ablation)")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="seeded fault schedule (DESIGN.md §14), e.g. "
                         "'expert_fetch=0.05,nan_logits@2,slow_step@5:25' "
                         "— site=RATE fires per opportunity, site@N,M at "
                         "ordinals, :MS adds a stall; sites: expert_fetch "
                         "swap_out swap_in page_pool nan_logits slow_step "
                         "(seeded by --seed; needs --continuous)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request wall-clock deadline; expired "
                         "requests finish with status deadline_exceeded "
                         "and release every resource they held")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue: submissions beyond CAP "
                         "waiting requests are rejected (backpressure) "
                         "instead of growing the queue without bound")
    ap.add_argument("--cancel-every", type=int, default=None, metavar="N",
                    help="cancel every Nth submitted request once it has "
                         "emitted a token — the client-abandonment chaos "
                         "driver (DESIGN.md §14)")
    ap.add_argument("--policy", default="overlap",
                    choices=["fcfs", "overlap"])
    ap.add_argument("--sampler", default="greedy",
                    choices=["greedy", "categorical", "topk"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write the full namespaced metrics snapshot "
                         "(schema: repro.obs.schema, validated by "
                         "tools/check_metrics_schema.py) and enable "
                         "step/request timing + roofline accounting "
                         "(DESIGN.md §10)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace_event JSON (load in "
                         "chrome://tracing or ui.perfetto.dev) of every "
                         "request's lifecycle spans and every step's "
                         "phase breakdown")
    ap.add_argument("--roofline-hw", default="t4",
                    choices=["t4", "3060", "3080m", "a100"],
                    help="cost-model hardware target for the measured-vs-"
                         "predicted roofline accounting")
    return ap


def make_telemetry(args):
    """CLI flags -> :class:`repro.obs.Telemetry` (off when neither
    output was requested — the engines then carry zero instrumentation)."""
    from repro.obs import Telemetry
    if args.metrics_json is None and args.trace is None:
        return Telemetry.off()
    return Telemetry(timing=True, trace=args.trace is not None,
                     roofline_hw=args.roofline_hw)


def write_outputs(args, obs, mode):
    if args.metrics_json is not None:
        obs.write_metrics(args.metrics_json, mode)
        print(f"[obs] metrics -> {args.metrics_json}")
    if args.trace is not None:
        obs.write_trace(args.trace)
        print(f"[obs] trace   -> {args.trace} "
              f"({len(obs.tracer.events)} events; load in chrome://tracing)")


def print_telemetry_summary(obs):
    """End-of-run summary straight from the registry — the same numbers
    the JSON snapshot carries."""
    snap = obs.snapshot()
    step = snap.get("step")
    if step and step["timed"]:
        total = sum(step[f"{p}_ns"] for p in
                    ("plan", "chunk", "dispatch", "sync", "sample", "host"))
        shares = " ".join(
            f"{p}={100 * step[f'{p}_ns'] / max(1, total):.0f}%"
            for p in ("plan", "chunk", "dispatch", "sync", "sample", "host"))
        wall = step["wall_ms"]
        print(f"[obs] step wall p50={wall['p50']:.2f}ms "
              f"p95={wall['p95']:.2f}ms over {step['timed']} timed steps; "
              f"phases: {shares}")
    roof = snap.get("roofline")
    if roof and roof["windows"]:
        print(f"[obs] roofline({roof['hw']}): measured "
              f"{roof['measured_tok_s']:.2f} tok/s vs predicted "
              f"{roof['predicted_tok_s']:.2f} tok/s "
              f"(delta x{roof['delta_ratio']:.2f}); h2d "
              f"{roof['measured_h2d_bytes_per_token']/1e6:.2f}MB/tok vs "
              f"naive {roof['naive_h2d_bytes_per_token']/1e6:.2f}MB/tok "
              f"(saves x{roof['h2d_savings_ratio']:.1f})")


def print_spec_summary(obs):
    snap = obs.snapshot()
    spec = snap.get("spec")
    if spec and spec["rounds"]:
        print(f"[spec] {spec['rounds']} verify rounds, acceptance "
              f"{spec['acceptance_rate']:.2f}, "
              f"{spec['bytes_h2d_per_accepted']/1e6:.2f}MB h2d per "
              f"emitted token")


def main():
    args = build_parser().parse_args()

    if args.kv_page is not None and not args.continuous:
        raise SystemExit("--kv-page targets the continuous engine's "
                         "slotted KV plane; add --continuous")
    try:
        prefix_pages, preempt, host_pages = resolve_kv_features(
            args.prefix_cache, args.preemption, args.kv_host_pages)
    except ValueError as e:
        raise SystemExit(str(e))
    if (prefix_pages or preempt) and not args.continuous:
        raise SystemExit("--prefix-cache/--preemption target the "
                         "continuous engine's paged KV plane; add "
                         "--continuous --kv-page")
    if ((args.inject_faults or args.deadline_ms is not None
         or args.queue_cap is not None or args.cancel_every is not None)
            and not args.continuous):
        raise SystemExit("--inject-faults/--deadline-ms/--queue-cap/"
                         "--cancel-every target the continuous engine's "
                         "request lifecycle; add --continuous")
    if ((args.metrics_json is not None or args.trace is not None)
            and not (args.continuous or args.offload)):
        raise SystemExit("--metrics-json/--trace instrument the continuous "
                         "and offload engines; add --continuous or "
                         "--offload")
    draft_name, draft_k = resolve_draft(args.draft_config,
                                        args.num_draft_tokens)
    if draft_name is not None and not (args.offload or args.continuous):
        raise SystemExit("--draft-config targets the offload and "
                         "continuous engines; add --offload or "
                         "--continuous")
    if draft_name is not None and args.sampler != "greedy":
        raise SystemExit("--draft-config needs --sampler greedy (the "
                         "acceptance rule compares argmax streams)")
    telem = make_telemetry(args)
    cfg = get_config(args.arch)
    if cfg.vocab_size > 100_000 or cfg.d_model > 1024:
        cfg = cfg.reduced()
        print(f"[serve] using reduced variant: {cfg.name}")
    try:
        cfg = resolve_top_k(cfg, args.top_k_override)
    except ValueError as e:
        raise SystemExit(str(e))
    if args.top_k_override is not None:
        print(f"[serve] router top-k override: {cfg.moe.top_k} "
              f"experts/token")
    if cfg.is_encoder_decoder and not args.continuous:
        raise SystemExit(
            f"{cfg.name} is encoder-decoder: serve it with --continuous "
            f"(the frontend output is encoded once at admission into the "
            f"shared encoder-KV plane, DESIGN.md §12)")
    rng = jax.random.key(args.seed)
    if args.checkpoint:
        from repro.checkpoint.checkpointer import restore
        tmpl = jax.eval_shape(lambda: T.init_model(rng, cfg))
        params = restore(args.checkpoint, tmpl)
    else:
        params = T.init_model(rng, cfg)
    prompts = args.prompt or ["def main(", "import os\n"]
    enc = [encode_text(p) % cfg.vocab_size for p in prompts]

    offload_eng = None
    if args.offload:
        if cfg.moe is None:
            raise SystemExit("--offload targets MoE archs (the paper's "
                             "technique needs routed experts); dense archs "
                             "use naive streaming — see DESIGN.md §5")
        from repro.configs.base import OffloadSpec
        spec = resolve_offload_spec(cfg.offload or OffloadSpec(),
                                    args.cache_size, args.num_speculative)
        draft = None
        if draft_name is not None and not args.continuous:
            from repro.core.draft import make_draft
            draft = make_draft(draft_name, seed=args.seed)
        eng = OffloadEngine(params, cfg, spec, quantized=args.quantize,
                            telemetry=telem if not args.continuous
                            else None,
                            draft=draft, num_draft_tokens=draft_k)
        if args.continuous:
            # continuous + offloaded decode compose (DESIGN.md §6); the
            # packed pool needs quantized weights
            if not args.quantize:
                raise SystemExit("--continuous --offload needs --quantize "
                                 "(the buffer pool serves HQQ-packed "
                                 "experts)")
            offload_eng = eng
    if args.offload and not args.continuous:
        for p, e in zip(prompts, enc):
            out, stats = eng.generate(e[None], args.max_new)
            print(f"--- prompt {p!r}")
            print("gen:", repr(decode_bytes(out[0])))
            print(f"stats: hit_ratio={stats.hit_ratio:.3f} "
                  f"demand={stats.demand_loads} spec_hits={stats.spec_hits} "
                  f"spec_loads={stats.spec_loads} "
                  f"h2d={stats.bytes_h2d/1e6:.1f}MB")
            for hw in ("t4", "3060", "3080m", "a100"):
                print(f"  {hw:6s}: {eng.throughput_estimate(stats, hw):.2f} "
                      f"tok/s (cost model @ {cfg.name} scale)")
        if eng.size_report:
            print("quantized sizes:", {k: f"{v/1e6:.1f}MB"
                                       for k, v in eng.size_report.items()})
        print_telemetry_summary(eng.obs)
        print_spec_summary(eng.obs)
        write_outputs(args, eng.obs, {
            "engine": "offload", "arch": cfg.name,
            "offloaded": True, "timing": eng.obs.timing,
            "plane": eng._exec.plane, "roofline": eng.obs.timing,
            "speculative": draft_k > 0})
        return

    if args.continuous:
        from repro.serving.engine import ContinuousEngine
        from repro.serving.scheduler import ExpertOverlapPolicy, fcfs_policy
        policy = (ExpertOverlapPolicy(params, cfg)
                  if args.policy == "overlap" and cfg.moe is not None
                  else fcfs_policy)
        draft_params, draft_cfg = None, None
        if draft_name is not None:
            draft_cfg = get_config(draft_name)
            draft_params = T.init_model(jax.random.key(args.seed),
                                        draft_cfg)
        faults = None
        if args.inject_faults:
            from repro.serving.faults import FaultInjector
            try:
                faults = FaultInjector.parse(args.inject_faults,
                                             seed=args.seed)
            except ValueError as e:
                raise SystemExit(f"--inject-faults: {e}")
        try:
            eng = ContinuousEngine(
                params, cfg, max_slots=args.max_slots,
                slot_len=args.slot_len,
                sampler=SamplerConfig(kind=args.sampler), policy=policy,
                prefill_chunk=args.prefill_chunk,
                token_budget=args.token_budget,
                seed=args.seed, offload=offload_eng,
                kv_page=args.kv_page,
                kv_pages_total=args.kv_pages_total,
                prefix_cache_pages=prefix_pages,
                preemption=preempt,
                kv_host_pages=host_pages,
                telemetry=telem,
                draft_params=draft_params, draft_cfg=draft_cfg,
                num_draft_tokens=draft_k,
                faults=faults, queue_cap=args.queue_cap,
                deadline_ms=args.deadline_ms)
        except ValueError as e:
            raise SystemExit(f"--continuous: {e}")

        def on_finish(req):
            print(f"[step {eng.step_count:4d}] req {req.rid} finished "
                  f"({req.finish_reason}, waited "
                  f"{req.arrival}→{eng.step_count}): "
                  f"{decode_bytes(np.array(req.generated))!r}")

        arrivals = np.random.default_rng(args.seed)
        # enc-dec archs need a frontend output per request; the CLI has
        # no audio pipeline, so a seeded stub stands in for it (the same
        # convention as the smoke tests)
        frontend = np.random.default_rng(args.seed + 1)
        submitted = 0
        rejected = 0
        pending_cancel = []
        # the run must also drain SWAPPED requests: a preempted request
        # is neither waiting nor running while parked off-device
        while submitted < args.n_requests or eng.sched.has_waiting \
                or eng.sched.n_running or eng._swapped:
            idle = (not eng.sched.has_waiting) and eng.sched.n_running == 0 \
                and not eng._swapped
            while (submitted < args.n_requests
                   and (idle or arrivals.random() < args.arrival_rate)):
                idle = False
                e = enc[submitted % len(enc)]
                extras = None
                if cfg.is_encoder_decoder:
                    extras = {"audio_embeds": frontend.standard_normal(
                        (cfg.encoder_seq, cfg.d_model)).astype(np.float32)}
                try:
                    req = eng.submit(e, args.max_new, on_finish=on_finish,
                                     extras=extras)
                except ValueError as err:
                    raise SystemExit(f"--continuous: {err} (raise "
                                     f"--slot-len or lower --max-new)")
                if req.status == "rejected":
                    rejected += 1
                elif (args.cancel_every and submitted % args.cancel_every
                        == args.cancel_every - 1):
                    pending_cancel.append(req)
                submitted += 1
            eng.step()
            # chaos driver: abandon marked requests once they have
            # streamed a token (mid-decode — the interesting case)
            for req in list(pending_cancel):
                if req.state == "finished":
                    pending_cancel.remove(req)
                elif req.generated:
                    eng.cancel(req.rid)
                    pending_cancel.remove(req)
        s = eng.stats()
        print(f"[continuous] {s['finished']} requests, {s['tokens']} tokens "
              f"in {s['steps']} steps ({s['tokens_per_step']:.2f} tok/step, "
              f"{args.max_slots} slots)")
        if args.kv_page is not None:
            print(f"[paged-kv] pool {s['kv_pages_total']} pages x "
                  f"{s['kv_page_size']} positions "
                  f"({s['kv_pages_free']} free at exit); decode attention "
                  f"sliced to the live page horizon (DESIGN.md §9)")
        if offload_eng is not None:
            print(f"[offloaded] pool traffic: {s['offload_demand_loads']} "
                  f"demand + {s['offload_spec_loads']} spec loads, "
                  f"{s['offload_hits']} hits "
                  f"({s['offload_bytes_h2d']/1e6:.1f}MB h2d measured)")
        if prefix_pages:
            pm = eng.metrics()["prefix"]
            print(f"[prefix] {pm['prefills_skipped']} prefills hit the "
                  f"cache ({pm['hit_tokens']} prompt tokens skipped); "
                  f"{pm['nodes']} pages indexed, {pm['evicted_pages']} "
                  f"evicted (DESIGN.md §13)")
        if preempt:
            km = eng.metrics()["kv_host"]
            print(f"[preempt] {km['preemptions']} preemptions, "
                  f"{km['resumes']} resumes ({km['recomputes']} by "
                  f"recompute); swap traffic "
                  f"{(km['swap_out_bytes'] + km['swap_in_bytes'])/1e6:.1f}"
                  f"MB over a {km['pages_total']}-page host pool")
        if (args.inject_faults or args.cancel_every or args.queue_cap
                or args.deadline_ms is not None):
            fm = eng.metrics()["faults"]
            print(f"[faults] {fm['injected']} injected "
                  f"(fetch={fm['fired_expert_fetch']} "
                  f"retries={fm['fetch_retries']} "
                  f"degraded={fm['fetch_degraded']} "
                  f"nan={fm['nan_quarantined']}); terminal statuses: "
                  f"{fm['completed']} completed, {fm['cancelled']} "
                  f"cancelled, {fm['deadline_exceeded']} "
                  f"deadline_exceeded, {fm['rejected']} rejected, "
                  f"{fm['failed']} failed (DESIGN.md §14)")
        print_telemetry_summary(eng.obs)
        print_spec_summary(eng.obs)
        write_outputs(args, eng.obs, {
            "engine": "continuous", "arch": cfg.name,
            "kv_layout": "paged" if args.kv_page is not None else "dense",
            "offloaded": offload_eng is not None,
            "timing": eng.obs.timing, "plane": eng._exec.plane,
            "roofline": eng.obs.timing, "speculative": draft_k > 0,
            "prefix_cache": prefix_pages > 0, "kv_host": preempt,
            "faults": True})
        return

    eng = ServeEngine(params, cfg, SamplerConfig(kind=args.sampler))
    reqs = [Request(e, args.max_new) for e in enc]
    try:
        served = eng.serve_batch(reqs, seed=args.seed)
    except ValueError as e:
        raise SystemExit(f"serve_batch: {e}")
    for p, r in zip(prompts, served):
        print(f"--- prompt {p!r}\ngen: {decode_bytes(np.array(r.completed))!r}")


if __name__ == "__main__":
    main()
