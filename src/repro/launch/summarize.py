"""Summarize experiments/dryrun/*.json into the EXPERIMENTS.md roofline
tables.

    PYTHONPATH=src python -m repro.launch.summarize [--mesh pod16x16]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[3]
DRY = ROOT / "experiments" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str):
    rows = []
    for fn in sorted(DRY.glob(f"*__{mesh}.json")):
        rows.append(json.loads(fn.read_text()))
    return rows


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(mesh: str, full: bool = False) -> str:
    rows = load(mesh)
    by = {(r["arch"], r["shape"]): r for r in rows}
    archs = sorted({r["arch"] for r in rows})
    out = ["| arch | shape | status | compute | memory | collective | "
           "bottleneck | useful_flops | peak_mem/chip |",
           "|---|---|---|---|---|---|---|---|---|"]
    for a in archs:
        for s in SHAPE_ORDER:
            r = by.get((a, s))
            if r is None:
                continue
            if r.get("status") == "skipped":
                out.append(f"| {a} | {s} | SKIP (see DESIGN.md §5) | - | - "
                           f"| - | - | - | - |")
                continue
            rf = r["roofline"]
            ma = r["memory_analysis"]
            mem = ma.get("peak_estimate_tpu_bytes",
                         ma["peak_estimate_bytes"])
            star = "*" if "peak_estimate_tpu_bytes" in ma else ""
            out.append(
                f"| {a} | {s} | ok | {fmt_s(rf['t_compute_s'])} | "
                f"{fmt_s(rf['t_memory_s'])} | {fmt_s(rf['t_collective_s'])} | "
                f"{rf['bottleneck']} | {r['useful_flops_ratio']:.3f} | "
                f"{mem/1e9:.2f}GB{star} |")
    return "\n".join(out)


def bottleneck_stats(mesh: str):
    rows = [r for r in load(mesh) if r.get("status") == "ok"]
    from collections import Counter
    c = Counter(r["roofline"]["bottleneck"] for r in rows)
    worst = sorted(rows, key=lambda r: -max(
        r["roofline"]["t_compute_s"], r["roofline"]["t_memory_s"],
        r["roofline"]["t_collective_s"]))
    coll = sorted(rows, key=lambda r: -(r["roofline"]["t_collective_s"]
                                        / max(1e-12, r["roofline"]["t_compute_s"]
                                              + r["roofline"]["t_memory_s"])))
    return c, worst[:5], coll[:5]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16",
                    choices=["pod16x16", "pod2x16x16", "both"])
    args = ap.parse_args()
    meshes = ["pod16x16", "pod2x16x16"] if args.mesh == "both" \
        else [args.mesh]
    for m in meshes:
        print(f"\n### mesh {m}\n")
        print(table(m))
        c, worst, coll = bottleneck_stats(m)
        print(f"\nbottleneck counts: {dict(c)}")
        print("worst absolute step time:",
              [(r['arch'], r['shape']) for r in worst])
        print("most collective-bound:",
              [(r['arch'], r['shape']) for r in coll])


if __name__ == "__main__":
    main()
