"""Roofline analysis from compiled (post-SPMD) HLO.

``compiled.cost_analysis()`` on this backend reports **per-device** numbers
and counts ``while`` (scan) bodies **once** (verified empirically), so the
layer-stack scan would be undercounted ~n_periods-fold.  This module
therefore parses ``compiled.as_text()`` itself:

* builds a shape table from every instruction definition line;
* walks the call graph from ENTRY, assigning each computation a *trip
  multiplier* (while bodies/conditions multiply by the loop trip count,
  recovered from the integer ``constant(N)`` in the condition computation);
* FLOPs: ``dot``/``convolution`` instructions -> 2 * prod(out) *
  prod(lhs contracting dims), scaled by the multiplier;
* bytes: per instruction at non-fusion level, operands + outputs (the same
  convention as XLA's own "bytes accessed"), scaled;
* collective bytes: per collective op, ring-model effective bytes moved
  per chip — all-gather/reduce-scatter: out*(g-1)/g, all-reduce: 2x that,
  all-to-all / collective-permute: size as-is — scaled by the multiplier.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (brief-provided).

The three roofline terms (seconds, per chip):
    compute    = flops / PEAK_FLOPS
    memory     = hbm_bytes / HBM_BW
    collective = collective_bytes / ICI_BW
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9       # bytes/s / chip
ICI_BW = 50e9        # bytes/s / link

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
# a computation header is any line ending with "{" that declares
# "(params) -> type"; params may contain nested tuple parens, so just grab
# the leading name token
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|then_branch|else_branch)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_RG_ARRAY_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_RG_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as one dict across jax versions
    (jax < 0.5 returns a per-program list of dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _parse_shape(typestr: str) -> float:
    """Total bytes of a (possibly tuple) type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _parse_dims(typestr: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(typestr)
    if not m:
        return "", []
    dt, dims = m.groups()
    if not dims:
        return dt, []
    return dt, [int(d) for d in dims.split(",")]


@dataclass
class Instruction:
    name: str
    opcode: str
    typestr: str
    line: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    is_fusion_body: bool = False
    root_opcode: str = ""


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    fusion_bodies = set()
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if s.endswith("{") and ") -> " in s and "=" not in s.split("(")[0]:
            hdr = _COMP_NAME_RE.match(s)
            if hdr:
                cur = Computation(hdr.group(1))
                comps[cur.name] = cur
                if s.startswith("ENTRY"):
                    entry = cur.name
                continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        d = _DEF_RE.match(s)
        if not d:
            continue
        name, rhs = d.groups()
        # rhs: "type opcode(...)" — opcode is the token before first '('
        m = re.match(r"(.+?)\s+([\w\-]+)\(", rhs)
        if not m:
            continue
        typestr, opcode = m.groups()
        inst = Instruction(name, opcode, typestr, s)
        cur.instructions.append(inst)
        if s.startswith("ROOT"):
            cur.root_opcode = opcode
        if opcode == "fusion":
            for cm in _CALLS_RE.findall(s):
                fusion_bodies.add(cm)
    for fb in fusion_bodies:
        if fb in comps:
            comps[fb].is_fusion_body = True
    return comps, entry


def _trip_count(cond: Computation, default: int) -> int:
    consts = []
    for inst in cond.instructions:
        consts += [int(c) for c in _CONST_RE.findall(inst.line)]
    # the loop bound is the largest integer constant in the condition
    return max(consts) if consts else default


def _multipliers(comps: Dict[str, Computation], entry: str,
                 default_trips: int) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for inst in comp.instructions:
            callees: List[Tuple[str, float]] = []
            w = _WHILE_RE.search(inst.line)
            if w:
                cond, body = w.groups()
                trips = _trip_count(comps.get(cond, Computation(cond)),
                                    default_trips)
                callees += [(cond, m * (trips + 1)), (body, m * trips)]
            else:
                for cm in _CALLS_RE.findall(inst.line):
                    callees.append((cm, m))
                br = _BRANCHES_RE.search(inst.line)
                if br:
                    for cm in br.group(1).split(","):
                        callees.append((cm.strip().lstrip("%"), m))
            for cn, cm in callees:
                mult[cn] = mult.get(cn, 0.0) + cm
                if cn not in seen:
                    seen.add(cn)
                    order.append(cn)
    return dict(mult)


def _group_size(line: str, n_devices: int) -> int:
    m = _RG_ARRAY_RE.search(line)
    if m:
        return int(m.group(2))
    m = _RG_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    return n_devices


def _dot_flops(inst: Instruction, shapes: Dict[str, Tuple[str, List[int]]]
               ) -> float:
    out_dt, out_dims = _parse_dims(inst.typestr)
    out_n = math.prod(out_dims) if out_dims else 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
    operands = _OPERAND_RE.findall(
        inst.line[inst.line.index("("):].split(")")[0])
    contract = 1
    if mc and operands:
        lhs = shapes.get(operands[0])
        if lhs:
            _, ldims = lhs
            for d in mc.group(1).split(","):
                if d != "" and int(d) < len(ldims):
                    contract *= ldims[int(d)]
    return 2.0 * out_n * contract


@dataclass
class RooflineReport:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_type: Dict[str, float] = field(default_factory=dict)
    coll_count: int = 0
    unscaled_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "collective_by_type": self.coll_by_type,
            "collective_op_count": self.coll_count,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


# HBM-traffic model: count operand+output bytes only for ops that would
# stay memory-moving after TPU fusion (matmuls, fusions at their
# boundaries, data movement, collectives).  Bare elementwise ops appearing
# at top level in the CPU-backend HLO would fuse on TPU and are skipped —
# otherwise the memory term inflates ~100x with phantom traffic.
COUNT_BYTE_OPS = {"dot", "convolution", "fusion", "custom-call", "copy",
                  "dynamic-slice", "dynamic-update-slice", "gather",
                  "scatter", "reduce", "sort", "select-and-scatter",
                  "concatenate", "pad", "transpose",
                  "all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute"}


def analyze(hlo_text: str, n_devices: int, default_trips: int = 1
            ) -> RooflineReport:
    comps, entry = parse_hlo(hlo_text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = _multipliers(comps, entry, default_trips)

    shapes: Dict[str, Tuple[str, List[int]]] = {}
    for comp in comps.values():
        for inst in comp.instructions:
            shapes[inst.name] = _parse_dims(inst.typestr)

    rep = RooflineReport()
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0.0:
            continue
        for inst in comp.instructions:
            if inst.opcode in ("dot", "convolution"):
                f = _dot_flops(inst, shapes)
                rep.flops += m * f
                rep.unscaled_flops += f
            if inst.opcode in COLLECTIVES or any(
                    inst.opcode.startswith(c) for c in COLLECTIVES):
                ckind = next(c for c in COLLECTIVES
                             if inst.opcode.startswith(c))
                out_bytes = _parse_shape(inst.typestr)
                g = _group_size(inst.line, n_devices)
                ring = (g - 1) / max(g, 1)
                eff = out_bytes * ring
                if ckind == "all-reduce":
                    eff *= 2.0
                elif ckind == "collective-permute":
                    eff = out_bytes
                rep.coll_bytes += m * eff
                rep.coll_by_type[ckind] = rep.coll_by_type.get(ckind, 0.0) \
                    + m * eff
                rep.coll_count += 1
            if not comp.is_fusion_body and inst.opcode in COUNT_BYTE_OPS:
                ops = inst.line[inst.line.index("("):] if "(" in inst.line else ""
                operands = _OPERAND_RE.findall(ops.split("),")[0])

                def _op_bytes(op_name):
                    if op_name in shapes:
                        dt, dims = shapes[op_name]
                        if dt in DTYPE_BYTES:
                            return (math.prod(dims) if dims else 1) \
                                * DTYPE_BYTES[dt]
                    return 0.0

                fusion_root = ""
                if inst.opcode == "fusion":
                    for cm in _CALLS_RE.findall(inst.line):
                        fusion_root = comps[cm].root_opcode if cm in comps \
                            else ""
                        break
                if inst.opcode == "dynamic-update-slice" \
                        or fusion_root == "dynamic-update-slice" \
                        or "dynamic-update-slice" in inst.name:
                    # in-place on TPU (buffer aliased): traffic = read+write
                    # of the update, not the whole buffer — approximate as
                    # 2x the non-largest operands
                    sizes = sorted((_op_bytes(o) for o in operands),
                                   reverse=True)
                    b = 2.0 * sum(sizes[1:]) if len(sizes) > 1 \
                        else _parse_shape(inst.typestr)
                elif inst.opcode == "dynamic-slice" \
                        or fusion_root == "dynamic-slice" \
                        or ("dynamic-slice" in inst.name
                            and "update" not in inst.name):
                    b = 2.0 * _parse_shape(inst.typestr)
                else:
                    b = _parse_shape(inst.typestr)
                    for op_name in operands:
                        b += _op_bytes(op_name)
                rep.hbm_bytes += m * b
    return rep


def model_flops(cfg, n_tokens: int, kind: str = "train") -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); bwd counted for train only."""
    from repro.models.transformer import count_params_analytic

    n = count_params_analytic(cfg)
    n -= cfg.vocab_size * cfg.d_model  # embeddings are lookups
    if cfg.moe is not None:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = (cfg.moe_layer_count * (cfg.moe.num_experts - cfg.moe.top_k)
                    * per_expert)
        n -= inactive
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens
