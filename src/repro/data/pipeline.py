"""Deterministic byte-level data pipeline.

No external datasets are available offline, so the corpus is built from
local text files (default: the Python standard library sources — real,
richly structured text).  Byte-level tokenization with a few specials.
Everything is seeded and order-deterministic so experiments reproduce.
"""
from __future__ import annotations

import hashlib
import os
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, List, Optional

import numpy as np

PAD, BOS, EOS = 256, 257, 258
N_SPECIALS = 3
VOCAB_BYTES = 256 + N_SPECIALS  # 259; model vocabs round up (e.g. 512)

_DEFAULT_DIRS = [
    os.path.dirname(os.__file__),  # python stdlib
]


def build_corpus(dirs: Optional[List[str]] = None, max_bytes: int = 8_000_000,
                 ext: str = ".py") -> np.ndarray:
    """Concatenated byte corpus with EOS between documents (deterministic
    file order by path hash)."""
    dirs = dirs or _DEFAULT_DIRS
    files: List[Path] = []
    for d in dirs:
        files.extend(p for p in sorted(Path(d).rglob(f"*{ext}"))
                     if p.is_file())
    files.sort(key=lambda p: hashlib.md5(str(p).encode()).hexdigest())
    chunks = []
    total = 0
    for p in files:
        try:
            raw = p.read_bytes()
        except OSError:
            continue
        arr = np.frombuffer(raw, dtype=np.uint8).astype(np.int32)
        chunks.append(np.concatenate([arr, [EOS]]))
        total += arr.size + 1
        if total >= max_bytes:
            break
    corpus = np.concatenate(chunks)[:max_bytes]
    return corpus


@dataclass
class DataConfig:
    seq_len: int = 256
    batch_size: int = 8
    max_bytes: int = 8_000_000
    seed: int = 0
    split_holdout: float = 0.05


class PackedDataset:
    """Packs the corpus into fixed-length sequences; iterates shuffled
    batches of (tokens, labels) with next-byte labels."""

    def __init__(self, cfg: DataConfig, corpus: Optional[np.ndarray] = None):
        self.cfg = cfg
        corpus = corpus if corpus is not None else build_corpus(
            max_bytes=cfg.max_bytes)
        n_hold = int(len(corpus) * cfg.split_holdout)
        self.train_bytes = corpus[:-n_hold] if n_hold else corpus
        self.eval_bytes = corpus[-n_hold:] if n_hold else corpus[-1024:]

    def _sequences(self, data: np.ndarray) -> np.ndarray:
        L = self.cfg.seq_len + 1
        n = len(data) // L
        return data[: n * L].reshape(n, L)

    def batches(self, split: str = "train", epochs: int = 1000
                ) -> Iterator[dict]:
        data = self.train_bytes if split == "train" else self.eval_bytes
        seqs = self._sequences(data)
        rng = np.random.default_rng(self.cfg.seed)
        B = self.cfg.batch_size
        for _ in range(epochs):
            order = rng.permutation(len(seqs))
            for i in range(0, len(order) - B + 1, B):
                chunk = seqs[order[i: i + B]]
                yield {"tokens": chunk[:, :-1].astype(np.int32),
                       "labels": chunk[:, 1:].astype(np.int32)}

    def eval_batches(self, max_batches: int = 8) -> Iterator[dict]:
        seqs = self._sequences(self.eval_bytes)
        B = self.cfg.batch_size
        for i in range(0, min(len(seqs), max_batches * B) - B + 1, B):
            chunk = seqs[i: i + B]
            yield {"tokens": chunk[:, :-1].astype(np.int32),
                   "labels": chunk[:, 1:].astype(np.int32)}


def decode_bytes(tokens: np.ndarray) -> str:
    return bytes(int(t) for t in tokens if t < 256).decode("utf-8", "replace")


def encode_text(s: str) -> np.ndarray:
    return np.frombuffer(s.encode(), dtype=np.uint8).astype(np.int32)
