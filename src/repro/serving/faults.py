"""Deterministic fault-injection plane (DESIGN.md §14).

The paper's target hardware is flaky by construction — free-tier Colab
GPUs, desktop cards behind a PCIe bus that stalls, clients that vanish
mid-decode.  This module is the seeded chaos source the serving stack is
hardened against: a :class:`FaultInjector` holds a schedule of
:class:`FaultSpec` entries keyed by *site* name, and the engine /
executor / KV manager ask ``fires(site)`` at each natural failure
boundary.  Everything is host-side: jit programs never see the injector,
so a faulty run's device computation is the SAME program as a fault-free
run — which is what makes the bitwise-survivor acceptance criterion
checkable at all.

Sites (each named for the subsystem boundary it perturbs):

``expert_fetch``
    A transient h2d expert fetch failure at the expert-pool acquire
    boundary (``core.expert_pool.FAULT_SITE``).  The executor retries
    with optional backoff; exhausted retries degrade that layer to
    store-direct streaming (``moe_apply_packed_stream``) and drop
    speculative prefetch for the step.
``swap_out`` / ``swap_in``
    Preemption d2h staging fails (victim's KV is dropped, resume
    recomputes) / resume h2d fails (blob is discarded, resume falls
    back to recompute).  Both land on paths PR 9 already proved
    bitwise-safe.
``page_pool``
    Admission-time pool exhaustion: ``can_admit`` reports no headroom
    even though pages are free; the admission simply retries next step.
``nan_logits``
    Poisons one decode row's logits with NaN before sampling — the
    quarantine path must fail only that row.
``slow_step``
    A wall-clock stall (``stall_ms``) at step start — exercises
    wall-clock deadlines without touching token streams.

Determinism: each site draws from its own ``np.random.default_rng([seed,
site_index])`` stream, and rate draws advance one draw per *opportunity*
(every ``fires`` call), so two runs with the same schedule, seed and
workload fire identically — and a site's stream is unaffected by how
often other sites are consulted.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["SITES", "FaultSpec", "FaultInjector"]

# canonical site order — index doubles as the per-site rng stream key
SITES = ("expert_fetch", "swap_out", "swap_in", "page_pool",
         "nan_logits", "slow_step")


@dataclass(frozen=True)
class FaultSpec:
    """One site's schedule.

    ``rate``     Bernoulli fire probability per opportunity.
    ``at``       explicit opportunity ordinals (0-based) that fire
                 regardless of ``rate`` — the deterministic "fail the
                 3rd fetch" form the tests lean on.
    ``max_fires`` cap on total fires (None = unlimited); ``at`` entries
                 count toward it.
    ``start``    opportunities before this ordinal never rate-fire
                 (``at`` still applies).
    ``stall_ms`` for ``slow_step``: how long the stall sleeps.
    """
    site: str
    rate: float = 0.0
    at: Tuple[int, ...] = ()
    max_fires: Optional[int] = None
    start: int = 0
    stall_ms: float = 0.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites: {', '.join(SITES)}")
        if not (0.0 <= self.rate <= 1.0):
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        object.__setattr__(self, "at", tuple(int(i) for i in self.at))


class FaultInjector:
    """Seeded, schedule-driven fault source.

    ``fires(site)`` is the single hot-path entry point: it counts one
    opportunity at ``site``, consults that site's schedule, and returns
    whether the fault fires.  Sites without a schedule entry never fire
    (and never draw), so an injector with an empty schedule is inert.
    """

    def __init__(self, schedule: Sequence[FaultSpec] = (), seed: int = 0):
        self.seed = int(seed)
        self.schedule: Dict[str, FaultSpec] = {}
        for spec in schedule:
            if spec.site in self.schedule:
                raise ValueError(f"duplicate schedule entry for site "
                                 f"{spec.site!r}")
            self.schedule[spec.site] = spec
        self._rng = {s: np.random.default_rng([self.seed, i])
                     for i, s in enumerate(SITES)}
        self.opportunities = {s: 0 for s in SITES}
        self.fired = {s: 0 for s in SITES}

    # -- hot path ------------------------------------------------------
    def fires(self, site: str) -> bool:
        """One opportunity at ``site`` -> did the fault fire?"""
        n = self.opportunities[site]          # KeyError = typo'd site
        self.opportunities[site] = n + 1
        spec = self.schedule.get(site)
        if spec is None:
            return False
        if spec.max_fires is not None and self.fired[site] >= spec.max_fires:
            return False
        hit = n in spec.at
        if not hit and spec.rate > 0.0 and n >= spec.start:
            # one draw per rate-eligible opportunity keeps the stream
            # aligned across runs regardless of ``at`` hits
            hit = bool(self._rng[site].random() < spec.rate)
        if hit:
            self.fired[site] += 1
        return hit

    def stall_ms(self, site: str = "slow_step") -> float:
        spec = self.schedule.get(site)
        return spec.stall_ms if spec is not None else 0.0

    # -- accounting ----------------------------------------------------
    @property
    def total_fired(self) -> int:
        return sum(self.fired.values())

    def stats(self) -> Dict[str, int]:
        out: Dict[str, int] = {"injected": self.total_fired}
        for s in SITES:
            out[f"fired_{s}"] = self.fired[s]
        return out

    # -- CLI grammar ---------------------------------------------------
    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultInjector":
        """``--inject-faults`` grammar: comma-separated site specs,
        each ``site[@i][@j]...[=rate][:stall_ms]``.

        Examples::

            expert_fetch=0.05           5% of fetches fail (transient)
            nan_logits@2                poison the 3rd decode sample pass
            swap_out@0,swap_in=1.0      first d2h fails; every h2d fails
            slow_step@5:25              25ms stall at step 5
        """
        specs = []
        for part in filter(None, (p.strip() for p in text.split(","))):
            stall = 0.0
            if ":" in part:
                part, ms = part.rsplit(":", 1)
                stall = float(ms)
            rate = 0.0
            if "=" in part:
                part, r = part.split("=", 1)
                rate = float(r)
            fields = part.split("@")
            site, at = fields[0].strip(), tuple(int(i) for i in fields[1:])
            specs.append(FaultSpec(site=site, rate=rate, at=at,
                                   stall_ms=stall))
        return cls(specs, seed=seed)
