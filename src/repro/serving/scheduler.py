"""Continuous-batching scheduler: admission queue + expert-aware policy.

Requests are submitted at any time; the engine asks the scheduler for the
next request whenever a KV slot frees up.  Which waiting request joins is
a *policy* decision:

* :func:`fcfs_policy` — arrival order (the throughput-neutral default);
* :class:`ExpertOverlapPolicy` — MoE-offload-aware: scores each waiting
  request by the predicted overlap between the experts it is about to
  route to and the experts the in-flight batch is already keeping hot
  (``core/offload_engine.ExpertUsageTracker``).  Predictions reuse the
  paper's speculative gate trick (``core/speculative.predict_experts``):
  apply each MoE layer's router to the request's last prompt-token
  embedding — the same "an early hidden state is a decent estimate"
  argument, pushed back to layer 0.  Grouping co-routed requests
  amortises expert-load cost on memory-constrained hardware (MoBiLE).

The scheduler never touches model state; slot bookkeeping lives in
``serving/kv_manager`` and the decode loop in ``serving/engine``.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import speculative
from repro.core.offload_engine import ExpertUsageTracker
from repro.core.trace import stacked_routers

_rid_counter = itertools.count()

WAITING, RUNNING, FINISHED = "waiting", "running", "finished"
PREEMPTED = "preempted"  # swapped out / dropped mid-decode (DESIGN.md §13)

# finish_reason -> terminal status (DESIGN.md §14).  Reasons not in the
# map (today only "nan", the quarantine path) are failures: a request
# that ended for a reason the map does not bless did not complete.
TERMINAL_STATUS = {"length": "completed", "eos": "completed",
                   "cancelled": "cancelled", "deadline": "deadline_exceeded",
                   "rejected": "rejected"}


@dataclass(eq=False)  # identity equality: the prompt array is unhashable
class GenRequest:
    """One generation request's full lifecycle record."""

    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    rid: int = field(default_factory=lambda: next(_rid_counter))
    arrival: int = 0  # engine step at which the request became visible
    on_token: Optional[Callable[["GenRequest", int], None]] = None
    on_finish: Optional[Callable[["GenRequest"], None]] = None
    state: str = WAITING
    slot: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    finish_reason: Optional[str] = None  # a TERMINAL_STATUS key, or "nan"
    # request-lifecycle hardening (DESIGN.md §14): wall-clock budget in
    # ms (engine stamps submit_ns at submission) and/or a deterministic
    # engine-step budget — whichever expires first wins
    deadline_ms: Optional[float] = None
    deadline_steps: Optional[int] = None
    submit_ns: Optional[int] = None
    # per-request sampling temperature (None = the engine sampler's
    # default); applied row-wise by serving/sampler.sample
    temperature: Optional[float] = None
    # preemption rank (DESIGN.md §13): higher wins.  Victims are chosen
    # lowest-priority-first (latest arrival breaks ties), and a waiting
    # request only preempts strictly lower-priority running ones
    priority: int = 0
    # non-token conditioning consumed at admission (never per step):
    # enc-dec archs require extras["audio_embeds"] (S_e, D) — encoded
    # once into the read-only shared encoder-KV plane (DESIGN.md §12)
    extras: Optional[dict] = None
    # filled lazily by ExpertOverlapPolicy (per-layer predicted expert ids)
    _pred_experts: Optional[List[np.ndarray]] = None

    def emit(self, tok: int) -> None:
        self.generated.append(tok)
        if self.on_token is not None:
            self.on_token(self, tok)

    def finish(self, reason: str) -> None:
        self.state = FINISHED
        self.finish_reason = reason
        if self.on_finish is not None:
            self.on_finish(self)

    @property
    def status(self) -> Optional[str]:
        """Terminal status (DESIGN.md §14), None while in flight."""
        if self.state != FINISHED:
            return None
        return TERMINAL_STATUS.get(self.finish_reason, "failed")


# ----------------------------------------------------------------------
# Per-arch admission cost (DESIGN.md §12).  Admitting a request claims
# sequence state on three distinct planes, and each plane bills
# differently:
#
#   kv_positions    growing per-position K/V — the ONLY plane a PagePool
#                   reserves for.  swa layers are clamped to their window
#                   (the ring never holds more), and a pure-recurrent
#                   stack needs ZERO positions no matter how long the
#                   request runs;
#   rec_state_bytes fixed-size recurrent carries (rglru/mlstm/slstm) —
#                   flat in both prompt_len and max_new_tokens, paid once
#                   per slot (the degenerate one-page-per-slot case);
#   enc_kv_bytes    read-only shared encoder KV, computed once at
#                   admission from extras["audio_embeds"] and only ever
#                   read afterwards — flat in decode length.
@dataclass(frozen=True)
class AdmissionCost:
    """State footprint one request claims at admission, by plane."""

    kv_positions: int      # growing-KV positions the engine must reserve
    kv_positions_windowed: int  # same, with swa layers clamped to window
    rec_state_bytes: int   # fixed recurrent state (flat in context)
    enc_kv_bytes: int      # shared read-only encoder KV (flat in decode)


def admission_cost(cfg: ModelConfig, prompt_len: int,
                   max_new_tokens: int) -> AdmissionCost:
    """What admitting one request costs, per state plane (DESIGN.md §12).

    The engine keys page reservation off ``kv_positions`` (zero for
    pure-recurrent stacks — that is what lets xlstm admit without a
    PagePool grant) and the cost model keys decode arithmetic off the
    flat ``rec_state_bytes`` / ``enc_kv_bytes`` terms.
    """
    from repro.core.cost_model import recurrent_state_bytes

    need = prompt_len + max_new_tokens
    kv_pos = 0
    kv_pos_win = 0
    for sp in cfg.state_planes():
        if sp.plane == "kv":
            kv_pos = max(kv_pos, need)
            kv_pos_win = max(kv_pos_win,
                             min(need, sp.window) if sp.window else need)
    rec_bytes = recurrent_state_bytes(cfg)
    enc_bytes = 0
    if cfg.is_encoder_decoder:
        enc_bytes = (2 * cfg.n_layers * cfg.encoder_seq * cfg.n_kv_heads
                     * cfg.head_dim * jnp.dtype(cfg.dtype).itemsize)
    return AdmissionCost(kv_positions=kv_pos,
                         kv_positions_windowed=kv_pos_win,
                         rec_state_bytes=rec_bytes,
                         enc_kv_bytes=enc_bytes)


# ----------------------------------------------------------------------
# Admission policies: (waiting, usage) -> index into waiting
def fcfs_policy(waiting: Sequence[GenRequest],
                usage: Optional[ExpertUsageTracker]) -> int:
    return 0


class ExpertOverlapPolicy:
    """Pick the waiting request whose predicted experts overlap most with
    the in-flight batch's hot experts; FCFS tie-break keeps it fair."""

    needs_usage = True  # makes the engine collect per-step routing info

    def __init__(self, params, cfg: ModelConfig, n_spec: int = 2):
        assert cfg.moe is not None, "expert-overlap policy needs an MoE arch"
        self.cfg = cfg
        self.n_spec = min(n_spec, cfg.moe.num_experts)
        self.routers = stacked_routers(params, cfg)  # (L_moe, D, E)
        self.embed = np.asarray(params["embed"]["table"])

    def _predict(self, req: GenRequest) -> List[np.ndarray]:
        if req._pred_experts is None:
            h = jnp.asarray(self.embed[int(req.prompt[-1])])[None]  # (1, D)
            req._pred_experts = [
                np.asarray(speculative.predict_experts(
                    jnp.asarray(self.routers[l]), h, self.n_spec)[0])
                for l in range(self.routers.shape[0])]
        return req._pred_experts

    def __call__(self, waiting: Sequence[GenRequest],
                 usage: Optional[ExpertUsageTracker]) -> int:
        if usage is None or len(waiting) == 1:
            return 0
        scores = [usage.overlap(self._predict(r)) for r in waiting]
        return int(np.argmax(scores))  # argmax takes first on ties = FCFS


# ----------------------------------------------------------------------
class Scheduler:
    """Admission queue with pluggable policy and invariant accounting."""

    def __init__(self, max_slots: int,
                 policy: Optional[Callable] = None,
                 queue_cap: Optional[int] = None):
        self.max_slots = max_slots
        self.policy = policy or fcfs_policy
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1 (got {queue_cap}); "
                             f"None means unbounded")
        self.queue_cap = queue_cap
        self.waiting: List[GenRequest] = []
        self.running: List[GenRequest] = []
        self.finished: List[GenRequest] = []
        self.joins = 0
        self.evictions = 0
        self.preemptions = 0
        self.resumes = 0
        self.queue_rejected = 0

    def submit(self, req: GenRequest) -> bool:
        """Enqueue ``req``; False = bounded queue is full (backpressure —
        the request was NOT retained, the caller owns the rejection)."""
        assert req.state == WAITING
        if self.queue_cap is not None and len(self.waiting) >= self.queue_cap:
            self.queue_rejected += 1
            return False
        self.waiting.append(req)
        return True

    @property
    def has_waiting(self) -> bool:
        return bool(self.waiting)

    @property
    def n_running(self) -> int:
        return len(self.running)

    def peek_next(self, usage: Optional[ExpertUsageTracker] = None
                  ) -> "tuple[int, GenRequest]":
        """Policy-selected waiting request WITHOUT admitting it — the
        paged engine inspects the pick's KV need before committing a
        slot.  The policy runs exactly once per admission: the caller
        passes the returned index to :meth:`pop_at` (re-invoking the
        policy could pick differently under randomized tie-breaking)."""
        assert self.waiting and len(self.running) < self.max_slots
        idx = self.policy(self.waiting, usage)
        return idx, self.waiting[idx]

    def pop_at(self, idx: int) -> GenRequest:
        """Admit the waiting request at ``idx`` (from :meth:`peek_next`)."""
        req = self.waiting.pop(idx)
        req.state = RUNNING
        self.running.append(req)
        self.joins += 1
        return req

    def pop_next(self, usage: Optional[ExpertUsageTracker] = None
                 ) -> GenRequest:
        """Policy-selected waiting request, moved to running."""
        idx, _ = self.peek_next(usage)
        return self.pop_at(idx)

    def evict(self, req: GenRequest, reason: str) -> None:
        self.running.remove(req)
        req.finish(reason)
        self.finished.append(req)
        self.evictions += 1

    def drop(self, req: GenRequest, reason: str) -> None:
        """Terminal exit for a request NOT in running (cancellation /
        deadline, DESIGN.md §14): waiting requests are dequeued; a
        preempted one just finishes (the engine owns its swap record).
        Either way the request lands in ``finished`` — the one census
        the terminal-status counters scan."""
        if req.state == WAITING:
            self.waiting.remove(req)
        else:
            assert req.state == PREEMPTED, \
                f"drop() takes waiting/preempted requests, not {req.state}"
        req.finish(reason)
        self.finished.append(req)

    def preempt(self, req: GenRequest) -> None:
        """Pull a running request off the batch mid-decode (its KV has
        been swapped to host or dropped for recompute); it re-enters via
        :meth:`resume` when the engine re-admits it (DESIGN.md §13)."""
        assert req.state == RUNNING
        self.running.remove(req)
        req.state = PREEMPTED
        self.preemptions += 1

    def resume(self, req: GenRequest) -> None:
        assert req.state == PREEMPTED
        assert len(self.running) < self.max_slots
        req.state = RUNNING
        self.running.append(req)
        self.resumes += 1

    def metrics(self) -> dict:
        """Queue/lifecycle counts for the telemetry ``engine`` namespace
        (the engine merges in its step/token counters)."""
        return {"joins": self.joins, "evictions": self.evictions,
                "finished": len(self.finished),
                "waiting": len(self.waiting),
                "running": len(self.running),
                "queue_rejected": self.queue_rejected}

    def check_invariants(self) -> None:
        assert len(self.running) <= self.max_slots
        slots = [r.slot for r in self.running]
        assert len(slots) == len(set(slots)), "duplicate slot assignment"
        assert all(r.state == RUNNING for r in self.running)
        assert all(r.state == WAITING for r in self.waiting)
        assert all(r.state == FINISHED for r in self.finished)
