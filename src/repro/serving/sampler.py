"""Token samplers (the paper samples proportionally to the predicted
probabilities — plain categorical; greedy, top-k and nucleus/top-p
provided too).  This is the ONE sampling surface every engine routes
through (``ServeEngine``, ``ContinuousEngine``, ``OffloadEngine`` — no
engine keeps a private greedy/rng branch), with per-request temperature
supported as a (B,) override for mixed continuous batches."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplerConfig:
    kind: str = "categorical"  # greedy | categorical | topk | topp
    temperature: float = 1.0
    top_k: int = 40
    top_p: float = 0.9  # nucleus mass (kind="topp")


def _top_p_filter(logits, top_p: float):
    """Nucleus filtering: keep the smallest prefix of the
    probability-sorted vocab whose cumulative mass reaches ``top_p``
    (the most-likely token always survives)."""
    order = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep_sorted = (cum - probs) < top_p  # mass BEFORE this token < p
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, NEG_INF)


def sample(rng, logits, cfg: SamplerConfig, temperature=None):
    """logits: (B, V) -> tokens (B,) int32.

    ``temperature`` overrides ``cfg.temperature`` — a scalar, or a (B,)
    array for per-request temperatures in a continuous batch (each row
    divides by its own value before filtering)."""
    if cfg.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = cfg.temperature if temperature is None else temperature
    t = jnp.asarray(t, jnp.float32)
    if t.ndim == 1:
        t = t[:, None]
    logits = logits / jnp.maximum(t, 1e-6)
    if cfg.kind == "topk":
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        thresh = vals[..., -1:]
        logits = jnp.where(logits < thresh, NEG_INF, logits)
    elif cfg.kind == "topp":
        logits = _top_p_filter(logits, cfg.top_p)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
