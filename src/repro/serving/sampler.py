"""Token samplers (the paper samples proportionally to the predicted
probabilities — plain categorical; greedy and top-k provided too)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    kind: str = "categorical"  # greedy | categorical | topk
    temperature: float = 1.0
    top_k: int = 40


def sample(rng, logits, cfg: SamplerConfig):
    """logits: (B, V) -> tokens (B,) int32."""
    if cfg.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / jnp.maximum(cfg.temperature, 1e-6)
    if cfg.kind == "topk":
        vals, _ = jax.lax.top_k(logits, cfg.top_k)
        thresh = vals[..., -1:]
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
