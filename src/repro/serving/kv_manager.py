"""Slotted KV-cache manager for continuous batching.

One preallocated decode state (``models/transformer.init_decode_state``
layout) holds ``n_slots`` independent sequences; the batch axis is the
slot table.  Requests of different lengths join and leave a *running*
batch by writing a freshly prefilled B=1 state into a free slot
(block-table indirection at slot granularity — every slot owns a
fixed-width ring of ``slot_len`` KV positions) and releasing it when the
request finishes.  Nothing else in the batch is touched: per-row ``pos``
(see ``decode_step``) keeps every slot at its own absolute position, and
ring slots carrying pos = −1 are invisible to attention, so a freed slot
needs no scrubbing before reuse — the next prefill overwrites every leaf
of that row.

The manager is deliberately model-agnostic: it treats the decode state as
an opaque pytree and only assumes the seed layout's axis convention
(``stack`` leaves carry batch at axis 1 under the scan axis, ``tail``
leaves at axis 0, ``pos`` is per-row).

:class:`PagedKVManager` is the block-paged alternative (DESIGN.md §9):
KV lives in a shared pool of fixed-size pages per layer and a slot owns
an ordered page list instead of a fixed-width ring, so short requests
stop reserving ``slot_len`` of KV and decode attention is sliced to the
*live* page horizon every step.  :class:`PagePool` is the host-side
allocator (heap free list + admission reservations) whose invariants
are property-tested in ``tests/test_paged_kv.py``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def _write_slot(big, small, i):
    """Scatter a B=1 decode state into row ``i`` of the slotted state.

    Plane-agnostic (DESIGN.md §12): ``stack`` leaves — ring KV *and*
    fixed-size recurrent state alike — carry batch at axis 1 under the
    scan axis, ``tail`` leaves at axis 0, so one tree-map covers every
    layer kind.  The shared encoder-KV plane (enc-dec decoders) carries
    batch at axis 1 under the layer axis; its ``pos`` is batch-free
    (every row's encoder output spans the same positions) and is never
    written."""
    out = dict(big)
    out["stack"] = [jax.tree.map(lambda b, s: b.at[:, i].set(s[:, 0]), bs, ss)
                    for bs, ss in zip(big["stack"], small["stack"])]
    out["tail"] = [jax.tree.map(lambda b, s: b.at[i].set(s[0]), bt, st)
                   for bt, st in zip(big["tail"], small["tail"])]
    # small pos is a scalar (unpadded prefill) or (1,) (padded prefill)
    out["pos"] = big["pos"].at[i].set(
        jnp.reshape(jnp.asarray(small["pos"]), (-1,))[0].astype(jnp.int32))
    if "enc_kv" in big:
        ek, sk = big["enc_kv"], small["enc_kv"]
        out["enc_kv"] = dict(ek,
                             k=ek["k"].at[:, i].set(sk["k"][:, 0]),
                             v=ek["v"].at[:, i].set(sk["v"][:, 0]))
    return out


def _read_slot(big, i):
    """Gather row ``i`` of the slotted state into a B=1 state — the exact
    inverse of :func:`_write_slot`.  This is the snapshot half of the
    recurrent speculative-rollback protocol (DESIGN.md §12): fixed-size
    state cannot be rolled back by a pos reset (the carry has already
    folded the rejected tokens in), so the engine snapshots the row
    before a verify round and restores + replays on rejection."""
    out = dict(big)
    out["stack"] = [jax.tree.map(
        lambda b: jax.lax.dynamic_slice_in_dim(b, i, 1, axis=1), bs)
        for bs in big["stack"]]
    out["tail"] = [jax.tree.map(
        lambda b: jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0), bt)
        for bt in big["tail"]]
    out["pos"] = jax.lax.dynamic_slice(big["pos"], (i,), (1,))
    if "enc_kv" in big:
        ek = big["enc_kv"]
        out["enc_kv"] = dict(
            ek,
            k=jax.lax.dynamic_slice_in_dim(ek["k"], i, 1, axis=1),
            v=jax.lax.dynamic_slice_in_dim(ek["v"], i, 1, axis=1))
    if "pages" in big:
        out["pages"] = jax.lax.dynamic_slice_in_dim(big["pages"], i, 1,
                                                    axis=0)
    return out


class KVSlotManager:
    """Free-list over the batch axis of one preallocated decode state."""

    def __init__(self, cfg: ModelConfig, n_slots: int, slot_len: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.slot_len = slot_len
        state = T.init_decode_state(cfg, n_slots, slot_len)
        state["pos"] = jnp.zeros((n_slots,), jnp.int32)  # per-row positions
        self.state = state
        # heap free list: O(log n) allocate/release, lowest slot first
        # (the order the old pop(0)/sort() list produced)
        self._free: List[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self._owner: List[Optional[object]] = [None] * n_slots
        self.peak_slots = 0
        # donate the big state: the write is a pure row update, so XLA
        # reuses the (KV-stack-sized) buffers instead of copying them
        self._write = jax.jit(_write_slot, donate_argnums=0)
        self._read = jax.jit(_read_slot)

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def owner(self, slot: int):
        return self._owner[slot]

    def allocate(self, owner=None) -> int:
        slot = heapq.heappop(self._free)
        self._owner[slot] = owner
        self.peak_slots = max(self.peak_slots, self.n_slots - self.n_free)
        return slot

    def release(self, slot: int) -> None:
        assert self._owner[slot] is not None, f"slot {slot} already free"
        self._owner[slot] = None
        heapq.heappush(self._free, slot)

    def metrics(self) -> Dict[str, object]:
        """KV occupancy counters — the telemetry ``kv`` namespace
        (``repro.obs.schema.KV_KEYS_DENSE``): the dense ring reserves
        ``slot_len`` positions per slot whether used or not —
        ``positions_reserved`` vs ``positions_live`` is exactly the waste
        the paged layout removes (DESIGN.md §9).  Pull-time only: the
        ``pos`` fetch happens per snapshot, never per step."""
        pos = np.asarray(self.state["pos"])
        live = [int(pos[s]) for s in range(self.n_slots)
                if self._owner[s] is not None]
        return {"layout": "dense",
                "slots_in_use": self.n_slots - self.n_free,
                "slots_free": self.n_free,
                "positions_reserved":
                    (self.n_slots - self.n_free) * self.slot_len,
                "peak_positions_reserved": self.peak_slots * self.slot_len,
                "positions_live": sum(live),
                "slot_lengths": live}

    def stats(self) -> Dict[str, object]:
        """Legacy flat projection of :meth:`metrics` (``kv_*`` keys)."""
        return {f"kv_{k}": v for k, v in self.metrics().items()}

    def check_invariants(self, cache_pages=()) -> None:
        """Dense-plane slice of the step-boundary audit (DESIGN.md §14):
        the free list and the owner map must partition the slots (no
        pages to account — the ring is preallocated per slot)."""
        free = sorted(self._free)
        assert len(set(free)) == len(free), \
            f"free list holds duplicates: {free}"
        owned = {s for s in range(self.n_slots)
                 if self._owner[s] is not None}
        assert not (set(free) & owned), \
            f"slots both free and owned: {sorted(set(free) & owned)}"
        assert set(free) | owned == set(range(self.n_slots)), \
            "slot free list + owner map do not cover all slots"

    # ------------------------------------------------------------------
    def new_row_state(self):
        """Fresh B=1 decode state of slot width — the accumulator for
        chunked admission (DESIGN.md §8): the runtime executor's
        ``prefill_chunk`` writes each chunk's KV into it at the chunk's
        offset (``pos .. pos+C−1`` ring slots), decode steps of the
        *other* rows proceed against the big slotted state in between,
        and after the final chunk :meth:`write_prefill` scatters the
        finished row in.  Because rows are disjoint, the deferred
        scatter cannot race the in-flight batch."""
        state = T.init_decode_state(self.cfg, 1, self.slot_len)
        return state

    def write_prefill(self, small_state, slot: int) -> None:
        """Install a prefilled B=1 state (``max_len == slot_len``) into
        ``slot``; the request's remaining KV budget is slot_len − pos."""
        # width check against the first layer that carries a ring KV
        # plane (hybrids may lead with recurrent blocks, whose fixed-size
        # state has no width to check)
        for bs, ss in zip(self.state["stack"], small_state["stack"]):
            if "kv" in ss:
                if ss["kv"]["k"].shape[2] != bs["kv"]["k"].shape[2]:
                    raise ValueError(
                        f"prefill state width {ss['kv']['k'].shape[2]} != "
                        f"slot width {bs['kv']['k'].shape[2]}; prefill "
                        f"with max_len == slot_len")
                break
        self.state = self._write(self.state, small_state, slot)

    # ------------------------------------------------------------------
    def snapshot(self, slot: int):
        """B=1 copy of the slot's full state (every plane: rings, rec,
        enc-KV row, pos) — the pre-round snapshot of the speculative
        rollback protocol for stacks with fixed-size recurrent state
        (DESIGN.md §12).  O(slot) device copy; taken only when the config
        actually has non-attention planes."""
        return self._read(self.state, slot)

    def restore(self, small_state, slot: int) -> None:
        """Write a :meth:`snapshot` (or a replayed continuation of one)
        back into ``slot`` — the restore half of speculative rollback."""
        self.state = self._write(self.state, small_state, slot)

    def remaining(self, slot: int) -> int:
        """Decode steps this slot can still take before its ring would
        overwrite live context (conservative for SWA stacks, where the
        window may be narrower than the slot)."""
        return self.slot_len - int(self.state["pos"][slot])

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Roll the slot back so exactly ``n_tokens`` positions are live
        — the speculative-decode rejection path (DESIGN.md §11).  For
        the dense ring this is a pos reset *only*: ring entries at
        positions ≥ n_tokens carry kpos > qpos for every future query,
        so the attention validity mask already hides them, and the next
        real token overwrites the same ring slot.  Valid only while the
        ring has never wrapped (bounded mode), which the speculative
        path guarantees.  Fixed-size recurrent planes CANNOT be rolled
        back this way (the carry already folded the rejected tokens) —
        the engine pairs this with :meth:`snapshot` / :meth:`restore`
        for such stacks (DESIGN.md §12)."""
        assert 0 <= n_tokens <= int(self.state["pos"][slot]), \
            f"truncate({slot}, {n_tokens}) would extend, not roll back"
        self.state = dict(
            self.state,
            pos=self.state["pos"].at[slot].set(np.int32(n_tokens)))


# ======================================================================
# Block-paged KV (DESIGN.md §9)
class PagePool:
    """Host-side page allocator: heap free list + per-slot ordered page
    lists + admission *reservations* + per-page *reference counts*.

    Pages are allocated lazily (``ensure`` covers positions as they are
    written) but admission reserves a slot's worst-case page count up
    front, so a mid-decode allocation can never fail — the conservative
    no-preemption discipline (a request that is admitted always runs to
    completion).  Under preemption (DESIGN.md §13) admission instead
    reserves only the prompt's pages and decode growth goes through
    :meth:`grow_reservation`, whose failure the engine resolves by
    swapping a victim out rather than crashing.

    Reference counts exist for prefix sharing (DESIGN.md §13): a page
    holding an immutable full page of shared prompt KV is held once by
    the prefix index and once per slot that adopted it
    (:meth:`adopt_shared`); ``release``/``trim`` only *return* a page to
    the free heap when its last reference drops, so the scrub — and any
    reuse — cannot touch KV another request is still reading.  Without
    sharing every refcount is 1 and the original semantics are
    unchanged.  Invariants (property-tested): free + referenced
    partitions the pool, a slot's table is gapless in ordinal order,
    every owned page has refs >= 1, and a page is freed exactly when its
    refcount reaches zero.
    """

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages > 0 and page_size > 0
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        self.owned: Dict[object, List[int]] = {}
        self.reserved: Dict[object, int] = {}
        self.refs: Dict[int, int] = {}  # page id -> live references
        self.peak_in_use = 0
        # peak COMMITTED pages (allocated + reserved-but-unallocated):
        # the honest memory footprint — a reserved page is unavailable
        # to other requests whether or not it has been written yet
        self.peak_committed = 0

    # ------------------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-int(n_tokens) // self.page_size))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_reserved_unallocated(self) -> int:
        return sum(max(0, r - len(self.owned.get(s, [])))
                   for s, r in self.reserved.items())

    def can_reserve(self, n_pages: int) -> bool:
        return n_pages <= self.n_free - self.n_reserved_unallocated

    def reserve(self, slot, n_tokens: int, prealloc_pages: int = 0) -> None:
        """``prealloc_pages`` is the prefix-hit credit: that many leading
        pages of the reservation will be adopted from the cache (already
        allocated, refs held elsewhere), so only the remainder must come
        out of the unreserved pool — and the committed-footprint peak
        must not double-count them (they are already in ``in_use``)."""
        need = self.pages_for(n_tokens)
        if not self.can_reserve(max(0, need - prealloc_pages)):
            raise ValueError(
                f"page pool exhausted: need {need - prealloc_pages} pages, "
                f"{self.n_free - self.n_reserved_unallocated} unreserved")
        assert slot not in self.reserved, f"slot {slot} already reserved"
        self.reserved[slot] = need
        self.owned[slot] = []
        self.peak_committed = max(
            self.peak_committed,
            self.n_pages - self.n_free + self.n_reserved_unallocated
            - prealloc_pages)

    def ensure(self, slot, n_tokens: int) -> List[int]:
        """Allocate pages so positions ``0 .. n_tokens−1`` are covered;
        returns the NEWLY allocated page ids (ordinal order)."""
        need = self.pages_for(n_tokens)
        assert slot in self.owned, f"slot {slot} not reserved"
        assert need <= self.reserved[slot], \
            f"slot {slot} outgrew its reservation ({need} > " \
            f"{self.reserved[slot]} pages)"
        new = []
        while len(self.owned[slot]) < need:
            pid = heapq.heappop(self._free)
            self.owned[slot].append(pid)
            self.refs[pid] = 1
            new.append(pid)
        self.peak_in_use = max(self.peak_in_use, self.n_pages - self.n_free)
        return new

    # -- reference counting (prefix sharing, DESIGN.md §13) ------------
    def incref(self, pid: int) -> None:
        assert self.refs.get(pid, 0) > 0, \
            f"incref on unreferenced page {pid}"
        self.refs[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was FREED (the
        caller must scrub it before reuse)."""
        n = self.refs[pid] - 1
        if n > 0:
            self.refs[pid] = n
            return False
        del self.refs[pid]
        heapq.heappush(self._free, pid)
        return True

    def adopt_shared(self, slot, page_ids: List[int]) -> None:
        """Map already-live (cache-held) pages as the slot's leading
        ordinals — the prefix-hit admission path.  Must run right after
        :meth:`reserve` (the slot owns nothing yet) so the shared pages
        occupy exactly the ordinals whose tokens they hold; the
        reservation from ``reserve`` counts TOTAL pages, so the adopted
        pages consume part of it rather than adding to the footprint."""
        assert slot in self.reserved and not self.owned[slot], \
            f"adopt_shared({slot}) must follow reserve() immediately"
        assert len(page_ids) <= self.reserved[slot]
        for pid in page_ids:
            self.incref(pid)
            self.owned[slot].append(pid)

    def can_grow_reservation(self, slot, n_tokens: int) -> bool:
        need = self.pages_for(n_tokens)
        cur = self.reserved.get(slot, 0)
        return (need <= cur
                or need - cur <= self.n_free - self.n_reserved_unallocated)

    def grow_reservation(self, slot, n_tokens: int) -> None:
        """Extend a slot's reservation to cover ``n_tokens`` — the
        optimistic-admission discipline under preemption: decode growth
        claims pages step by step, and when this fails the engine swaps
        a victim out instead of the admission-time worst case having
        refused the request outright."""
        need = self.pages_for(n_tokens)
        cur = self.reserved[slot]
        if need <= cur:
            return
        if need - cur > self.n_free - self.n_reserved_unallocated:
            raise ValueError(
                f"page pool exhausted: slot {slot} needs {need - cur} more "
                f"pages, {self.n_free - self.n_reserved_unallocated} "
                f"unreserved")
        self.reserved[slot] = need
        self.peak_committed = max(
            self.peak_committed,
            self.n_pages - self.n_free + self.n_reserved_unallocated)

    def release(self, slot) -> List[int]:
        """Drop the slot's reference on every page it owns; returns the
        pages actually FREED (for scrubbing) — a page still held by the
        prefix index (or another adopter) stays live and keeps its KV."""
        ids = self.owned.pop(slot, [])
        self.reserved.pop(slot, None)
        return [pid for pid in ids if self.decref(pid)]

    def trim(self, slot, n_tokens: int) -> List[int]:
        """Give back the pages beyond ``pages_for(n_tokens)`` — the
        speculative-decode rejection path.  The reservation is kept (the
        request may regrow into it), only allocations shrink; returns
        the freed page ids (highest ordinals first) for scrubbing.
        Shared pages that are popped but still referenced are not
        returned (they stay live for their other holders) — the caller
        clears table ordinals from the new owned length, not from the
        freed count."""
        keep = self.pages_for(n_tokens)
        assert slot in self.owned, f"slot {slot} not reserved"
        freed = []
        while len(self.owned[slot]) > keep:
            pid = self.owned[slot].pop()
            if self.decref(pid):
                freed.append(pid)
        return freed

    def stats(self) -> Dict[str, object]:
        return {"pages_total": self.n_pages,
                "pages_free": self.n_free,
                "pages_in_use": self.n_pages - self.n_free,
                "pages_peak_in_use": self.peak_in_use,
                "pages_peak_committed": self.peak_committed,
                "pages_reserved_unallocated": self.n_reserved_unallocated,
                "page_size": self.page_size}


class HostPagePool:
    """Budget + accounting for KV pages staged to host RAM (DESIGN.md
    §13) — the KV-plane analogue of ``core/expert_pool.py``'s staged
    streaming: swap-out gathers a slot's pages into one contiguous
    device buffer and stages it d2h, swap-in stages it back and scatters
    into freshly allocated pages.  The blobs themselves live with the
    engine's preempted-request records; this object only enforces the
    ``--kv-host-pages`` budget and carries the byte counters the
    ``kv_host`` telemetry namespace reports.  A zero budget is a real
    ablation: every preemption then drops its KV and resumes by
    recomputation."""

    def __init__(self, n_pages: int):
        assert n_pages >= 0
        self.n_pages = int(n_pages)
        self.in_use = 0
        self.peak_in_use = 0
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0

    def can_hold(self, n_pages: int) -> bool:
        return self.in_use + n_pages <= self.n_pages

    def note_out(self, n_pages: int, nbytes: int) -> None:
        self.in_use += n_pages
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.swap_out_bytes += nbytes

    def note_in(self, n_pages: int, nbytes: int) -> None:
        assert self.in_use >= n_pages
        self.in_use -= n_pages
        self.swap_in_bytes += nbytes

    def note_drop(self, n_pages: int) -> None:
        """Give back budget for a blob discarded WITHOUT an h2d restore
        (cancel-while-swapped, swap-in fault — DESIGN.md §14): the pages
        leave the host pool but no swap-in bytes flow."""
        assert self.in_use >= n_pages
        self.in_use -= n_pages

    def stats(self) -> Dict[str, int]:
        return {"pages_total": self.n_pages,
                "pages_in_use": self.in_use,
                "peak_pages_in_use": self.peak_in_use,
                "swap_out_bytes": self.swap_out_bytes,
                "swap_in_bytes": self.swap_in_bytes}


class PagedKVManager:
    """Block-paged slotted decode state (DESIGN.md §9).

    Same slot protocol as :class:`KVSlotManager` — ``allocate`` /
    ``release`` / per-row ``pos`` — but KV lives in per-layer page pools
    (``models/layers.init_paged_attn_cache``) indexed through one shared
    per-slot page table, so:

    * admission prefill chunks write **directly into the pool pages the
      slot owns** (``decode_step(row=...)``) — there is no B=1 side
      state and no install scatter;
    * a request reserves ``ceil((prompt+max_new)/page_size)`` pages, not
      ``slot_len`` positions;
    * each decode step runs against a table **view** sliced to the live
      page horizon (:meth:`live_width`), so attention cost follows live
      context, not slot capacity.

    The page table is authoritative host-side (``numpy``); the device
    copy is rebuilt only when allocation changes it.  Released pages
    have their ``ppos`` scrubbed to −1 (one jitted op over the layer
    stack) so a reused page can never leak its previous owner's
    positions into a new row's attention mask.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, page_size: int,
                 pages_total: int, max_pages_per_slot: int, *,
                 bucket: bool = True):
        self.cfg = cfg
        # per-layer-kind state planes (DESIGN.md §12): only layers whose
        # plane GROWS with context hold pool pages.  A stack with no such
        # layer (pure-recurrent, e.g. xlstm) reserves ZERO pages per
        # request — its fixed-size state rides in the dense batch rows —
        # so admission never gates on pool capacity it would never use.
        self.has_kv = cfg.has_kv_layers
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_pages = max_pages_per_slot
        self.slot_len = max_pages_per_slot * page_size  # per-request cap
        self.bucket = bucket
        state = T.init_decode_state(cfg, n_slots, self.slot_len,
                                    kv_pages=pages_total, kv_page=page_size,
                                    kv_max_pages=max_pages_per_slot)
        state["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.state = state
        self.pool = PagePool(pages_total, page_size)
        self._pages_np = np.full((n_slots, max_pages_per_slot), -1, np.int32)
        self._pages_dev = jnp.asarray(self._pages_np)
        self._dirty = False
        self._free: List[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self._owner: List[Optional[object]] = [None] * n_slots
        self._len = [0] * n_slots  # host mirror of live token counts
        self.host: Optional[HostPagePool] = None  # swap budget (§13)
        self._page_nbytes: Optional[int] = None
        self._finj = None  # FaultInjector (DESIGN.md §14); None = inert

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def owner(self, slot: int):
        return self._owner[slot]

    def set_fault_injector(self, inj) -> None:
        """Attach (or clear) the seeded fault plane (DESIGN.md §14).
        Sites here: ``page_pool`` (admission sees no headroom),
        ``swap_out`` (the d2h stage fails, KV is dropped for
        recompute-resume)."""
        self._finj = inj

    def can_admit(self, n_tokens: int, prealloc_pages: int = 0) -> bool:
        """``prealloc_pages`` is the prefix-hit credit (DESIGN.md §13):
        pages the request would adopt from the cache are already
        allocated, so only the remainder of its worst-case budget must
        be reservable."""
        if self._finj is not None and self._finj.fires("page_pool"):
            # injected exhaustion: the admission stalls and retries next
            # step — same recovery path a genuinely dry pool exercises
            return False
        if not self.has_kv:
            return bool(self._free)  # zero-page archs gate on slots only
        need = max(0, self.pool.pages_for(n_tokens) - prealloc_pages)
        return bool(self._free) and self.pool.can_reserve(need)

    def allocate(self, owner=None, n_tokens: int = 1, *,
                 shared_pages=(), base: int = 0) -> int:
        """Claim a slot and reserve its worst-case page budget (zero
        pages when no layer carries a growing KV plane).  A prefix-hit
        admission passes the cache's pages as ``shared_pages`` (mapped
        read-only as the slot's leading ordinals, refcounted) and
        ``base`` = the matched token count, so the slot starts at the
        divergence point and its prefill covers only ``[base, total)``;
        otherwise position resets to 0."""
        assert base == len(shared_pages) * self.page_size
        slot = heapq.heappop(self._free)
        if self.has_kv:
            self.pool.reserve(slot, n_tokens,
                              prealloc_pages=len(shared_pages))
            if shared_pages:
                self.pool.adopt_shared(slot, list(shared_pages))
                for j, pid in enumerate(shared_pages):
                    self._pages_np[slot, j] = pid
                self._dirty = True
        self._owner[slot] = owner
        self._len[slot] = base
        self.state = dict(self.state,
                          pos=self.state["pos"].at[slot].set(base))
        # paged prefill chunks write IN PLACE (no install scatter), so a
        # reused slot's fixed-size recurrent carries must reset here —
        # KV pages get the same hygiene from the release-time ppos scrub
        if self.cfg.has_recurrent_layers:
            self._reset_rec(slot)
        return slot

    def release(self, slot: int) -> None:
        assert self._owner[slot] is not None, f"slot {slot} already free"
        ids = self.pool.release(slot)  # only pages whose LAST ref dropped
        # table edit precedes the scrub: the scrub donates the state
        # (including the device table buffer), so mark it stale first
        self._pages_np[slot] = -1
        self._dirty = True
        self._scrub(ids)
        self._owner[slot] = None
        self._len[slot] = 0
        heapq.heappush(self._free, slot)

    def free_cached_pages(self, page_ids: List[int]) -> List[int]:
        """Drop the prefix index's reference on evicted pages; pages
        whose last reference this was are freed AND scrubbed (the
        scrub-on-reuse guarantee holds through the cache path too).
        Returns the pages actually freed."""
        freed = [pid for pid in page_ids if self.pool.decref(pid)]
        self._scrub(freed)
        return freed

    # -- host swap (preemption, DESIGN.md §13) -------------------------
    def enable_host_swap(self, n_pages: int) -> None:
        self.host = HostPagePool(n_pages)

    def page_nbytes(self) -> int:
        """Bytes one pool page occupies across every layer's kp/vp/ppos
        planes — the unit of swap traffic accounting."""
        if self._page_nbytes is None:
            total = 0
            for blk in self.state["stack"] + self.state["tail"]:
                kv = blk.get("kv") if isinstance(blk, dict) else None
                if isinstance(kv, dict) and "ppos" in kv:
                    for name in ("kp", "vp", "ppos"):
                        total += kv[name].nbytes // self.pool.n_pages
            self._page_nbytes = total
        return self._page_nbytes

    def _swap_width(self, k: int) -> int:
        w = 1
        while w < k:
            w *= 2
        return min(w, self.max_pages)

    def swap_out(self, slot: int):
        """Stage the slot's live pages to host (d2h) so the engine can
        release them — the swap half of preemption.  Returns a host blob
        (numpy pytree + bookkeeping) the engine stores with the
        preempted request, or ``None`` when the host budget cannot hold
        the pages (the engine then drops the KV and resumes by
        recomputation).  The caller releases the slot afterwards; shared
        prefix pages survive that release through their cache refs, and
        the blob holds their content anyway, so the restore is exact
        either way."""
        if self.host is None or not self.has_kv:
            return None
        pids = list(self.pool.owned.get(slot, []))
        k = len(pids)
        if k == 0 or not self.host.can_hold(k):
            return None
        if self._finj is not None and self._finj.fires("swap_out"):
            # injected d2h failure: report "could not stage" — the
            # engine's drop-KV + recompute-resume path absorbs it
            return None
        w = self._swap_width(k)
        padded = np.zeros((w,), np.int32)  # junk beyond k, dropped on restore
        padded[:k] = pids
        data = self._swap_gather_fn(w)(self.state, jnp.asarray(padded))
        data = jax.tree.map(np.asarray, data)  # the d2h stage
        self.host.note_out(k, k * self.page_nbytes())
        return {"data": data, "n_pages": k, "width": w,
                "n_tokens": self._len[slot]}

    def swap_in(self, owner, blob, reserve_tokens: int) -> int:
        """Re-admit a swapped request: allocate a slot + reservation,
        take exactly the blob's page count from the pool and scatter the
        staged pages back (h2d).  Positions, page ordinals and ``ppos``
        restore verbatim, so the resumed decode is bitwise the
        uninterrupted one."""
        slot = self.allocate(owner, reserve_tokens)
        k = blob["n_pages"]
        self.ensure(slot, k * self.page_size)
        pids = self.pool.owned[slot]
        assert len(pids) == k, f"swap_in expected {k} pages, got {len(pids)}"
        w = blob["width"]
        # pad with the out-of-bounds sentinel: the gather's junk rows
        # beyond n_pages scatter nowhere (mode="drop")
        padded = np.full((w,), self.pool.n_pages, np.int32)
        padded[:k] = pids
        self.state = self._swap_scatter_fn(w)(
            self.state, blob["data"], jnp.asarray(padded))
        self.host.note_in(k, k * self.page_nbytes())
        n_live = blob["n_tokens"]
        self._len[slot] = n_live
        self.state = dict(self.state,
                          pos=self.state["pos"].at[slot].set(n_live))
        return slot

    def discard_blob(self, blob) -> None:
        """Drop a swap-out blob without restoring it (cancel-while-
        swapped, or an injected ``swap_in`` fault — DESIGN.md §14): the
        host budget returns immediately and no h2d traffic flows.  The
        blob's numpy pytree is garbage once the caller drops its
        reference."""
        if blob is None or self.host is None:
            return
        self.host.note_drop(blob["n_pages"])

    def host_stats(self) -> Dict[str, int]:
        host = self.host if self.host is not None else HostPagePool(0)
        return host.stats()

    def remaining(self, slot: int) -> int:
        return self.slot_len - self._len[slot]

    # ------------------------------------------------------------------
    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow the slot's page list to cover positions < n_tokens."""
        if not self.has_kv:
            return  # no growing plane — nothing to cover
        new = self.pool.ensure(slot, n_tokens)
        if new:
            base = len(self.pool.owned[slot]) - len(new)
            for j, pid in enumerate(new):
                self._pages_np[slot, base + j] = pid
            self._dirty = True

    def can_grow(self, slot: int, n_tokens: int) -> bool:
        """Could the slot's reservation stretch to ``n_tokens``?  The
        preemption-mode decode-growth probe: when False the engine frees
        pages (cache eviction, then victim swap-out) before growing."""
        if not self.has_kv:
            return True
        return self.pool.can_grow_reservation(slot, n_tokens)

    def grow(self, slot: int, n_tokens: int) -> None:
        """Extend the slot's reservation (optimistic admission under
        preemption) and allocate the covering pages."""
        if not self.has_kv:
            return
        self.pool.grow_reservation(slot, n_tokens)
        self.ensure(slot, n_tokens)

    def note_tokens(self, slot: int, n_tokens: int) -> None:
        """Record the slot's live token count (host mirror of ``pos`` —
        kept on the host so per-step page sizing never syncs a device
        array)."""
        self._len[slot] = n_tokens

    def length(self, slot: int) -> int:
        return self._len[slot]

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Roll the slot back so exactly ``n_tokens`` positions are live
        — the speculative-decode rejection path (DESIGN.md §11).  Pos
        and the host length mirror reset, and pages past
        ``pages_for(n_tokens)`` are returned to the pool (scrubbed, so
        a reused page cannot leak the rejected tokens' positions into
        another row's mask) — after a rejection the slot's page table is
        exactly what non-speculative decode at the same position holds,
        a property the spec tests assert literally."""
        assert n_tokens >= 0 and n_tokens <= self._len[slot], \
            f"truncate({slot}, {n_tokens}) would extend, not roll back"
        if self.has_kv:
            freed = self.pool.trim(slot, n_tokens)
            # clear every popped ordinal — under sharing a popped page may
            # stay live (another holder), but it is no longer THIS row's
            keep = len(self.pool.owned[slot])
            if (self._pages_np[slot, keep:] != -1).any():
                self._pages_np[slot, keep:] = -1
                self._dirty = True
            self._scrub(freed)
        self._len[slot] = n_tokens
        self.state = dict(self.state,
                          pos=self.state["pos"].at[slot].set(n_tokens))

    # ------------------------------------------------------------------
    def _reset_rec(self, slot: int) -> None:
        """Zero one slot's recurrent ("rec") planes across the layer
        stack — the rec plane's analogue of the page scrub: without it a
        reused slot's prefill folds the EVICTED request's final carries
        into the new prompt (DESIGN.md §12)."""
        def make():
            def zrow(d, idx):  # stack leaves carry a leading period axis
                return dict(d, rec=jax.tree.map(
                    lambda a: a.at[idx].set(jnp.zeros((), a.dtype)),
                    d["rec"]))

            def z(state, i):
                stack = [zrow(d, (slice(None), i)) if "rec" in d else d
                         for d in state["stack"]]
                tail = [zrow(d, i) if "rec" in d else d
                        for d in state["tail"]]
                return dict(state, stack=stack, tail=tail)
            return jax.jit(z, donate_argnums=0)
        fn = T.cached_jit(("reset_rec_row", self.cfg), make)
        self.state = fn(self.state, slot)

    def pages_dev(self):
        if self._dirty:
            self._pages_dev = jnp.asarray(self._pages_np)
            self._dirty = False
        return self._pages_dev

    def live_width(self, slots) -> int:
        """Page-table width covering every listed slot's allocated pages
        — the decode step's attention horizon.  Bucketed to the next
        power of two so jit recompiles O(log max_pages) programs, not
        one per width."""
        used = max((len(self.pool.owned.get(s, [])) for s in slots),
                   default=1)
        used = max(1, used)
        if not self.bucket:
            return self.max_pages
        w = 1
        while w < used:
            w *= 2
        return min(w, self.max_pages)

    def view(self, width: Optional[int] = None):
        """State with the page table sliced to ``width`` ordinals — what
        one decode step executes against.  The table leaf is always a
        fresh buffer: decode programs donate their state, and the cached
        full-width table must survive the donation."""
        pages = self.pages_dev()
        if width is not None and width < self.max_pages:
            pages = pages[:, :width]
        else:
            pages = jnp.copy(pages)
        return dict(self.state, pages=pages)

    def adopt(self, new_state) -> None:
        """Take the pools/positions a step returned; the (possibly
        sliced, never written) page table is replaced by the full
        host-authoritative one."""
        self.state = dict(new_state, pages=self.pages_dev())

    def write_enc_kv(self, slot: int, enc_kv) -> None:
        """Install a request's admission-time encoder-KV (B=1 layout from
        ``transformer.encode_enc_kv``) into its row of the shared
        read-only plane.  Paged admission writes prompt chunks straight
        into the big state (``decode_step(row=...)``), so the enc-KV row
        must be resident BEFORE the first chunk runs (DESIGN.md §12)."""
        def make():
            def w(state, enc, i):
                ek = state["enc_kv"]
                return dict(state, enc_kv=dict(
                    ek,
                    k=ek["k"].at[:, i].set(enc["k"][:, 0]),
                    v=ek["v"].at[:, i].set(enc["v"][:, 0])))
            return jax.jit(w, donate_argnums=0)
        fn = T.cached_jit(("write_enc_kv", self.cfg), make)
        self.state = fn(self.state, enc_kv, slot)

    # ------------------------------------------------------------------
    def _scrub(self, page_ids: List[int]) -> None:
        """Reset ``ppos`` of released pages to −1 in every layer (one
        jitted program; ids padded to max_pages with an out-of-bounds
        sentinel that ``mode="drop"`` discards).  Without this a reused
        page would expose its previous owner's absolute positions to the
        next row's attention mask."""
        if not page_ids:
            return
        # the donation consumes the state's device table buffer too —
        # force pages_dev() to re-upload from the host-authoritative
        # table (free_cached_pages scrubs without editing any table row,
        # so it cannot rely on the caller having marked it stale)
        self._dirty = True
        pad = np.full((self.max_pages,), self.pool.n_pages, np.int32)
        for chunk_lo in range(0, len(page_ids), self.max_pages):
            ids = page_ids[chunk_lo: chunk_lo + self.max_pages]
            pids = pad.copy()
            pids[: len(ids)] = ids
            self.state = self._scrub_fn()(self.state, jnp.asarray(pids))

    def _scrub_fn(self):
        cfg = self.cfg

        def make():
            def scrub(state, pids):
                def scrub_kv(blk):
                    kv = blk.get("kv")
                    if not isinstance(kv, dict) or "ppos" not in kv:
                        return blk
                    pp = kv["ppos"]
                    if pp.ndim == 3:  # stacked (n_periods, P, ps)
                        pp = pp.at[:, pids].set(-1, mode="drop")
                    else:
                        pp = pp.at[pids].set(-1, mode="drop")
                    return dict(blk, kv=dict(kv, ppos=pp))
                return dict(state,
                            stack=[scrub_kv(b) for b in state["stack"]],
                            tail=[scrub_kv(b) for b in state["tail"]])
            return jax.jit(scrub, donate_argnums=0)
        return T.cached_jit(("paged_scrub", cfg, self.max_pages), make)

    def _swap_gather_fn(self, width: int):
        """One program gathering ``width`` pages of every layer's
        kp/vp/ppos into a contiguous buffer — the d2h stage of swap-out.
        Width is pow-2 bucketed (:meth:`_swap_width`) so jit compiles
        O(log max_pages) programs.  Not donated: the state stays live."""
        cfg = self.cfg

        def make():
            def gather(state, pids):
                def g(blk):
                    kv = blk.get("kv") if isinstance(blk, dict) else None
                    if not isinstance(kv, dict) or "ppos" not in kv:
                        return None
                    if kv["ppos"].ndim == 3:  # stacked (n_periods, P, ...)
                        return {n: kv[n][:, pids]
                                for n in ("kp", "vp", "ppos")}
                    return {n: kv[n][pids] for n in ("kp", "vp", "ppos")}
                return {"stack": [g(b) for b in state["stack"]],
                        "tail": [g(b) for b in state["tail"]]}
            return jax.jit(gather)
        return T.cached_jit(("kv_swap_gather", cfg, width), make)

    def _swap_scatter_fn(self, width: int):
        """Inverse of :meth:`_swap_gather_fn` — the h2d stage of
        swap-in: scatter a staged blob into freshly allocated pages.
        Donated (pure page update); padded ids carry the out-of-bounds
        sentinel so the gather's junk rows are dropped."""
        cfg = self.cfg

        def make():
            def scatter(state, data, pids):
                def s(blk, d):
                    kv = blk.get("kv") if isinstance(blk, dict) else None
                    if d is None or not isinstance(kv, dict) \
                            or "ppos" not in kv:
                        return blk
                    if kv["ppos"].ndim == 3:
                        upd = {n: kv[n].at[:, pids].set(d[n], mode="drop")
                               for n in ("kp", "vp", "ppos")}
                    else:
                        upd = {n: kv[n].at[pids].set(d[n], mode="drop")
                               for n in ("kp", "vp", "ppos")}
                    return dict(blk, kv=dict(kv, **upd))
                return dict(
                    state,
                    stack=[s(b, d) for b, d in
                           zip(state["stack"], data["stack"])],
                    tail=[s(b, d) for b, d in
                          zip(state["tail"], data["tail"])])
            return jax.jit(scatter, donate_argnums=0)
        return T.cached_jit(("kv_swap_scatter", cfg, width), make)

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        """Telemetry ``kv`` namespace (``schema.KV_KEYS_PAGED``) — slot
        occupancy from the host mirrors plus the page pool's counters."""
        live = [self._len[s] for s in range(self.n_slots)
                if self._owner[s] is not None]
        out = {"layout": "paged",
               "slots_in_use": self.n_slots - self.n_free,
               "slots_free": self.n_free,
               # committed = allocated + reserved-unallocated, so this is
               # comparable with the dense manager's slot-capacity peak
               "peak_positions_reserved":
                   self.pool.peak_committed * self.page_size,
               "positions_live": sum(live),
               "slot_lengths": live,
               "slot_pages": {s: list(self.pool.owned.get(s, []))
                              for s in range(self.n_slots)
                              if self._owner[s] is not None}}
        out.update(self.pool.stats())
        return out

    def stats(self) -> Dict[str, object]:
        """Legacy flat projection of :meth:`metrics` (``kv_*`` keys)."""
        return {f"kv_{k}": v for k, v in self.metrics().items()}

    # ------------------------------------------------------------------
    def check_invariants(self, cache_pages=()) -> None:
        """Step-boundary crash-consistency audit (DESIGN.md §14).

        ``cache_pages`` enumerates every page the prefix index currently
        holds a reference on.  Asserts, exactly:

        * the free heap and the referenced set are a disjoint partition
          of ``range(n_pages)`` — no page is lost, none counted twice;
        * the refcount of every page equals its holder count (slots
          owning it + one per prefix-cache node) — no phantom or leaked
          reference anywhere;
        * every slot's host page table row mirrors its owned list,
          gapless, with −1 past the end — what the device executes
          against is what the allocator believes;
        * no slot's allocation exceeds its reservation, and reservations
          exist exactly for allocated slots;
        * the slot free list and the owner map partition the slots.
        """
        pool = self.pool
        free = sorted(pool._free)
        assert len(set(free)) == len(free), \
            f"free heap holds duplicates: {free}"
        live = set(pool.refs)
        both = set(free) & live
        assert not both, f"pages both free and referenced: {sorted(both)}"
        missing = set(range(pool.n_pages)) - set(free) - live
        assert not missing, f"pages neither free nor referenced: " \
                            f"{sorted(missing)}"
        assert set(pool.owned) == set(pool.reserved), \
            "reservation/ownership slot sets diverge"
        holders: Dict[int, int] = {}
        for slot, ids in pool.owned.items():
            assert len(ids) <= pool.reserved[slot], \
                f"slot {slot} owns {len(ids)} pages over its " \
                f"{pool.reserved[slot]}-page reservation"
            for pid in ids:
                holders[pid] = holders.get(pid, 0) + 1
        for pid in cache_pages:
            holders[int(pid)] = holders.get(int(pid), 0) + 1
        assert holders == pool.refs, \
            f"refcounts diverge from holder counts:\n" \
            f"  holders: {dict(sorted(holders.items()))}\n" \
            f"  refs   : {dict(sorted(pool.refs.items()))}"
        for s in range(self.n_slots):
            ids = pool.owned.get(s, []) if self._owner[s] is not None else []
            row = self._pages_np[s]
            assert list(row[:len(ids)]) == list(ids), \
                f"slot {s} table row {row[:len(ids)].tolist()} != owned " \
                f"{list(ids)}"
            assert (row[len(ids):] == -1).all(), \
                f"slot {s} table has stale ids past its {len(ids)} pages"
        free_slots, owned_slots = set(self._free), \
            {s for s in range(self.n_slots) if self._owner[s] is not None}
        assert not (free_slots & owned_slots), \
            f"slots both free and owned: {sorted(free_slots & owned_slots)}"
        assert free_slots | owned_slots == set(range(self.n_slots)), \
            "slot free list + owner map do not cover all slots"


# ======================================================================
class StateManager:
    """Facade over the slot-state manager families (DESIGN.md §12).

    One construction point that reads the config's ``state_planes()``
    descriptor and returns the right manager for its mix of layer kinds:

    * dense rings + fixed-size recurrent rows (+ the shared enc-KV
      plane) -> :class:`KVSlotManager`, which is plane-agnostic: it
      scatters/gathers whole slot rows, whatever planes they hold;
    * block-paged KV (``kv_page`` set) -> :class:`PagedKVManager`, whose
      page pool only ever holds pages for GROWING planes — a config with
      none (pure-recurrent stacks) reserves zero pages per request and
      gates admission on slots alone.

    Both families share the slot protocol the engine consumes
    (allocate / release / truncate / remaining / metrics), so the engine
    never branches on arch_type — only on which family it got.
    """

    @staticmethod
    def create(cfg: ModelConfig, n_slots: int, slot_len: int, *,
               kv_page: Optional[int] = None,
               kv_pages_total: Optional[int] = None,
               bucket: bool = True):
        if kv_page is None:
            if kv_pages_total is not None:
                raise ValueError("kv_pages_total needs kv_page (it sizes "
                                 "the paged pool)")
            return KVSlotManager(cfg, n_slots, slot_len)
        max_pages = -(-slot_len // kv_page)
        pages_total = (kv_pages_total if kv_pages_total is not None
                       else n_slots * max_pages)
        return PagedKVManager(cfg, n_slots, kv_page, pages_total,
                              max_pages, bucket=bucket)
