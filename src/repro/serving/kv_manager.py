"""Slotted KV-cache manager for continuous batching.

One preallocated decode state (``models/transformer.init_decode_state``
layout) holds ``n_slots`` independent sequences; the batch axis is the
slot table.  Requests of different lengths join and leave a *running*
batch by writing a freshly prefilled B=1 state into a free slot
(block-table indirection at slot granularity — every slot owns a
fixed-width ring of ``slot_len`` KV positions) and releasing it when the
request finishes.  Nothing else in the batch is touched: per-row ``pos``
(see ``decode_step``) keeps every slot at its own absolute position, and
ring slots carrying pos = −1 are invisible to attention, so a freed slot
needs no scrubbing before reuse — the next prefill overwrites every leaf
of that row.

The manager is deliberately model-agnostic: it treats the decode state as
an opaque pytree and only assumes the seed layout's axis convention
(``stack`` leaves carry batch at axis 1 under the scan axis, ``tail``
leaves at axis 0, ``pos`` is per-row).

:class:`PagedKVManager` is the block-paged alternative (DESIGN.md §9):
KV lives in a shared pool of fixed-size pages per layer and a slot owns
an ordered page list instead of a fixed-width ring, so short requests
stop reserving ``slot_len`` of KV and decode attention is sliced to the
*live* page horizon every step.  :class:`PagePool` is the host-side
allocator (heap free list + admission reservations) whose invariants
are property-tested in ``tests/test_paged_kv.py``.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def _write_slot(big, small, i):
    """Scatter a B=1 decode state into row ``i`` of the slotted state.

    Plane-agnostic (DESIGN.md §12): ``stack`` leaves — ring KV *and*
    fixed-size recurrent state alike — carry batch at axis 1 under the
    scan axis, ``tail`` leaves at axis 0, so one tree-map covers every
    layer kind.  The shared encoder-KV plane (enc-dec decoders) carries
    batch at axis 1 under the layer axis; its ``pos`` is batch-free
    (every row's encoder output spans the same positions) and is never
    written."""
    out = dict(big)
    out["stack"] = [jax.tree.map(lambda b, s: b.at[:, i].set(s[:, 0]), bs, ss)
                    for bs, ss in zip(big["stack"], small["stack"])]
    out["tail"] = [jax.tree.map(lambda b, s: b.at[i].set(s[0]), bt, st)
                   for bt, st in zip(big["tail"], small["tail"])]
    # small pos is a scalar (unpadded prefill) or (1,) (padded prefill)
    out["pos"] = big["pos"].at[i].set(
        jnp.reshape(jnp.asarray(small["pos"]), (-1,))[0].astype(jnp.int32))
    if "enc_kv" in big:
        ek, sk = big["enc_kv"], small["enc_kv"]
        out["enc_kv"] = dict(ek,
                             k=ek["k"].at[:, i].set(sk["k"][:, 0]),
                             v=ek["v"].at[:, i].set(sk["v"][:, 0]))
    return out


def _read_slot(big, i):
    """Gather row ``i`` of the slotted state into a B=1 state — the exact
    inverse of :func:`_write_slot`.  This is the snapshot half of the
    recurrent speculative-rollback protocol (DESIGN.md §12): fixed-size
    state cannot be rolled back by a pos reset (the carry has already
    folded the rejected tokens in), so the engine snapshots the row
    before a verify round and restores + replays on rejection."""
    out = dict(big)
    out["stack"] = [jax.tree.map(
        lambda b: jax.lax.dynamic_slice_in_dim(b, i, 1, axis=1), bs)
        for bs in big["stack"]]
    out["tail"] = [jax.tree.map(
        lambda b: jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0), bt)
        for bt in big["tail"]]
    out["pos"] = jax.lax.dynamic_slice(big["pos"], (i,), (1,))
    if "enc_kv" in big:
        ek = big["enc_kv"]
        out["enc_kv"] = dict(
            ek,
            k=jax.lax.dynamic_slice_in_dim(ek["k"], i, 1, axis=1),
            v=jax.lax.dynamic_slice_in_dim(ek["v"], i, 1, axis=1))
    if "pages" in big:
        out["pages"] = jax.lax.dynamic_slice_in_dim(big["pages"], i, 1,
                                                    axis=0)
    return out


class KVSlotManager:
    """Free-list over the batch axis of one preallocated decode state."""

    def __init__(self, cfg: ModelConfig, n_slots: int, slot_len: int):
        self.cfg = cfg
        self.n_slots = n_slots
        self.slot_len = slot_len
        state = T.init_decode_state(cfg, n_slots, slot_len)
        state["pos"] = jnp.zeros((n_slots,), jnp.int32)  # per-row positions
        self.state = state
        # heap free list: O(log n) allocate/release, lowest slot first
        # (the order the old pop(0)/sort() list produced)
        self._free: List[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self._owner: List[Optional[object]] = [None] * n_slots
        self.peak_slots = 0
        # donate the big state: the write is a pure row update, so XLA
        # reuses the (KV-stack-sized) buffers instead of copying them
        self._write = jax.jit(_write_slot, donate_argnums=0)
        self._read = jax.jit(_read_slot)

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def owner(self, slot: int):
        return self._owner[slot]

    def allocate(self, owner=None) -> int:
        slot = heapq.heappop(self._free)
        self._owner[slot] = owner
        self.peak_slots = max(self.peak_slots, self.n_slots - self.n_free)
        return slot

    def release(self, slot: int) -> None:
        assert self._owner[slot] is not None, f"slot {slot} already free"
        self._owner[slot] = None
        heapq.heappush(self._free, slot)

    def metrics(self) -> Dict[str, object]:
        """KV occupancy counters — the telemetry ``kv`` namespace
        (``repro.obs.schema.KV_KEYS_DENSE``): the dense ring reserves
        ``slot_len`` positions per slot whether used or not —
        ``positions_reserved`` vs ``positions_live`` is exactly the waste
        the paged layout removes (DESIGN.md §9).  Pull-time only: the
        ``pos`` fetch happens per snapshot, never per step."""
        pos = np.asarray(self.state["pos"])
        live = [int(pos[s]) for s in range(self.n_slots)
                if self._owner[s] is not None]
        return {"layout": "dense",
                "slots_in_use": self.n_slots - self.n_free,
                "slots_free": self.n_free,
                "positions_reserved":
                    (self.n_slots - self.n_free) * self.slot_len,
                "peak_positions_reserved": self.peak_slots * self.slot_len,
                "positions_live": sum(live),
                "slot_lengths": live}

    def stats(self) -> Dict[str, object]:
        """Legacy flat projection of :meth:`metrics` (``kv_*`` keys)."""
        return {f"kv_{k}": v for k, v in self.metrics().items()}

    # ------------------------------------------------------------------
    def new_row_state(self):
        """Fresh B=1 decode state of slot width — the accumulator for
        chunked admission (DESIGN.md §8): the runtime executor's
        ``prefill_chunk`` writes each chunk's KV into it at the chunk's
        offset (``pos .. pos+C−1`` ring slots), decode steps of the
        *other* rows proceed against the big slotted state in between,
        and after the final chunk :meth:`write_prefill` scatters the
        finished row in.  Because rows are disjoint, the deferred
        scatter cannot race the in-flight batch."""
        state = T.init_decode_state(self.cfg, 1, self.slot_len)
        return state

    def write_prefill(self, small_state, slot: int) -> None:
        """Install a prefilled B=1 state (``max_len == slot_len``) into
        ``slot``; the request's remaining KV budget is slot_len − pos."""
        # width check against the first layer that carries a ring KV
        # plane (hybrids may lead with recurrent blocks, whose fixed-size
        # state has no width to check)
        for bs, ss in zip(self.state["stack"], small_state["stack"]):
            if "kv" in ss:
                if ss["kv"]["k"].shape[2] != bs["kv"]["k"].shape[2]:
                    raise ValueError(
                        f"prefill state width {ss['kv']['k'].shape[2]} != "
                        f"slot width {bs['kv']['k'].shape[2]}; prefill "
                        f"with max_len == slot_len")
                break
        self.state = self._write(self.state, small_state, slot)

    # ------------------------------------------------------------------
    def snapshot(self, slot: int):
        """B=1 copy of the slot's full state (every plane: rings, rec,
        enc-KV row, pos) — the pre-round snapshot of the speculative
        rollback protocol for stacks with fixed-size recurrent state
        (DESIGN.md §12).  O(slot) device copy; taken only when the config
        actually has non-attention planes."""
        return self._read(self.state, slot)

    def restore(self, small_state, slot: int) -> None:
        """Write a :meth:`snapshot` (or a replayed continuation of one)
        back into ``slot`` — the restore half of speculative rollback."""
        self.state = self._write(self.state, small_state, slot)

    def remaining(self, slot: int) -> int:
        """Decode steps this slot can still take before its ring would
        overwrite live context (conservative for SWA stacks, where the
        window may be narrower than the slot)."""
        return self.slot_len - int(self.state["pos"][slot])

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Roll the slot back so exactly ``n_tokens`` positions are live
        — the speculative-decode rejection path (DESIGN.md §11).  For
        the dense ring this is a pos reset *only*: ring entries at
        positions ≥ n_tokens carry kpos > qpos for every future query,
        so the attention validity mask already hides them, and the next
        real token overwrites the same ring slot.  Valid only while the
        ring has never wrapped (bounded mode), which the speculative
        path guarantees.  Fixed-size recurrent planes CANNOT be rolled
        back this way (the carry already folded the rejected tokens) —
        the engine pairs this with :meth:`snapshot` / :meth:`restore`
        for such stacks (DESIGN.md §12)."""
        assert 0 <= n_tokens <= int(self.state["pos"][slot]), \
            f"truncate({slot}, {n_tokens}) would extend, not roll back"
        self.state = dict(
            self.state,
            pos=self.state["pos"].at[slot].set(np.int32(n_tokens)))


# ======================================================================
# Block-paged KV (DESIGN.md §9)
class PagePool:
    """Host-side page allocator: heap free list + per-slot ordered page
    lists + admission *reservations*.

    Pages are allocated lazily (``ensure`` covers positions as they are
    written) but admission reserves a slot's worst-case page count up
    front, so a mid-decode allocation can never fail — the conservative
    no-preemption discipline (a request that is admitted always runs to
    completion).  Invariants (property-tested): a page has at most one
    owner, free + owned partitions the pool, a slot's table is gapless
    in ordinal order, and release returns every page.
    """

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages > 0 and page_size > 0
        self.n_pages = n_pages
        self.page_size = page_size
        self._free: List[int] = list(range(n_pages))
        heapq.heapify(self._free)
        self.owned: Dict[object, List[int]] = {}
        self.reserved: Dict[object, int] = {}
        self.peak_in_use = 0
        # peak COMMITTED pages (allocated + reserved-but-unallocated):
        # the honest memory footprint — a reserved page is unavailable
        # to other requests whether or not it has been written yet
        self.peak_committed = 0

    # ------------------------------------------------------------------
    def pages_for(self, n_tokens: int) -> int:
        return max(1, -(-int(n_tokens) // self.page_size))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_reserved_unallocated(self) -> int:
        return sum(max(0, r - len(self.owned.get(s, [])))
                   for s, r in self.reserved.items())

    def can_reserve(self, n_pages: int) -> bool:
        return n_pages <= self.n_free - self.n_reserved_unallocated

    def reserve(self, slot, n_tokens: int) -> None:
        need = self.pages_for(n_tokens)
        if not self.can_reserve(need):
            raise ValueError(
                f"page pool exhausted: need {need} pages, "
                f"{self.n_free - self.n_reserved_unallocated} unreserved")
        assert slot not in self.reserved, f"slot {slot} already reserved"
        self.reserved[slot] = need
        self.owned[slot] = []
        self.peak_committed = max(
            self.peak_committed,
            self.n_pages - self.n_free + self.n_reserved_unallocated)

    def ensure(self, slot, n_tokens: int) -> List[int]:
        """Allocate pages so positions ``0 .. n_tokens−1`` are covered;
        returns the NEWLY allocated page ids (ordinal order)."""
        need = self.pages_for(n_tokens)
        assert slot in self.owned, f"slot {slot} not reserved"
        assert need <= self.reserved[slot], \
            f"slot {slot} outgrew its reservation ({need} > " \
            f"{self.reserved[slot]} pages)"
        new = []
        while len(self.owned[slot]) < need:
            pid = heapq.heappop(self._free)
            self.owned[slot].append(pid)
            new.append(pid)
        self.peak_in_use = max(self.peak_in_use, self.n_pages - self.n_free)
        return new

    def release(self, slot) -> List[int]:
        """Free every page the slot owns; returns them (for scrubbing)."""
        ids = self.owned.pop(slot, [])
        self.reserved.pop(slot, None)
        for pid in ids:
            heapq.heappush(self._free, pid)
        return ids

    def trim(self, slot, n_tokens: int) -> List[int]:
        """Give back the pages beyond ``pages_for(n_tokens)`` — the
        speculative-decode rejection path.  The reservation is kept (the
        request may regrow into it), only allocations shrink; returns
        the freed page ids (highest ordinals first) for scrubbing."""
        keep = self.pages_for(n_tokens)
        assert slot in self.owned, f"slot {slot} not reserved"
        freed = []
        while len(self.owned[slot]) > keep:
            pid = self.owned[slot].pop()
            heapq.heappush(self._free, pid)
            freed.append(pid)
        return freed

    def stats(self) -> Dict[str, object]:
        return {"pages_total": self.n_pages,
                "pages_free": self.n_free,
                "pages_in_use": self.n_pages - self.n_free,
                "pages_peak_in_use": self.peak_in_use,
                "pages_peak_committed": self.peak_committed,
                "pages_reserved_unallocated": self.n_reserved_unallocated,
                "page_size": self.page_size}


class PagedKVManager:
    """Block-paged slotted decode state (DESIGN.md §9).

    Same slot protocol as :class:`KVSlotManager` — ``allocate`` /
    ``release`` / per-row ``pos`` — but KV lives in per-layer page pools
    (``models/layers.init_paged_attn_cache``) indexed through one shared
    per-slot page table, so:

    * admission prefill chunks write **directly into the pool pages the
      slot owns** (``decode_step(row=...)``) — there is no B=1 side
      state and no install scatter;
    * a request reserves ``ceil((prompt+max_new)/page_size)`` pages, not
      ``slot_len`` positions;
    * each decode step runs against a table **view** sliced to the live
      page horizon (:meth:`live_width`), so attention cost follows live
      context, not slot capacity.

    The page table is authoritative host-side (``numpy``); the device
    copy is rebuilt only when allocation changes it.  Released pages
    have their ``ppos`` scrubbed to −1 (one jitted op over the layer
    stack) so a reused page can never leak its previous owner's
    positions into a new row's attention mask.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, page_size: int,
                 pages_total: int, max_pages_per_slot: int, *,
                 bucket: bool = True):
        self.cfg = cfg
        # per-layer-kind state planes (DESIGN.md §12): only layers whose
        # plane GROWS with context hold pool pages.  A stack with no such
        # layer (pure-recurrent, e.g. xlstm) reserves ZERO pages per
        # request — its fixed-size state rides in the dense batch rows —
        # so admission never gates on pool capacity it would never use.
        self.has_kv = cfg.has_kv_layers
        self.n_slots = n_slots
        self.page_size = page_size
        self.max_pages = max_pages_per_slot
        self.slot_len = max_pages_per_slot * page_size  # per-request cap
        self.bucket = bucket
        state = T.init_decode_state(cfg, n_slots, self.slot_len,
                                    kv_pages=pages_total, kv_page=page_size,
                                    kv_max_pages=max_pages_per_slot)
        state["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.state = state
        self.pool = PagePool(pages_total, page_size)
        self._pages_np = np.full((n_slots, max_pages_per_slot), -1, np.int32)
        self._pages_dev = jnp.asarray(self._pages_np)
        self._dirty = False
        self._free: List[int] = list(range(n_slots))
        heapq.heapify(self._free)
        self._owner: List[Optional[object]] = [None] * n_slots
        self._len = [0] * n_slots  # host mirror of live token counts

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def owner(self, slot: int):
        return self._owner[slot]

    def can_admit(self, n_tokens: int) -> bool:
        if not self.has_kv:
            return bool(self._free)  # zero-page archs gate on slots only
        return (bool(self._free)
                and self.pool.can_reserve(self.pool.pages_for(n_tokens)))

    def allocate(self, owner=None, n_tokens: int = 1) -> int:
        """Claim a slot and reserve its worst-case page budget (zero
        pages when no layer carries a growing KV plane); the slot's
        position resets to 0 (page writes start at ordinal 0)."""
        slot = heapq.heappop(self._free)
        if self.has_kv:
            self.pool.reserve(slot, n_tokens)
        self._owner[slot] = owner
        self._len[slot] = 0
        self.state = dict(self.state,
                          pos=self.state["pos"].at[slot].set(0))
        # paged prefill chunks write IN PLACE (no install scatter), so a
        # reused slot's fixed-size recurrent carries must reset here —
        # KV pages get the same hygiene from the release-time ppos scrub
        if self.cfg.has_recurrent_layers:
            self._reset_rec(slot)
        return slot

    def release(self, slot: int) -> None:
        assert self._owner[slot] is not None, f"slot {slot} already free"
        ids = self.pool.release(slot)
        # table edit precedes the scrub: the scrub donates the state
        # (including the device table buffer), so mark it stale first
        self._pages_np[slot] = -1
        self._dirty = True
        self._scrub(ids)
        self._owner[slot] = None
        self._len[slot] = 0
        heapq.heappush(self._free, slot)

    def remaining(self, slot: int) -> int:
        return self.slot_len - self._len[slot]

    # ------------------------------------------------------------------
    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow the slot's page list to cover positions < n_tokens."""
        if not self.has_kv:
            return  # no growing plane — nothing to cover
        new = self.pool.ensure(slot, n_tokens)
        if new:
            base = len(self.pool.owned[slot]) - len(new)
            for j, pid in enumerate(new):
                self._pages_np[slot, base + j] = pid
            self._dirty = True

    def note_tokens(self, slot: int, n_tokens: int) -> None:
        """Record the slot's live token count (host mirror of ``pos`` —
        kept on the host so per-step page sizing never syncs a device
        array)."""
        self._len[slot] = n_tokens

    def length(self, slot: int) -> int:
        return self._len[slot]

    def truncate(self, slot: int, n_tokens: int) -> None:
        """Roll the slot back so exactly ``n_tokens`` positions are live
        — the speculative-decode rejection path (DESIGN.md §11).  Pos
        and the host length mirror reset, and pages past
        ``pages_for(n_tokens)`` are returned to the pool (scrubbed, so
        a reused page cannot leak the rejected tokens' positions into
        another row's mask) — after a rejection the slot's page table is
        exactly what non-speculative decode at the same position holds,
        a property the spec tests assert literally."""
        assert n_tokens >= 0 and n_tokens <= self._len[slot], \
            f"truncate({slot}, {n_tokens}) would extend, not roll back"
        freed = self.pool.trim(slot, n_tokens) if self.has_kv else []
        if freed:
            base = len(self.pool.owned[slot])
            self._pages_np[slot, base: base + len(freed)] = -1
            self._dirty = True
            self._scrub(freed)
        self._len[slot] = n_tokens
        self.state = dict(self.state,
                          pos=self.state["pos"].at[slot].set(n_tokens))

    # ------------------------------------------------------------------
    def _reset_rec(self, slot: int) -> None:
        """Zero one slot's recurrent ("rec") planes across the layer
        stack — the rec plane's analogue of the page scrub: without it a
        reused slot's prefill folds the EVICTED request's final carries
        into the new prompt (DESIGN.md §12)."""
        def make():
            def zrow(d, idx):  # stack leaves carry a leading period axis
                return dict(d, rec=jax.tree.map(
                    lambda a: a.at[idx].set(jnp.zeros((), a.dtype)),
                    d["rec"]))

            def z(state, i):
                stack = [zrow(d, (slice(None), i)) if "rec" in d else d
                         for d in state["stack"]]
                tail = [zrow(d, i) if "rec" in d else d
                        for d in state["tail"]]
                return dict(state, stack=stack, tail=tail)
            return jax.jit(z, donate_argnums=0)
        fn = T.cached_jit(("reset_rec_row", self.cfg), make)
        self.state = fn(self.state, slot)

    def pages_dev(self):
        if self._dirty:
            self._pages_dev = jnp.asarray(self._pages_np)
            self._dirty = False
        return self._pages_dev

    def live_width(self, slots) -> int:
        """Page-table width covering every listed slot's allocated pages
        — the decode step's attention horizon.  Bucketed to the next
        power of two so jit recompiles O(log max_pages) programs, not
        one per width."""
        used = max((len(self.pool.owned.get(s, [])) for s in slots),
                   default=1)
        used = max(1, used)
        if not self.bucket:
            return self.max_pages
        w = 1
        while w < used:
            w *= 2
        return min(w, self.max_pages)

    def view(self, width: Optional[int] = None):
        """State with the page table sliced to ``width`` ordinals — what
        one decode step executes against.  The table leaf is always a
        fresh buffer: decode programs donate their state, and the cached
        full-width table must survive the donation."""
        pages = self.pages_dev()
        if width is not None and width < self.max_pages:
            pages = pages[:, :width]
        else:
            pages = jnp.copy(pages)
        return dict(self.state, pages=pages)

    def adopt(self, new_state) -> None:
        """Take the pools/positions a step returned; the (possibly
        sliced, never written) page table is replaced by the full
        host-authoritative one."""
        self.state = dict(new_state, pages=self.pages_dev())

    def write_enc_kv(self, slot: int, enc_kv) -> None:
        """Install a request's admission-time encoder-KV (B=1 layout from
        ``transformer.encode_enc_kv``) into its row of the shared
        read-only plane.  Paged admission writes prompt chunks straight
        into the big state (``decode_step(row=...)``), so the enc-KV row
        must be resident BEFORE the first chunk runs (DESIGN.md §12)."""
        def make():
            def w(state, enc, i):
                ek = state["enc_kv"]
                return dict(state, enc_kv=dict(
                    ek,
                    k=ek["k"].at[:, i].set(enc["k"][:, 0]),
                    v=ek["v"].at[:, i].set(enc["v"][:, 0])))
            return jax.jit(w, donate_argnums=0)
        fn = T.cached_jit(("write_enc_kv", self.cfg), make)
        self.state = fn(self.state, enc_kv, slot)

    # ------------------------------------------------------------------
    def _scrub(self, page_ids: List[int]) -> None:
        """Reset ``ppos`` of released pages to −1 in every layer (one
        jitted program; ids padded to max_pages with an out-of-bounds
        sentinel that ``mode="drop"`` discards).  Without this a reused
        page would expose its previous owner's absolute positions to the
        next row's attention mask."""
        if not page_ids:
            return
        pad = np.full((self.max_pages,), self.pool.n_pages, np.int32)
        for chunk_lo in range(0, len(page_ids), self.max_pages):
            ids = page_ids[chunk_lo: chunk_lo + self.max_pages]
            pids = pad.copy()
            pids[: len(ids)] = ids
            self.state = self._scrub_fn()(self.state, jnp.asarray(pids))

    def _scrub_fn(self):
        cfg = self.cfg

        def make():
            def scrub(state, pids):
                def scrub_kv(blk):
                    kv = blk.get("kv")
                    if not isinstance(kv, dict) or "ppos" not in kv:
                        return blk
                    pp = kv["ppos"]
                    if pp.ndim == 3:  # stacked (n_periods, P, ps)
                        pp = pp.at[:, pids].set(-1, mode="drop")
                    else:
                        pp = pp.at[pids].set(-1, mode="drop")
                    return dict(blk, kv=dict(kv, ppos=pp))
                return dict(state,
                            stack=[scrub_kv(b) for b in state["stack"]],
                            tail=[scrub_kv(b) for b in state["tail"]])
            return jax.jit(scrub, donate_argnums=0)
        return T.cached_jit(("paged_scrub", cfg, self.max_pages), make)

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, object]:
        """Telemetry ``kv`` namespace (``schema.KV_KEYS_PAGED``) — slot
        occupancy from the host mirrors plus the page pool's counters."""
        live = [self._len[s] for s in range(self.n_slots)
                if self._owner[s] is not None]
        out = {"layout": "paged",
               "slots_in_use": self.n_slots - self.n_free,
               "slots_free": self.n_free,
               # committed = allocated + reserved-unallocated, so this is
               # comparable with the dense manager's slot-capacity peak
               "peak_positions_reserved":
                   self.pool.peak_committed * self.page_size,
               "positions_live": sum(live),
               "slot_lengths": live,
               "slot_pages": {s: list(self.pool.owned.get(s, []))
                              for s in range(self.n_slots)
                              if self._owner[s] is not None}}
        out.update(self.pool.stats())
        return out

    def stats(self) -> Dict[str, object]:
        """Legacy flat projection of :meth:`metrics` (``kv_*`` keys)."""
        return {f"kv_{k}": v for k, v in self.metrics().items()}


# ======================================================================
class StateManager:
    """Facade over the slot-state manager families (DESIGN.md §12).

    One construction point that reads the config's ``state_planes()``
    descriptor and returns the right manager for its mix of layer kinds:

    * dense rings + fixed-size recurrent rows (+ the shared enc-KV
      plane) -> :class:`KVSlotManager`, which is plane-agnostic: it
      scatters/gathers whole slot rows, whatever planes they hold;
    * block-paged KV (``kv_page`` set) -> :class:`PagedKVManager`, whose
      page pool only ever holds pages for GROWING planes — a config with
      none (pure-recurrent stacks) reserves zero pages per request and
      gates admission on slots alone.

    Both families share the slot protocol the engine consumes
    (allocate / release / truncate / remaining / metrics), so the engine
    never branches on arch_type — only on which family it got.
    """

    @staticmethod
    def create(cfg: ModelConfig, n_slots: int, slot_len: int, *,
               kv_page: Optional[int] = None,
               kv_pages_total: Optional[int] = None,
               bucket: bool = True):
        if kv_page is None:
            if kv_pages_total is not None:
                raise ValueError("kv_pages_total needs kv_page (it sizes "
                                 "the paged pool)")
            return KVSlotManager(cfg, n_slots, slot_len)
        max_pages = -(-slot_len // kv_page)
        pages_total = (kv_pages_total if kv_pages_total is not None
                       else n_slots * max_pages)
        return PagedKVManager(cfg, n_slots, kv_page, pages_total,
                              max_pages, bucket=bucket)
