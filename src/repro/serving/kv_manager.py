"""Slotted KV-cache manager for continuous batching.

One preallocated decode state (``models/transformer.init_decode_state``
layout) holds ``n_slots`` independent sequences; the batch axis is the
slot table.  Requests of different lengths join and leave a *running*
batch by writing a freshly prefilled B=1 state into a free slot
(block-table indirection at slot granularity — every slot owns a
fixed-width ring of ``slot_len`` KV positions) and releasing it when the
request finishes.  Nothing else in the batch is touched: per-row ``pos``
(see ``decode_step``) keeps every slot at its own absolute position, and
ring slots carrying pos = −1 are invisible to attention, so a freed slot
needs no scrubbing before reuse — the next prefill overwrites every leaf
of that row.

The manager is deliberately model-agnostic: it treats the decode state as
an opaque pytree and only assumes the seed layout's axis convention
(``stack`` leaves carry batch at axis 1 under the scan axis, ``tail``
leaves at axis 0, ``pos`` is per-row).
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def _write_slot(big, small, i):
    """Scatter a B=1 decode state into row ``i`` of the slotted state."""
    out = dict(big)
    out["stack"] = [jax.tree.map(lambda b, s: b.at[:, i].set(s[:, 0]), bs, ss)
                    for bs, ss in zip(big["stack"], small["stack"])]
    out["tail"] = [jax.tree.map(lambda b, s: b.at[i].set(s[0]), bt, st)
                   for bt, st in zip(big["tail"], small["tail"])]
    # small pos is a scalar (unpadded prefill) or (1,) (padded prefill)
    out["pos"] = big["pos"].at[i].set(
        jnp.reshape(jnp.asarray(small["pos"]), (-1,))[0].astype(jnp.int32))
    return out


class KVSlotManager:
    """Free-list over the batch axis of one preallocated decode state."""

    def __init__(self, cfg: ModelConfig, n_slots: int, slot_len: int):
        if not cfg.attention_only_stack:
            raise ValueError(
                f"continuous batching supports causal-attention stacks; "
                f"{cfg.name} has mixers that keep cross-token state "
                f"(or an encoder) that slot writes cannot isolate")
        self.cfg = cfg
        self.n_slots = n_slots
        self.slot_len = slot_len
        state = T.init_decode_state(cfg, n_slots, slot_len)
        state["pos"] = jnp.zeros((n_slots,), jnp.int32)  # per-row positions
        self.state = state
        self._free: List[int] = list(range(n_slots))
        self._owner: List[Optional[object]] = [None] * n_slots
        # donate the big state: the write is a pure row update, so XLA
        # reuses the (KV-stack-sized) buffers instead of copying them
        self._write = jax.jit(_write_slot, donate_argnums=0)

    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    def owner(self, slot: int):
        return self._owner[slot]

    def allocate(self, owner=None) -> int:
        slot = self._free.pop(0)
        self._owner[slot] = owner
        return slot

    def release(self, slot: int) -> None:
        assert self._owner[slot] is not None, f"slot {slot} already free"
        self._owner[slot] = None
        self._free.append(slot)
        self._free.sort()

    # ------------------------------------------------------------------
    def new_row_state(self):
        """Fresh B=1 decode state of slot width — the accumulator for
        chunked admission (DESIGN.md §8): the runtime executor's
        ``prefill_chunk`` writes each chunk's KV into it at the chunk's
        offset (``pos .. pos+C−1`` ring slots), decode steps of the
        *other* rows proceed against the big slotted state in between,
        and after the final chunk :meth:`write_prefill` scatters the
        finished row in.  Because rows are disjoint, the deferred
        scatter cannot race the in-flight batch."""
        state = T.init_decode_state(self.cfg, 1, self.slot_len)
        return state

    def write_prefill(self, small_state, slot: int) -> None:
        """Install a prefilled B=1 state (``max_len == slot_len``) into
        ``slot``; the request's remaining KV budget is slot_len − pos."""
        kshape = small_state["stack"][0]["kv"]["k"].shape \
            if small_state["stack"] and "kv" in small_state["stack"][0] else None
        if kshape is not None and kshape[2] != self.state["stack"][0]["kv"]["k"].shape[2]:
            raise ValueError(
                f"prefill state width {kshape[2]} != slot width "
                f"{self.state['stack'][0]['kv']['k'].shape[2]}; prefill with "
                f"max_len == slot_len")
        self.state = self._write(self.state, small_state, slot)

    def remaining(self, slot: int) -> int:
        """Decode steps this slot can still take before its ring would
        overwrite live context (conservative for SWA stacks, where the
        window may be narrower than the slot)."""
        return self.slot_len - int(self.state["pos"][slot])
