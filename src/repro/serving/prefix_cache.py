"""Radix-style prefix index over immutable full KV pages (DESIGN.md §13).

The paper's economics — exploit reuse to dodge transfer/compute (LRU
expert caching, PAPER.md §3.1) — applied to the KV plane: requests that
share a system prompt / few-shot prefix share the *pages* holding that
prefix's KV, and their prefill starts at the divergence point.

Why this is safe without a copy-on-write fault path:

* Causal attention means the KV at position ``p`` depends only on tokens
  ``[0..p]``, and chunked prefill is bitwise-identical to whole prefill
  (tests/test_runtime.py) — so a *full* page of prompt KV is a pure
  function of the token block that produced it.  Pages are therefore
  content-addressed by token bytes along a hash chain: node key =
  ``(parent_serial, block_bytes)``.
* Only FULL pages are ever indexed, and :meth:`lookup` additionally caps
  the match at ``((len(prompt) - 1) // page_size) * page_size``: the
  final prompt token is always recomputed (its logits seed the first
  sampled token), and every KV *write* a request performs — the prefill
  tail and all decode tokens — lands at positions past the matched
  prefix, i.e. in page ordinals the request allocated privately.  "Copy
  on write" thus degenerates to "never write a shared page": divergence
  within a page simply means that page is not matched.

The cache holds one reference per indexed page (``PagePool.incref``,
taken by the caller via the ``registered`` return of :meth:`insert`);
adopters hold their own.  A page is freed — and scrubbed — only when the
last reference drops, so eviction of a node whose page is still mapped
into a live slot is safe.  Eviction is leaf-first LRU: only childless
nodes can go (an interior node's page is reachable through its
descendants' matches).

The index itself is tiny host-side bookkeeping: it never touches device
memory and is exercised allocator-only (no jax) by the property tests in
tests/test_prefix_swap.py.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["PrefixCache"]


@dataclass
class _Node:
    key: Tuple[int, bytes]
    serial: int          # monotonic id; 0 is the (virtual) root
    parent: int          # parent node's serial, 0 for depth-0 nodes
    page: int            # device page id backing this ordinal's KV
    children: int = 0
    tick: int = 0        # LRU clock


class PrefixCache:
    """Hash-chain prefix index: one node per (prefix, page ordinal)."""

    def __init__(self, page_size: int, capacity_pages: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if capacity_pages < 1:
            raise ValueError(
                f"prefix cache needs capacity >= 1 page, got "
                f"{capacity_pages} (pass 0 upstream to disable the cache)")
        self.page_size = int(page_size)
        self.capacity = int(capacity_pages)
        self._nodes: Dict[Tuple[int, bytes], _Node] = {}
        self._by_serial: Dict[int, _Node] = {}
        self._serial = itertools.count(1)
        self._tick = itertools.count(1)
        # cumulative counters (surface through the engine's collector;
        # hit-token accounting lives engine-side — a stalled admission
        # retries lookup every step and must not overcount)
        self.lookups = 0
        self.inserted_pages = 0
        self.evicted_pages = 0

    # -- helpers -------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return len(self._nodes)

    def pages(self) -> List[int]:
        """Every page id the index currently holds a reference on — the
        cache's contribution to the step-boundary refcount audit
        (DESIGN.md §14): each node is exactly one ``PagePool`` ref."""
        return [nd.page for nd in self._nodes.values()]

    def _key(self, parent: int, tokens: np.ndarray,
             ordinal: int) -> Tuple[int, bytes]:
        ps = self.page_size
        block = np.ascontiguousarray(
            tokens[ordinal * ps:(ordinal + 1) * ps], dtype=np.int32)
        return (parent, block.tobytes())

    # -- queries -------------------------------------------------------
    def lookup(self, tokens: np.ndarray) -> Tuple[int, List[int]]:
        """Longest cached full-page prefix of ``tokens``.

        Returns ``(matched_tokens, page_ids)`` with ``matched_tokens ==
        len(page_ids) * page_size``, capped so the final prompt token is
        never part of the match (the admitting request must run at least
        one prefill position to produce its first-token logits, and all
        its writes must land past the shared ordinals).
        """
        tokens = np.asarray(tokens)
        self.lookups += 1
        limit = max(0, (len(tokens) - 1) // self.page_size)
        parent, pids, path = 0, [], []
        for o in range(limit):
            node = self._nodes.get(self._key(parent, tokens, o))
            if node is None:
                break
            path.append(node)
            pids.append(node.page)
            parent = node.serial
        tick = next(self._tick)
        for node in path:          # refresh the whole matched chain
            node.tick = tick
        return len(pids) * self.page_size, pids

    # -- updates -------------------------------------------------------
    def insert(self, tokens: np.ndarray,
               page_ids: List[int]) -> Tuple[List[int], List[int]]:
        """Index ``tokens``' full-page prefix chain; ``page_ids[o]`` is
        the (already prefilled) device page backing ordinal ``o``.

        Returns ``(registered, evicted)``: the caller must ``incref``
        every registered page BEFORE releasing the evicted ones — a
        pathological capacity can evict a node registered by this very
        call.  Ordinals whose node already exists are skipped (a
        concurrent duplicate prefill keeps its private, content-equal
        pages; mixing producers along one chain is fine because page
        content is a pure function of the token prefix).
        """
        tokens = np.asarray(tokens)
        n = min(len(page_ids), len(tokens) // self.page_size)
        parent, registered = 0, []
        for o in range(n):
            key = self._key(parent, tokens, o)
            node = self._nodes.get(key)
            if node is None:
                node = _Node(key=key, serial=next(self._serial),
                             parent=parent, page=int(page_ids[o]),
                             tick=next(self._tick))
                self._nodes[key] = node
                self._by_serial[node.serial] = node
                if parent:
                    self._by_serial[parent].children += 1
                registered.append(node.page)
                self.inserted_pages += 1
            else:
                node.tick = next(self._tick)
            parent = node.serial
        evicted: List[int] = []
        while len(self._nodes) > self.capacity:
            pids = self.evict_lru()
            if not pids:
                break
            evicted.extend(pids)
        return registered, evicted

    def evict_lru(self, n_nodes: int = 1) -> List[int]:
        """Drop up to ``n_nodes`` oldest *childless* nodes; returns their
        page ids (caller decrefs — pages still adopted by live slots
        survive until their last reference drops)."""
        out: List[int] = []
        for _ in range(n_nodes):
            leaves = [nd for nd in self._nodes.values() if nd.children == 0]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: (nd.tick, nd.serial))
            del self._nodes[victim.key]
            del self._by_serial[victim.serial]
            if victim.parent:
                self._by_serial[victim.parent].children -= 1
            out.append(victim.page)
            self.evicted_pages += 1
        return out

    def stats(self) -> Dict[str, int]:
        return {"nodes": len(self._nodes),
                "cached_pages": len(self._nodes),
                "inserted_pages": self.inserted_pages,
                "evicted_pages": self.evicted_pages}
