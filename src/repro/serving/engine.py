"""Serving engines (non-offloaded accelerator path).

Two modes, both dispatching through the unified runtime
(:class:`repro.runtime.Executor` — DESIGN.md §8; no engine owns a
private copy of the block-step bodies):

* :class:`ServeEngine` — static batch: left-pads a fixed request set to a
  common length and decodes until every request finishes.  Pad positions
  are excluded from attention and from MoE dispatch capacity via the
  ``pad_mask`` threaded through the padded prefill (DESIGN.md §2).
* :class:`ContinuousEngine` — continuous batching: requests join and
  leave a *running* batch (DESIGN.md §4).  A slotted KV state
  (``serving/kv_manager``) holds ``max_slots`` sequences at independent
  positions; admission prefill runs through the executor's chunk program
  (B=1, the whole prompt as one chunk by default — bitwise identical to
  the ``generate_plain`` oracle, which runs the same program), and
  finished requests release their slot the same step.  Which waiting
  request joins next is the scheduler policy's call (expert-overlap
  grouping, ``serving/scheduler``).

  With ``prefill_chunk=C`` admission becomes **chunked prefill**
  (DESIGN.md §8): each step executes a :class:`~repro.runtime.StepPlan`
  mixing one decode token per running row with prompt chunks packed
  under a :class:`~repro.runtime.TokenBudgetPolicy` — a long prompt no
  longer head-of-line-blocks the in-flight decodes.  Chunking never
  changes a *logit* bit, so under greedy decoding the generated tokens
  are bitwise those of unchunked admission (tests/test_runtime.py);
  stochastic samplers stay distribution-identical but consume the
  engine rng stream in a different step order, so sampled streams are
  reproducible per seed, not across chunk settings.

The memory-constrained interactive mode is
``core/offload_engine.OffloadEngine`` (the paper's contribution).
:class:`ContinuousEngine` composes with it: passing a packed offload
engine (``offload=...``) switches decode to the HQQ-packed expert
buffer pool — continuous batching over offloaded experts, with the pool
shared across the running batch (DESIGN.md §6) and prefill chunks
streaming their experts straight from the host store (zero pool
traffic, DESIGN.md §8).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, parse_block
from repro.core.offload_engine import (ExpertUsageTracker, routing_from_info)
from repro.data.pipeline import EOS
from repro.obs import Telemetry, jit_cache_metrics
from repro.runtime import (Admission, ChunkTask, Executor, StepPlan,
                           TokenBudgetPolicy)
from repro.serving.faults import SITES as FAULT_SITES
from repro.serving.kv_manager import KVSlotManager, StateManager
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import (RUNNING, GenRequest, Scheduler,
                                     admission_cost)


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    completed: List[int] = field(default_factory=list)


@dataclass
class _Swapped:
    """A preempted request parked off-device (DESIGN.md §13): either its
    pages staged to host (``blob``) or dropped for recompute resume."""

    req: GenRequest
    blob: Optional[dict]  # host-staged pages; None => recompute resume
    next_tok: int         # pending (already emitted) token to feed
    n_tokens: int         # live KV positions at preemption
    seq: int              # FIFO tie-break within a priority class


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 sampler: Optional[SamplerConfig] = None):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler or SamplerConfig(kind="greedy")
        self._exec = Executor(params, cfg)

    def serve_batch(self, requests: List[Request], seed: int = 0
                    ) -> List[Request]:
        """Left-pads prompts to a common length and decodes the batch.
        The pad mask keeps shorter prompts from attending to (or spending
        MoE capacity on) pad positions; each row decodes from its own
        true length (per-row ``pos``).  Pad isolation only exists for
        causal-attention stacks — recurrent mixers fold pad tokens into
        their state, so unequal-length batches are rejected there."""
        cfg = self.cfg
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        needs_pad = any(len(r.prompt) != S for r in requests)
        if needs_pad and not cfg.attention_only_stack:
            raise ValueError(
                f"left-padded serve_batch needs a causal-attention stack; "
                f"{cfg.name}'s mixers accumulate state over pad tokens "
                f"— batch equal-length prompts for this arch")
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), bool)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad with 0
            mask[i, S - len(r.prompt):] = True
        batch = {"tokens": jnp.asarray(toks)}
        if needs_pad:
            batch["pad_mask"] = jnp.asarray(mask)
        pre_logits, state = self._exec.prefill_padded(batch, S + max_new)
        rng = jax.random.key(seed)
        rng, sub = jax.random.split(rng)
        tok = sample(sub, pre_logits[:, -1], self.sampler)
        done = np.zeros(B, bool)
        for i in range(B):
            requests[i].completed.append(int(tok[i]))
        for step in range(max_new - 1):
            logits, state, _, _ = self._exec.decode(state, tok[:, None])
            rng, sub = jax.random.split(rng)
            tok = sample(sub, logits[:, -1], self.sampler)
            for i, r in enumerate(requests):
                if done[i] or len(r.completed) >= r.max_new_tokens:
                    done[i] = True
                    continue
                t = int(tok[i])
                r.completed.append(t)
                if t == EOS:
                    done[i] = True
            if done.all():
                break
        return requests


# ======================================================================
class ContinuousEngine:
    """Continuous-batching decode loop over a slotted KV state.

    Per step: (1) admit policy-selected waiting requests into free slots
    — whole-prompt prefill (one chunk) by default, budgeted prompt
    chunks with ``prefill_chunk=C`` — (2) one batched executor decode
    step over the running slots with per-row positions, (3) sample
    (through ``serving/sampler`` with per-request temperatures), stream
    tokens to request callbacks, evict finished requests.  Free slots
    decode a dummy token whose output is ignored and whose state is
    fully overwritten at the next admission.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 4,
                 slot_len: int = 256, sampler: Optional[SamplerConfig] = None,
                 policy=None, eos_id: Optional[int] = EOS,
                 prefill_chunk: Optional[int] = None,
                 token_budget: Optional[int] = None,
                 seed: int = 0, offload=None,
                 kv_page: Optional[int] = None,
                 kv_pages_total: Optional[int] = None,
                 ragged_bucket: bool = True,
                 prefix_cache_pages: int = 0,
                 preemption: bool = False,
                 kv_host_pages: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 draft_params=None,
                 draft_cfg: Optional[ModelConfig] = None,
                 num_draft_tokens: int = 0,
                 faults=None,
                 check_invariants: bool = False,
                 queue_cap: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 fetch_retries: int = 2,
                 fetch_backoff_ms: float = 0.0):
        """``offload``: a packed :class:`~repro.core.offload_engine.
        OffloadEngine` (``quantized=True``) switches this engine into
        **offloaded decode mode** (DESIGN.md §6): experts stay HQQ-packed
        in the offload engine's host store, every decode step serves the
        batch's routed experts from the per-layer device buffer pool
        (shared across requests — the expert-overlap admission policy is
        what makes that sharing pay), and admission prefill streams
        experts from the host store chunk-wise.  ``params`` is ignored in
        that mode (the offload engine's executable params are used).

        ``prefill_chunk``: admission prompt chunk size; ``None`` = whole
        prompt per step (one chunk).  ``token_budget`` caps the tokens
        one step computes (decode rows + prefill chunks); default
        ``max_slots + prefill_chunk``.

        ``kv_page`` switches the KV plane to **block-paged storage**
        (DESIGN.md §9): KV lives in a shared pool of ``kv_pages_total``
        pages of ``kv_page`` positions (default: full provisioning,
        ``max_slots * ceil(slot_len/kv_page)``), requests reserve pages
        for their actual ``prompt + max_new`` instead of a slot_len
        ring, admission chunks write straight into the slot's pages (no
        install copy), and every decode step's attention is sliced to
        the live page horizon — cost follows live context, not slot
        width.  ``ragged_bucket=False`` pins the horizon to the full
        table, which makes paged decoding BITWISE the dense engine
        (tests/test_paged_kv.py); bucketing keeps greedy token streams
        identical while paying only for live pages.

        ``prefix_cache_pages`` (paged, causal-attention-only stacks):
        keep up to that many immutable full pages of finished prompts in
        a radix prefix index (``serving/prefix_cache``); a request whose
        prompt hits a cached prefix adopts those pages read-only and
        prefills only from the divergence point (DESIGN.md §13).  0
        disables the cache (a real ablation, not a falsy default).

        ``preemption`` (paged, causal-attention-only stacks): admission
        reserves only the prompt's pages instead of the worst case, and
        when decode growth or a higher-priority admission runs the pool
        dry a victim is *preempted* — its pages staged to a host pool of
        ``kv_host_pages`` pages (d2h) and re-staged on resume, or, when
        the host budget cannot hold them (``kv_host_pages=0`` always),
        dropped and rebuilt by re-prefilling prompt+generated.  Either
        resume path is bitwise the uninterrupted decode under greedy
        sampling.  Off by default: admission keeps the PR-5 no-
        preemption discipline and stalls until releases free pages.

        ``draft_params`` / ``draft_cfg`` / ``num_draft_tokens``: token-
        level draft-and-verify decoding (DESIGN.md §11).  With a dense
        draft model sharing the target's vocab and ``num_draft_tokens=k
        >= 1``, each step decodes every running row through one C =
        k+1 verify chunk instead of k+1 single-token steps: the draft
        proposes k tokens per row, the target verifies them in one
        chunk, the longest matching prefix plus the target's own next
        token is emitted, and both target KV (``truncate``) and draft
        state roll back past each row's rejection point.  Greedy
        sampler only; the output token streams are bitwise those of
        non-speculative decode for any draft.  ``num_draft_tokens=0``
        disables speculation regardless of the draft arguments (the
        CLI ablation path).

        ``telemetry``: a :class:`repro.obs.Telemetry` turns on the
        unified telemetry plane (DESIGN.md §10) — per-step phase timing,
        per-request span tracing and roofline accounting.  Default is
        ``Telemetry.off()``: only the pull-time collectors that back
        :meth:`metrics` / :meth:`stats` exist, the decode loop carries
        zero instrumentation, and generated tokens are bitwise identical
        either way (tests/test_obs.py).

        ``faults``: a seeded :class:`repro.serving.faults.FaultInjector`
        turns on the fault-injection plane (DESIGN.md §14): transient
        expert-fetch failures retry ``fetch_retries`` times (sleeping
        ``fetch_backoff_ms`` between attempts) then degrade to store-
        direct streaming; page-pool/swap faults exercise the admission
        and preemption stall paths; ``nan_logits`` poisons one decode
        row, which is quarantined (terminal status ``failed``) while
        every other row's tokens stay bitwise the fault-free run's.
        ``None`` (default) removes every injection check from the hot
        path.  ``check_invariants=True`` runs the full step-boundary
        accounting audit (:meth:`check_invariants`) after EVERY step.
        ``queue_cap`` bounds the admission queue — :meth:`submit` on a
        full queue returns a request already finished with terminal
        status ``rejected`` (backpressure, never unbounded growth).
        ``deadline_ms`` is the default per-request wall-clock budget;
        per-request ``deadline_ms`` / ``deadline_steps`` on submit()
        override it."""
        self.offload = offload
        if offload is not None:
            if offload._decoder is None:
                raise ValueError("offloaded decode mode needs a packed "
                                 "OffloadEngine (quantized=True)")
            if offload.cfg is not cfg and offload.cfg != cfg:
                raise ValueError("offload engine config mismatch")
            params = offload.params
            self._exec: Executor = offload._decoder
            self._pstate = self._exec.init_pool_state()
        else:
            self._exec = Executor(params, cfg)
        self.params = params
        self.cfg = cfg
        self.sampler = sampler or SamplerConfig(kind="greedy")
        self.max_slots = max_slots
        self.eos_id = eos_id
        self.paged = kv_page is not None
        # per-layer-kind state planes (DESIGN.md §12): the facade picks
        # dense rings vs paged KV for the growing "kv" layers; recurrent
        # layers keep fixed-size per-slot state either way and reserve
        # ZERO pool pages (the degenerate one-page-per-slot case)
        self.kv = StateManager.create(
            cfg, max_slots, slot_len, kv_page=kv_page,
            kv_pages_total=kv_pages_total, bucket=ragged_bucket)
        if self.paged:
            slot_len = self.kv.slot_len  # per-request cap, page-rounded
        self.slot_len = slot_len
        self.sched = Scheduler(max_slots, policy, queue_cap=queue_cap)
        # --------------------------------------------------------------
        # fault-injection plane + request-lifecycle hardening (§14)
        self.faults = faults
        self._check_inv = bool(check_invariants)
        self._deadline_ms = deadline_ms
        self._nan_quarantined = 0
        # executors can be shared (the offload engine hands over its
        # decoder) — like set_observer, the last engine to attach wins
        self._exec.set_fault_injector(faults, max_retries=fetch_retries,
                                      backoff_ms=fetch_backoff_ms)
        if hasattr(self.kv, "set_fault_injector"):
            self.kv.set_fault_injector(faults)
        # --------------------------------------------------------------
        # prefix reuse + preemption (DESIGN.md §13)
        self._prefix = None
        self._preempt = bool(preemption)
        self._swapped: List[_Swapped] = []
        self._swap_seq = 0
        self._recomputes = 0
        self._prefills_skipped = 0
        self._prefix_hit_tokens = 0
        if prefix_cache_pages or preemption or kv_host_pages:
            if not self.paged:
                raise ValueError(
                    "prefix caching / preemption need block-paged KV "
                    "(set kv_page); dense rings have no shareable or "
                    "swappable page unit")
            if not cfg.attention_only_stack:
                raise ValueError(
                    f"prefix caching / preemption need a causal-attention "
                    f"stack: {cfg.name!r} carries state (recurrent carries "
                    f"or encoder KV) that pages neither share nor swap")
            if kv_host_pages and not preemption:
                raise ValueError("kv_host_pages without preemption would "
                                 "never be used — enable preemption or "
                                 "drop the host pool")
            if preemption and num_draft_tokens:
                raise ValueError(
                    "preemption composes with plain decode only: a "
                    "draft-and-verify round holds un-verified KV that a "
                    "mid-round swap would tear")
        if prefix_cache_pages:
            from repro.serving.prefix_cache import PrefixCache
            self._prefix = PrefixCache(self.kv.page_size, prefix_cache_pages)
        if preemption:
            self.kv.enable_host_swap(kv_host_pages)
        self.prefill_chunk = prefill_chunk
        self.budget: Optional[TokenBudgetPolicy] = None
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            if prefill_chunk > slot_len:
                raise ValueError(f"prefill_chunk={prefill_chunk} exceeds "
                                 f"slot_len={slot_len} (the KV ring width)")
            self.budget = TokenBudgetPolicy(
                chunk_size=prefill_chunk,
                token_budget=token_budget or (max_slots + prefill_chunk),
                max_rows=max_slots)
        elif token_budget is not None:
            raise ValueError("token_budget needs prefill_chunk (the budget "
                             "schedules prompt chunks)")
        self._admissions: List[Admission] = []
        # routing collection costs per-step host transfers; only pay for
        # it when the admission policy actually reads the usage histogram
        # (the packed path surfaces routing for free)
        self._collect = (cfg.moe is not None
                         and (getattr(policy, "needs_usage", False)
                              or offload is not None))
        self.usage = (ExpertUsageTracker.for_config(cfg)
                      if self._collect else None)
        # greedy decode folds argmax into the jitted step and feeds the
        # token straight back on-device — the host only sees (B,) ints
        self._greedy = self.sampler.kind == "greedy"
        # request length cap: only GROWING kv planes consume positions.
        # All-SWA stacks roll their window inside the slot, so a request
        # may decode past slot_len; a pure-recurrent stack (xlstm) has
        # no growing plane at all, so NO request ever outgrows its slot.
        # Anything else must fit the slot ring; paged slots never roll
        # (pages are position-indexed), so every request must fit its
        # page reservation there.
        kv_mixers = {sp.mixer for sp in cfg.state_planes() if sp.grows}
        self._unbounded = (not self.paged
                           and (not kv_mixers
                                or (kv_mixers == {"swa"}
                                    and cfg.sliding_window
                                    and slot_len >= cfg.sliding_window)))
        self._has_rec = cfg.has_recurrent_layers
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.step_count = 0
        self._rng = jax.random.key(seed)
        # telemetry plane (DESIGN.md §10): collectors are registered even
        # in the off mode (they only run at snapshot time and back the
        # legacy stats() projection); timing/tracing/roofline attach only
        # when an enabled Telemetry is passed in
        self.obs = telemetry if telemetry is not None else Telemetry.off()
        reg = self.obs.registry
        reg.register_collector("engine", self._engine_metrics)
        reg.register_collector("kv", self.kv.metrics)
        reg.register_collector("jit", jit_cache_metrics)
        # always present — chaos and clean runs share one schema (all
        # fire counts are simply zero without an injector)
        reg.register_collector("faults", self._faults_metrics)
        if offload is not None:
            reg.register_collector("offload", self._offload_metrics)
        if self._prefix is not None:
            reg.register_collector("prefix", self._prefix_metrics)
        if self._preempt:
            reg.register_collector("kv_host", self._kv_host_metrics)
        if self.obs.timing:
            self.obs.declare_step_schema()
            self.obs.declare_request_schema()
            # executors can be shared (the offload engine hands over its
            # decoder) — the last engine to attach an observer wins
            self._exec.set_observer(self.obs.exec_observer(self._exec.plane))
            if offload is not None:
                q = offload.size_report is not None
                self.obs.attach_roofline(
                    cfg,
                    expert_bits=offload.spec.expert_bits if q else 16,
                    attn_bits=offload.spec.attn_bits if q else 16,
                    expert_bytes=offload.expert_bytes,
                    # the same tiny counts array stats() already fetches,
                    # read once per roofline window — never per step
                    h2d_counts_fn=lambda: tuple(
                        int(c) for c in np.asarray(self._pstate.counts)))
            else:
                self.obs.attach_roofline(cfg)
        # --------------------------------------------------------------
        # token-level draft-and-verify decoding (DESIGN.md §11)
        self.spec_k = int(num_draft_tokens or 0)
        self._spec_metrics = None
        if self.spec_k > 0:
            if draft_params is None or draft_cfg is None:
                raise ValueError("num_draft_tokens >= 1 needs draft_params "
                                 "and draft_cfg (the dense draft model)")
            if not self._greedy:
                raise ValueError(
                    "draft-and-verify decoding is greedy-only: the "
                    "acceptance rule compares the target's argmax stream")
            if self._has_rec and (self.paged or offload is not None):
                raise ValueError(
                    f"draft-and-verify on {cfg.name!r} needs the dense "
                    f"non-offloaded engine: recurrent carries roll back "
                    f"by snapshot-and-restore of the pre-round row state, "
                    f"which the paged page-table trim and the packed "
                    f"offload step don't carry")
            # a wrapped ring cannot roll back: a rejected verify-chunk
            # write would overwrite the live entry W positions back.
            # Bound every request to the narrowest ring width instead of
            # letting SWA slots roll (dense rings are min(slot_len,
            # window) wide; paged KV is position-indexed and never
            # wraps, so its cap stays the page reservation)
            self._unbounded = False
            self._spec_cap = slot_len
            if (not self.paged and cfg.sliding_window
                    and any(parse_block(k)[0] == "swa"
                            for k in cfg.block_pattern)):
                self._spec_cap = min(slot_len, cfg.sliding_window)
            if draft_cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab_size} != target vocab "
                    f"{cfg.vocab_size}; draft and target must share tokens")
            if not draft_cfg.attention_only_stack or draft_cfg.moe is not None:
                raise ValueError(
                    f"draft {draft_cfg.name!r} must be a dense causal-"
                    f"attention stack (rollback = pos reset; an MoE draft "
                    f"would compete for the h2d bus)")
            self._draft_exec = Executor(draft_params, draft_cfg)
            # draft ring gets k positions of headroom: rejected draft
            # self-feeds land past the canonical stream and must never
            # wrap onto live context
            self._draft_kv = KVSlotManager(draft_cfg, max_slots,
                                           slot_len + self.spec_k)
            self._draft_consumed = np.zeros(max_slots, np.int64)
            # which request's draft state each slot row holds — draft
            # admission is lazy (first speculative step touching the row)
            self._draft_rid = np.full(max_slots, -1, np.int64)
            from repro.obs import SpecMetrics
            self._spec_metrics = SpecMetrics(self.obs.registry)
            self._spec_last_h2d = 0.0

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 32, on_token=None,
               on_finish=None, temperature: Optional[float] = None,
               extras: Optional[dict] = None,
               priority: int = 0,
               deadline_ms: Optional[float] = None,
               deadline_steps: Optional[int] = None) -> GenRequest:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0, "empty prompt"
        if self._preempt:
            # optimistic admission must still terminate: a request whose
            # worst case exceeds the WHOLE pool could preempt every other
            # request and still deadlock mid-decode
            worst = self.kv.pool.pages_for(prompt.size + max_new_tokens)
            if worst > self.kv.pool.n_pages:
                raise ValueError(
                    f"request needs {worst} pages > pool total "
                    f"{self.kv.pool.n_pages}; even preemption cannot "
                    f"make it fit")
        if self.cfg.is_encoder_decoder:
            if not extras or "audio_embeds" not in extras:
                raise ValueError(
                    f"{self.cfg.name} is encoder-decoder: submit() needs "
                    f"extras={{'audio_embeds': (S_e, d_model)}} — encoded "
                    f"once at admission into the read-only shared "
                    f"encoder-KV plane (DESIGN.md §12)")
            ae = np.asarray(extras["audio_embeds"], np.float32)
            if ae.ndim == 2:
                ae = ae[None]
            if ae.shape[0] != 1 or ae.shape[1] != self.cfg.encoder_seq:
                raise ValueError(
                    f"audio_embeds must be (S_e={self.cfg.encoder_seq}, "
                    f"d_model) for one request; got {ae.shape}")
            extras = dict(extras, audio_embeds=ae)
        if temperature is not None and self._greedy:
            raise ValueError(
                "per-request temperature needs a stochastic sampler; this "
                "engine decodes greedily (argmax ignores temperature) — "
                "construct it with sampler=SamplerConfig(kind='categorical'"
                "/'topk'/'topp')")
        cap = (self.slot_len if self._spec_metrics is None
               else self._spec_cap)
        if not self._unbounded and prompt.size + max_new_tokens > cap:
            detail = (f"slot_len={self.slot_len}" if cap == self.slot_len
                      else f"the speculative ring cap {cap} (= min(slot_"
                           f"len, sliding_window); a wrapped ring cannot "
                           f"roll back rejected verify chunks)")
            raise ValueError(
                f"request needs {prompt.size + max_new_tokens} KV "
                f"positions > {detail}")
        req = GenRequest(prompt=prompt, max_new_tokens=max_new_tokens,
                         arrival=self.step_count, on_token=on_token,
                         on_finish=on_finish, temperature=temperature,
                         extras=extras, priority=priority,
                         deadline_ms=(deadline_ms if deadline_ms is not None
                                      else self._deadline_ms),
                         deadline_steps=deadline_steps,
                         submit_ns=time.perf_counter_ns())
        self.obs.req_submitted(req.rid, self.step_count)
        if not self.sched.submit(req):
            # bounded admission queue is full: reject with backpressure —
            # the request is terminal NOW, never retained (DESIGN.md §14)
            req.finish("rejected")
            self.obs.req_finished(req.rid, 0, "rejected")
            return req
        return req

    # ------------------------------------------------------------------
    def _sample_rows(self, logits, reqs: List[GenRequest]) -> np.ndarray:
        """logits (B, V) for exactly ``reqs`` rows -> (B,) int32."""
        if self.sampler.kind == "greedy":
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        temps = None
        if any(r.temperature is not None for r in reqs):
            temps = np.asarray(
                [self.sampler.temperature if r.temperature is None
                 else r.temperature for r in reqs], np.float32)
        self._rng, sub = jax.random.split(self._rng)
        return np.asarray(sample(sub, logits, self.sampler,
                                 temperature=temps))

    # ------------------------------------------------------------------
    # admission
    def _start_admissions(self) -> None:
        """Move policy-selected waiting requests into slots; their
        prompts prefill as chunks over the coming steps (or this step,
        when unchunked).  Paged mode additionally gates admission on the
        page pool: the policy's pick must be able to reserve its
        worst-case ``ceil((prompt+max_new)/page_size)`` pages, else
        admission stalls until releases free pages (head-of-line on
        memory — the no-preemption discipline, DESIGN.md §9).

        With a prefix cache, the pick's cached full-page prefix counts
        as admission credit (those pages are adopted, not allocated) and
        its prefill starts at the divergence point.  With preemption,
        preempted requests resume FIRST (head-of-line: fresh arrivals
        must not starve a swapped request), admission reserves only
        ``prompt+1`` tokens of pages, and a stalled pick may swap out a
        strictly lower-priority victim (DESIGN.md §13)."""
        while self.kv.n_free and (self.sched.has_waiting or self._swapped):
            if self.paged:
                if self._swapped:
                    sw = min(self._swapped,
                             key=lambda s: (-s.req.priority, s.seq))
                    pick = (self.sched.peek_next(self.usage)[1]
                            if self.sched.has_waiting else None)
                    if pick is None or sw.req.priority >= pick.priority:
                        if not self._try_resume():
                            break  # no new admissions past a stuck resume
                        continue
                    # else: the strictly higher-priority arrival admits
                    # first — resuming its own preemption victim here
                    # would hand back the pages _make_room just freed
                    # for it and ping-pong forever
                if not self.sched.has_waiting:
                    break
                idx, cand = self.sched.peek_next(self.usage)
                # per-arch admission cost (scheduler.admission_cost):
                # only growing kv planes claim pool positions — a pure-
                # recurrent stack reserves ZERO pages however long the
                # request runs, so its admission can never stall on the
                # pool (only on free slots)
                need = admission_cost(self.cfg, len(cand.prompt),
                                      cand.max_new_tokens).kv_positions
                base, shared = self._prefix_lookup(cand.prompt)
                # optimistic reservation under preemption: the prompt
                # plus one decode position; growth claims pages step by
                # step (_grow_running_rows) and preempts when dry
                reserve = (len(cand.prompt) + 1
                           if self._preempt and need else need)
                if not self.kv.can_admit(reserve, len(shared)):
                    if self._make_room(cand):
                        continue  # re-peek: eviction may drop cached pids
                    break
                req = self.sched.pop_at(idx)
                self.obs.req_admitted(req.rid, self.step_count - req.arrival)
                slot = self.kv.allocate(req.rid, reserve,
                                        shared_pages=shared, base=base)
                req.slot = slot
                self._note_prefix_hit(base)
                if self.cfg.is_encoder_decoder:
                    # admission-time encode: the shared encoder-KV plane
                    # is written once into the slot and only READ by
                    # every decode step after (never scattered to)
                    self.kv.write_enc_kv(
                        slot, self._exec.encode(req.extras["audio_embeds"]))
                # no accumulator state: chunks write the slot's pages
                self._admissions.append(Admission(
                    rid=req.rid, slot=slot, total=len(req.prompt),
                    next_lo=base, state=None, req=req))
                continue
            req = self.sched.pop_next(self.usage)
            self.obs.req_admitted(req.rid, self.step_count - req.arrival)
            slot = self.kv.allocate(req.rid)
            req.slot = slot
            state = self.kv.new_row_state()
            if self.cfg.is_encoder_decoder:
                # B=1 encode at admission; installed into the slot with
                # the rest of the row state by write_prefill
                state["enc_kv"] = self._exec.encode(
                    req.extras["audio_embeds"])
            self._admissions.append(Admission(
                rid=req.rid, slot=slot, total=len(req.prompt),
                state=state, req=req))

    # ------------------------------------------------------------------
    # prefix reuse + preemption (DESIGN.md §13)
    def _prefix_lookup(self, tokens):
        if self._prefix is None:
            return 0, []
        return self._prefix.lookup(np.asarray(tokens))

    def _note_prefix_hit(self, base: int) -> None:
        if not base:
            return
        self._prefills_skipped += 1
        self._prefix_hit_tokens += base
        if self.obs.roofline is not None:
            self.obs.roofline.add_prefix_hit(base)

    def _prefix_insert(self, tokens, slot: int) -> None:
        """Index the slot's full prompt pages after its prefill finished
        (the pages are immutable from here on: all further writes land
        past the last full page ordinal).  Registered pages gain a cache
        reference BEFORE capacity evictions are released — the order
        matters when the insert itself overflows the capacity."""
        n_full = len(tokens) // self.kv.page_size
        if not n_full:
            return
        pids = self.kv.pool.owned[slot][:n_full]
        registered, evicted = self._prefix.insert(np.asarray(tokens), pids)
        for pid in registered:
            self.kv.pool.incref(pid)
        if evicted:
            self.kv.free_cached_pages(evicted)

    def _evict_prefix_pages(self) -> int:
        """Evict LRU prefix entries until DEVICE pages actually free (a
        node whose page other slots still adopt frees nothing); returns
        the number freed, 0 when the cache is exhausted."""
        if self._prefix is None:
            return 0
        while True:
            pids = self._prefix.evict_lru()
            if not pids:
                return 0
            freed = self.kv.free_cached_pages(pids)
            if freed:
                return len(freed)

    def _pick_victim(self, exclude_slot: Optional[int] = None,
                     max_priority: Optional[int] = None
                     ) -> Optional[GenRequest]:
        """Lowest-priority, latest-arrival running row (admitting rows
        excluded — a half-prefilled slot has nothing consistent to
        swap).  ``max_priority`` restricts to STRICTLY lower priorities:
        an admission/resume never preempts its own class (no ping-pong);
        decode growth passes no floor (it must proceed)."""
        admitting = {a.rid for a in self._admissions}
        cands = [r for r in self.sched.running
                 if r.rid not in admitting and r.slot != exclude_slot
                 and (max_priority is None or r.priority < max_priority)]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority, -r.arrival, -r.rid))

    def _preempt_req(self, req: GenRequest) -> None:
        """Swap a running row out: stage its pages to the host pool
        (d2h) when the budget holds them, else drop them for recompute
        resume; either way the device pages free for the beneficiary."""
        slot = req.slot
        n_live = self.kv.length(slot)
        blob = self.kv.swap_out(slot)  # None => drop + recompute
        if blob is not None and self.obs.roofline is not None:
            self.obs.roofline.add_swap_bytes(
                blob["n_pages"] * self.kv.page_nbytes())
        self.kv.release(slot)
        self.sched.preempt(req)
        self._swap_seq += 1
        self._swapped.append(_Swapped(
            req=req, blob=blob, next_tok=int(self.tokens[slot, 0]),
            n_tokens=n_live, seq=self._swap_seq))
        req.slot = None

    def _make_room(self, cand: Optional[GenRequest] = None) -> bool:
        """Free device pages for a stalled admission/resume: prefix-
        cache eviction first (cheapest — cached pages are speculative
        capital), then a strictly-lower-priority victim swap when
        preemption is on.  Returns True when pages were freed."""
        if self._evict_prefix_pages():
            return True
        if not self._preempt:
            return False
        victim = self._pick_victim(
            max_priority=cand.priority if cand is not None else None)
        if victim is None:
            return False
        self._preempt_req(victim)
        return True

    def _try_resume(self) -> bool:
        """Re-admit the best swapped request (priority, then preemption
        order).  Host-swapped pages scatter back verbatim (h2d) and the
        row decodes on; dropped KV re-prefills prompt+generated[:-1]
        through the normal admission machinery (with prefix credit) and
        feeds the pending token instead of sampling — bitwise either
        way under greedy decode."""
        sw = min(self._swapped, key=lambda s: (-s.req.priority, s.seq))
        req = sw.req
        if (sw.blob is not None and self.faults is not None
                and self.faults.fires("swap_in")):
            # h2d restage failed: drop the staged pages and fall to the
            # degrade rung below blob resume — recompute (DESIGN.md §14)
            self.kv.discard_blob(sw.blob)
            sw.blob = None
        if sw.blob is not None:
            while not self.kv.can_admit(sw.n_tokens + 1):
                if not self._make_room(req):
                    return False
            slot = self.kv.swap_in(req.rid, sw.blob, sw.n_tokens + 1)
            if self.obs.roofline is not None:
                self.obs.roofline.add_swap_bytes(
                    sw.blob["n_pages"] * self.kv.page_nbytes())
            req.slot = slot
            self.sched.resume(req)
            self.tokens[slot, 0] = sw.next_tok
            self._swapped.remove(sw)
            return True
        ext = np.concatenate(
            [req.prompt, np.asarray(req.generated[:-1], np.int32)])
        n_live = len(ext)
        base, shared = self._prefix_lookup(ext)
        while not self.kv.can_admit(n_live + 1, len(shared)):
            if not self._make_room(req):
                return False
            base, shared = self._prefix_lookup(ext)  # eviction-safe redo
        slot = self.kv.allocate(req.rid, n_live + 1,
                                shared_pages=shared, base=base)
        req.slot = slot
        self.sched.resume(req)
        self._recomputes += 1
        self._note_prefix_hit(base)
        self._admissions.append(Admission(
            rid=req.rid, slot=slot, total=n_live, next_lo=base,
            state=None, req=req, tokens=ext, resume_tok=sw.next_tok))
        self._swapped.remove(sw)
        return True

    def _grow_running_rows(self) -> None:
        """Preemption mode: secure every running row's next decode
        position BEFORE the step plan forms — a mid-step preemption
        would tear rows the plan already scheduled.  Rows grow
        best-first (priority desc, arrival asc) so the rows not yet
        grown are exactly the preferred victims."""
        admitting = {a.rid for a in self._admissions}
        rows = sorted((r for r in self.sched.running
                       if r.rid not in admitting),
                      key=lambda r: (-r.priority, r.arrival, r.rid))
        for req in rows:
            if req.state != RUNNING:
                continue  # already taken as an earlier row's victim
            n = self.kv.length(req.slot) + 1
            while not self.kv.can_grow(req.slot, n):
                if self._evict_prefix_pages():
                    continue
                victim = self._pick_victim(exclude_slot=req.slot)
                if victim is None:
                    raise RuntimeError(
                        "page pool exhausted with nothing left to "
                        "preempt (submit() guards that a lone request "
                        "always fits the pool)")
                self._preempt_req(victim)
            self.kv.grow(req.slot, n)

    def _run_chunks(self, chunks) -> List[GenRequest]:
        """Execute this step's prefill chunks; complete admissions whose
        final chunk ran (sample the first token, then install the row).

        Budgeted mode defers the ``write_prefill`` install to the START
        of the next step (``_install_ready``): this step's batched
        decode runs over every slot, and a freshly-written row that is
        not in the planned decode rows would otherwise be silently
        advanced — KV written, pos bumped, token discarded — skipping
        one output token.  Unchunked mode installs immediately because
        the recomputed decode rows include the new row the same step
        (legacy admission timing)."""
        finished = []
        by_rid = {a.rid: a for a in self._admissions}
        for task in chunks:
            adm = by_rid[task.rid]
            req: GenRequest = adm.req
            t0 = (self.obs.clock_ns()
                  if self.obs.tracer is not None else 0)
            # recompute-resume admissions prefill prompt+generated[:-1]
            # instead of the prompt (DESIGN.md §13)
            src = adm.tokens if adm.tokens is not None else req.prompt
            tokens = jnp.asarray(src[None, task.lo: task.hi])
            if self.paged:
                # chunk writes straight into the slot's pool pages —
                # allocate up to the chunk's end, then adopt the state
                # (view(): chunks see the full, freshly-synced table)
                self.kv.ensure(adm.slot, task.hi)
                logits, new_state = self._exec.prefill_chunk_row(
                    self.kv.view(), tokens, adm.slot)
                self.kv.adopt(new_state)
                self.kv.note_tokens(adm.slot, task.hi)
            else:
                logits, adm.state, _ = self._exec.prefill_chunk(
                    adm.state, tokens)
            self.obs.req_chunk(req.rid, task.lo, task.hi, t0)
            adm.next_lo = task.hi
            if task.last:
                if self.paged and self._prefix is not None:
                    # the prefilled full pages are immutable from here on
                    # — index them BEFORE any release path below so the
                    # cache reference outlives the slot
                    self._prefix_insert(src, adm.slot)
                if adm.resume_tok is not None:
                    # recompute resume: the pending token was emitted
                    # before preemption — feed it, never re-sample it
                    self.tokens[adm.slot, 0] = int(adm.resume_tok)
                    self._admissions.remove(adm)
                    continue
                if self.faults is not None:
                    # a genuinely-poisoned prefill fails at its first
                    # sample, before the row ever joins the decode batch
                    row = np.asarray(logits[:, -1])
                    if not np.isfinite(row).all():
                        self._nan_quarantined += 1
                        self._admissions.remove(adm)
                        self._fail_row(req, "nan")
                        finished.append(req)
                        continue
                first = int(self._sample_rows(logits[:, -1], [req])[0])
                req.emit(first)
                if self._done(req, first):
                    self._admissions.remove(adm)
                    self.kv.release(adm.slot)
                    self.sched.evict(req, self._reason(req, first))
                    self.obs.req_finished(req.rid, len(req.generated),
                                          req.finish_reason)
                    finished.append(req)
                    continue
                self.obs.req_decode_start(req.rid)
                self.tokens[adm.slot, 0] = first
                if self.paged:
                    # KV is already in place — the row joins the decode
                    # rows as soon as the plan includes it (this step
                    # when unchunked, next step's plan under a budget:
                    # the same timing the dense install path produces)
                    self._admissions.remove(adm)
                elif self.budget is None:
                    self.kv.write_prefill(adm.state, adm.slot)
                    self._admissions.remove(adm)
                # else: adm.done marks it ready; installed next step
        return finished

    def _install_ready(self) -> None:
        """Install admissions whose final chunk ran last step (budgeted
        mode): scatter the finished B=1 state into the slot; the row
        enters this step's decode rows."""
        for adm in [a for a in self._admissions if a.done]:
            self.kv.write_prefill(adm.state, adm.slot)
            self._admissions.remove(adm)

    def _plan(self) -> StepPlan:
        """This step's mixed batch: every decodable row + prompt chunks
        under the token budget (unchunked mode: whole prompts this step,
        split only at the KV ring width, no budget)."""
        if self._preempt:
            # secure every running row's next decode position BEFORE the
            # plan forms — preempting a row the plan already scheduled
            # would tear the step (DESIGN.md §13)
            self._grow_running_rows()
        self._install_ready()
        self._start_admissions()
        decode_rows = self._decode_rows()
        if self.budget is not None:
            return self.budget.plan(decode_rows, self._admissions)
        plan = StepPlan(decode_rows=decode_rows)
        for adm in self._admissions:
            # whole prompt as one chunk; prompts longer than the ring
            # (unbounded SWA) split at slot_len so chunk writes never
            # overlap themselves
            for lo in range(adm.next_lo, adm.total, self.slot_len):
                hi = min(lo + self.slot_len, adm.total)
                plan.chunks.append(ChunkTask(rid=adm.rid, slot=adm.slot,
                                             lo=lo, hi=hi,
                                             last=hi >= adm.total))
        return plan

    def _decode_rows(self) -> List[int]:
        admitting = {a.rid for a in self._admissions}
        return sorted(r.slot for r in self.sched.running
                      if r.rid not in admitting)

    def _done(self, req: GenRequest, tok: int) -> bool:
        return (len(req.generated) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))

    def _reason(self, req: GenRequest, tok: int) -> str:
        return ("eos" if self.eos_id is not None and tok == self.eos_id
                else "length")

    # ------------------------------------------------------------------
    # request-lifecycle hardening (DESIGN.md §14)
    def _fail_row(self, req: GenRequest, reason: str) -> None:
        """Terminal exit for a RUNNING row: release its slot (pages
        decref-then-free, draft ring unbound) before the scheduler sees
        the eviction — the release order every normal finish uses."""
        self.kv.release(req.slot)
        if self.spec_k > 0:
            self._draft_rid[req.slot] = -1
        self.sched.evict(req, reason)
        self.obs.req_finished(req.rid, len(req.generated), reason)

    def _terminate(self, rid: int, reason: str) -> bool:
        """Tear one in-flight request down wherever it currently lives —
        waiting queue, mid-prefill admission, running row, or swapped
        out — releasing paged KV, draft-ring binding and (recompute
        path) host-staged pages without leaking.  Prefix-cache refs the
        request's prompt REGISTERED survive by design: cached pages are
        the cache's capital, not the request's."""
        for req in self.sched.waiting:
            if req.rid == rid:
                self.sched.drop(req, reason)
                self.obs.req_finished(rid, len(req.generated), reason)
                return True
        # mid-prefill: the request is in sched.running WITH an admission
        # record — tear the admission first so the chunk plan forgets it
        for adm in self._admissions:
            if adm.rid == rid:
                self._admissions.remove(adm)
                self._fail_row(adm.req, reason)
                return True
        for req in self.sched.running:
            if req.rid == rid:
                self._fail_row(req, reason)
                return True
        for sw in self._swapped:
            if sw.req.rid == rid:
                self._swapped.remove(sw)
                if sw.blob is not None:
                    self.kv.discard_blob(sw.blob)
                self.sched.drop(sw.req, reason)
                self.obs.req_finished(rid, len(sw.req.generated), reason)
                return True
        return False

    def cancel(self, rid: int) -> bool:
        """Client abandonment (DESIGN.md §14): terminal status
        ``cancelled``, callable between steps.  Returns False when the
        rid is unknown or already terminal.  Every resource the request
        held — KV slot/pages, draft-ring row, host-swap blob — is
        released; the surviving requests' token streams are bitwise
        those of a run where this request never existed (greedy
        sampling; tests/test_faults.py)."""
        return self._terminate(rid, "cancelled")

    def _expire_deadlines(self) -> None:
        """Fail requests past their wall-clock (``deadline_ms``) or
        engine-step (``deadline_steps``) budget, wherever they live.
        Runs at the top of every step — a deadline can expire while the
        request is still queued, mid-prefill, decoding, or swapped."""
        cands = [r for r in (self.sched.waiting + self.sched.running
                             + [sw.req for sw in self._swapped])
                 if r.deadline_ms is not None or r.deadline_steps is not None]
        if not cands:
            return
        now = time.perf_counter_ns()
        for req in cands:
            over = (req.deadline_steps is not None
                    and self.step_count - req.arrival >= req.deadline_steps)
            if (not over and req.deadline_ms is not None
                    and req.submit_ns is not None):
                over = (now - req.submit_ns) > req.deadline_ms * 1e6
            if over:
                self._terminate(req.rid, "deadline")

    def _quarantine(self, last: np.ndarray,
                    reqs: List[GenRequest],
                    finished: List[GenRequest]) -> List[GenRequest]:
        """Poison injection + NaN/Inf row quarantine (DESIGN.md §14).
        ``last`` is the step's host-side (max_slots, V) last-position
        logits; an injected ``nan_logits`` fault poisons the lowest-rid
        decode row.  Poisoned rows fail (reason ``nan`` → status
        ``failed``) and release their state; survivors' logits are
        untouched, so their argmax stays bitwise the fault-free run."""
        if reqs and self.faults.fires("nan_logits"):
            victim = min(reqs, key=lambda r: r.rid)
            last[victim.slot, :] = np.nan
        finite = np.isfinite(last).all(axis=-1)
        bad = [r for r in reqs if not finite[r.slot]]
        for req in bad:
            self._nan_quarantined += 1
            self._fail_row(req, "nan")
            finished.append(req)
        if bad:
            reqs = [r for r in reqs if finite[r.slot]]
        return reqs

    def _audit_step(self) -> None:
        if self._check_inv:
            self.check_invariants()
        else:
            self.sched.check_invariants()

    def check_invariants(self) -> None:
        """Step-boundary accounting audit (DESIGN.md §14): scheduler
        state-list consistency, the KV manager's free/live partition and
        exact per-page refcounts (prefix-cache refs included), the draft
        ring's slot ledger, and host-pool occupancy == the pages staged
        by currently-swapped requests.  Cheap host-side bookkeeping only
        — never a device fetch — but O(pages), so it is opt-in
        (``check_invariants=True``) outside tests."""
        self.sched.check_invariants()
        cache_pages = self._prefix.pages() if self._prefix is not None else ()
        self.kv.check_invariants(cache_pages)
        if self._spec_metrics is not None:
            self._draft_kv.check_invariants()
        host = getattr(self.kv, "host", None)
        if host is not None:
            staged = sum(sw.blob["n_pages"] for sw in self._swapped
                         if sw.blob is not None)
            assert host.in_use == staged, \
                f"host pool holds {host.in_use} pages but swapped " \
                f"requests staged {staged}"

    # ------------------------------------------------------------------
    def step(self) -> List[GenRequest]:
        """One engine step: run the step plan (prefill chunks + one
        batched decode over the planned rows).  Returns requests
        finished this step."""
        if self.faults is not None and self.faults.fires("slow_step"):
            # injected stall: a slow step must trip wall-clock deadlines
            # (checked right below) exactly like a real device hiccup
            time.sleep(self.faults.stall_ms() / 1e3)
        self._expire_deadlines()
        st = self.obs.step_begin(self.step_count)
        plan = self._plan()
        if st is not None:
            st.mark("plan")
        finished = self._run_chunks(plan.chunks)
        if st is not None:
            st.mark("chunk")
        # unchunked admission keeps the legacy timing: a request admitted
        # this step decodes this step.  Budgeted (chunked) steps decode
        # exactly the planned rows so the budget accounting stays exact.
        rows = (self._decode_rows() if self.budget is None
                else plan.decode_rows)
        if not rows:
            if plan.chunks:
                self.step_count += 1
                self._audit_step()
            self.obs.step_end(st, n_chunks=len(plan.chunks))
            return finished
        reqs = sorted((r for r in self.sched.running
                       if r.slot in set(rows)), key=lambda r: r.slot)
        if self._spec_metrics is not None:
            # one draft-and-verify round for the whole batch; k is
            # clipped so no row can emit past its budget (a row with one
            # token left falls the batch back to the plain step below —
            # which is what non-speculative decode would run anyway)
            k_round = min([self.spec_k]
                          + [r.max_new_tokens - len(r.generated) - 1
                             for r in reqs])
            if k_round >= 1:
                return self._step_speculative(st, plan, finished, rows,
                                              reqs, k_round)
        active = np.zeros((self.max_slots,), bool)
        active[rows] = True
        if self.paged:
            # page for each row's write position, then slice the table
            # to the live horizon: attention pays for live context, not
            # slot capacity (DESIGN.md §9)
            for r in rows:
                self.kv.ensure(r, self.kv.length(r) + 1)
            step_state = self.kv.view(self.kv.live_width(rows))
            act_dev = jnp.asarray(active)
        else:
            step_state = self.kv.state
            act_dev = None
        # fault mode decodes to host-side LOGITS on both planes so
        # poisoned rows can be quarantined before sampling; the plain
        # plane switches from the fused-argmax step to the gather
        # program — the oracle's own decode, so survivor logits (hence
        # their argmax) carry the very values the fused step reduces
        quar = self.faults is not None
        if self.offload is not None:
            # offloaded decode: layerwise packed step over the slotted
            # state; free slots bypass the expert pool (active mask), so
            # their dummy tokens never pollute the cache or the stats
            logits, state, self._pstate, route_ids = self._exec.decode(
                step_state, jnp.asarray(self.tokens), self._pstate,
                jnp.asarray(active))
            if self._collect:
                self.usage.update([np.asarray(i) for i in route_ids],
                                  rows=rows)
            nxt_dev = (jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                       if self._greedy and not quar else logits[:, -1])
        elif quar:
            out = self._exec.decode(
                step_state, jnp.asarray(self.tokens), active=act_dev,
                collect_info=self._collect)
            if self._collect:
                logits, state, _, (info_stack, _) = out
                ids, _ = routing_from_info(self.cfg, info_stack,
                                           want_hiddens=False)
                self.usage.update(ids, rows=rows)
            else:
                logits, state, _, _ = out
            nxt_dev = logits[:, -1]
        else:
            out = self._exec.decode_sampled(
                step_state, jnp.asarray(self.tokens),
                collect_info=self._collect, greedy=self._greedy,
                active=act_dev)
            if self._collect:
                nxt_dev, state, (info_stack, _) = out
                ids, _ = routing_from_info(self.cfg, info_stack,
                                           want_hiddens=False)
                self.usage.update(ids, rows=rows)
            else:
                nxt_dev, state = out
        if st is not None:
            st.mark("dispatch")
        if self.paged:
            self.kv.adopt(state)
            for r in rows:
                self.kv.note_tokens(r, self.kv.length(r) + 1)
        else:
            self.kv.state = state
        if quar:
            # (max_slots, V) host fetch: the price of inspecting logits
            # before sampling — paid only when an injector is attached
            # (copied: the poison write needs a writable buffer)
            last = np.asarray(nxt_dev).copy()
            if st is not None:
                st.mark("sync")
            reqs = self._quarantine(last, reqs, finished)
            nxt = np.zeros((self.max_slots,), np.int32)
            if reqs:
                srows = [r.slot for r in reqs]
                if self._greedy:
                    nxt[srows] = np.argmax(
                        last[srows], axis=-1).astype(np.int32)
                else:
                    nxt[srows] = self._sample_rows(
                        jnp.asarray(last[srows]), reqs)
                    if st is not None:
                        st.mark("sample")
        elif self._greedy:
            # the step's one blocking device fetch — everything the
            # device is still computing lands in this phase
            nxt = np.asarray(nxt_dev)
            if st is not None:
                st.mark("sync")
        else:
            nxt = self._sample_rows(
                jnp.asarray(nxt_dev)[np.asarray(rows)], reqs)
            full = np.zeros((self.max_slots,), np.int32)
            full[np.asarray(rows)] = nxt
            nxt = full
            if st is not None:
                st.mark("sample")
        for req in reqs:
            t = int(nxt[req.slot])
            req.emit(t)
            if self._done(req, t):
                self.kv.release(req.slot)
                self.sched.evict(req, self._reason(req, t))
                self.obs.req_finished(req.rid, len(req.generated),
                                      req.finish_reason)
                finished.append(req)
            else:
                self.tokens[req.slot, 0] = t
        self.step_count += 1
        self._audit_step()
        if st is not None:
            st.mark("host")
            # live context from host-side request records — never a
            # device fetch (the dense manager's pos lives on device)
            ctx = (sum(len(r.prompt) + len(r.generated) for r in reqs)
                   / max(1, len(reqs)))
            self.obs.step_end(st, n_decode=len(reqs),
                              n_chunks=len(plan.chunks), context_len=ctx)
        return finished

    # ------------------------------------------------------------------
    # token-level draft-and-verify decoding (DESIGN.md §11)
    def _draft_admit(self, req: GenRequest) -> None:
        """Bind a running request to its slot's draft-state row: B=1
        draft prefill over the prompt, scattered in at the draft ring
        width.  Lazy — runs at the first speculative step that touches
        the row, so requests admitted under any admission mode (whole,
        chunked, paged) pick up draft state identically."""
        slot = req.slot
        _, st, _ = self._draft_exec.prefill(
            jnp.asarray(req.prompt[None]), self._draft_kv.slot_len)
        self._draft_kv.write_prefill(st, slot)
        self._draft_consumed[slot] = req.prompt.size
        self._draft_rid[slot] = req.rid

    def _draft_propose(self, reqs: List[GenRequest], k: int) -> Dict[int, List[int]]:
        """Batched draft catch-up + proposal: every row first consumes
        its canonical tail (the tokens emitted since the draft last saw
        the stream), then proposes k greedy tokens, feeding itself the
        first k−1.  Rows run in lockstep (B = max_slots sub-steps); a
        row that finishes early dummy-feeds one position PAST its last
        real feed (dead under the validity mask, overwritten when a real
        token lands there).  The draft state's ``pos`` is host-
        authoritative: it is rebuilt from ``_draft_consumed`` before
        every sub-step, which is also what rolls rejected feeds back."""
        kvd = self._draft_kv
        queues: Dict[int, List[int]] = {}
        total: Dict[int, int] = {}
        fed: Dict[int, int] = {}
        props: Dict[int, List[int]] = {}
        for req in reqs:
            r = req.slot
            canon = np.concatenate(
                [req.prompt.astype(np.int64),
                 np.asarray(req.generated, np.int64)])
            q = [int(t) for t in canon[int(self._draft_consumed[r]):]]
            assert q, "draft ahead of the canonical stream"
            queues[r], total[r] = q, len(q) + k - 1
            fed[r], props[r] = 0, []
        state = kvd.state
        pos_dtype = state["pos"].dtype
        for _ in range(max(total.values())):
            toks = np.zeros((self.max_slots, 1), np.int32)
            pos = np.zeros((self.max_slots,), np.int64)
            for req in reqs:
                r = req.slot
                i = min(fed[r], total[r])  # done rows park one past last
                pos[r] = int(self._draft_consumed[r]) + i
                if fed[r] < len(queues[r]):
                    toks[r, 0] = queues[r][fed[r]]
                elif fed[r] < total[r]:
                    toks[r, 0] = props[r][fed[r] - len(queues[r])]
            state = dict(state, pos=jnp.asarray(pos).astype(pos_dtype))
            logits, state, _, _ = self._draft_exec.decode(
                state, jnp.asarray(toks))
            am = np.asarray(jnp.argmax(logits[:, -1], -1))
            for req in reqs:
                r = req.slot
                if fed[r] < total[r]:
                    fed[r] += 1
                    if fed[r] >= len(queues[r]):
                        props[r].append(int(am[r]))
        kvd.state = state  # pos is stale; _draft_consumed is the truth
        for req in reqs:
            self._draft_consumed[req.slot] += len(queues[req.slot])
        return props

    def _step_speculative(self, st, plan, finished, rows,
                          reqs: List[GenRequest], k_round: int
                          ) -> List[GenRequest]:
        """One draft-and-verify round over the running rows: draft
        proposes ``k_round`` tokens per row, the target verifies them in
        a single C = k_round+1 chunk through the executor, each row
        emits its longest matching prefix plus the target's own next
        token, and target KV (``truncate``) and draft bookkeeping roll
        back past each row's rejection point.  Bitwise the plain decode
        path under greedy sampling (tests/test_spec_decode.py)."""
        from repro.core.draft import verify_round
        C = k_round + 1
        for req in reqs:
            if self._draft_rid[req.slot] != req.rid:
                self._draft_admit(req)
        props = self._draft_propose(reqs, k_round)
        rec_snaps = {}
        if self._has_rec:
            # recurrent carries cannot roll back by a pos reset — the
            # verify chunk FOLDS rejected tokens into the fixed-size
            # state.  Mirror the paged page-table trim with the rec
            # plane's own trivial preemption primitive: snapshot each
            # row's pre-round state now, restore + replay the accepted
            # prefix after the verdict (DESIGN.md §12)
            for req in reqs:
                rec_snaps[req.slot] = self.kv.snapshot(req.slot)
        chunk = np.zeros((self.max_slots, C), np.int32)
        for req in reqs:
            chunk[req.slot, 0] = self.tokens[req.slot, 0]
            chunk[req.slot, 1:] = props[req.slot]
        active = np.zeros((self.max_slots,), bool)
        active[rows] = True
        base_len = {}
        if self.paged:
            for r in rows:
                base_len[r] = self.kv.length(r)
                self.kv.ensure(r, base_len[r] + C)
            step_state = self.kv.view(self.kv.live_width(rows))
            act_dev = jnp.asarray(active)
        else:
            step_state = self.kv.state
            act_dev = None
        if self.offload is not None:
            logits, state, self._pstate, route_ids = self._exec.decode(
                step_state, jnp.asarray(chunk), self._pstate,
                jnp.asarray(active))
            if self._collect:
                # packed route ids are token-major (B*C, K): map each
                # chunk position back to its slot for the usage histogram
                tok_rows = [r * C + j for r in rows for j in range(C)]
                self.usage.update([np.asarray(i) for i in route_ids],
                                  rows=tok_rows)
        else:
            logits, state, _, infos = self._exec.decode(
                step_state, jnp.asarray(chunk), active=act_dev,
                collect_info=self._collect)
            if self._collect:
                info_stack, _ = infos
                ids, _ = routing_from_info(self.cfg, info_stack,
                                           want_hiddens=False)
                tok_rows = [r * C + j for r in rows for j in range(C)]
                self.usage.update(ids, rows=tok_rows)
        if st is not None:
            st.mark("dispatch")
        if self.paged:
            self.kv.adopt(state)
            for r in rows:
                self.kv.note_tokens(r, base_len[r] + C)
        else:
            self.kv.state = state
        # the round's one blocking fetch: every row's target argmax
        tgt = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        if st is not None:
            st.mark("sync")
        for req in reqs:
            r = req.slot
            emitted, a = verify_round(props[r], tgt[r])
            self._spec_metrics.round(k_round, a)
            stopped = False
            for t in emitted:
                req.emit(int(t))
                if self._done(req, int(t)):
                    stopped = True
                    break
            if stopped:
                self.kv.release(r)
                self.sched.evict(req, self._reason(req, req.generated[-1]))
                self.obs.req_finished(req.rid, len(req.generated),
                                      req.finish_reason)
                finished.append(req)
                self._draft_rid[r] = -1
            else:
                # roll back to the canonical position: live KV is
                # prompt + generated minus the one un-fed last token —
                # exactly where non-speculative decode would stand
                self.tokens[r, 0] = req.generated[-1]
                if self._has_rec:
                    # restore the pre-round snapshot and replay the
                    # accepted feeds ONE TOKEN AT A TIME: C=1 steps are
                    # the plain engine's exact programs, so the restored
                    # carries (and any kv rings riding along) land
                    # bitwise where non-speculative decode would stand
                    # — a C-wide replay folds matmuls differently at
                    # the last partial chunk and drifts ~1e-7
                    snap = rec_snaps[r]
                    for j in range(len(emitted)):
                        _, snap, _, _ = self._exec.decode(
                            snap, jnp.asarray(chunk[r:r + 1, j:j + 1]))
                    self.kv.restore(snap, r)
                else:
                    self.kv.truncate(
                        r, len(req.prompt) + len(req.generated) - 1)
                self._draft_consumed[r] += min(a, k_round - 1)
        if self.offload is not None:
            hits, spec_hits, demand, spec_l = (
                int(c) for c in np.asarray(self._pstate.counts))
            total_h2d = (demand + spec_l) * self.offload.expert_bytes
            self._spec_metrics.add_bytes(total_h2d - self._spec_last_h2d)
            self._spec_last_h2d = total_h2d
        self.step_count += 1
        self._audit_step()
        if st is not None:
            st.mark("host")
            ctx = (sum(len(r.prompt) + len(r.generated) for r in reqs)
                   / max(1, len(reqs)))
            self.obs.step_end(st, n_decode=len(reqs),
                              n_chunks=len(plan.chunks), context_len=ctx)
        return finished

    def run(self, max_steps: Optional[int] = None) -> List[GenRequest]:
        """Drive until every submitted request finishes; returns them in
        completion order."""
        steps = 0
        while (self.sched.has_waiting or self.sched.n_running
               or self._swapped):
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.sched.finished

    # ------------------------------------------------------------------
    # telemetry collectors (pull-time only — DESIGN.md §10)
    def _engine_metrics(self) -> Dict[str, float]:
        toks = sum(len(r.generated) for r in self.sched.finished)
        out = self.sched.metrics()
        out.update(steps=self.step_count, tokens=toks,
                   tokens_per_step=toks / max(1, self.step_count),
                   # every emitted token, still-running requests included
                   decode_tokens=toks + sum(len(r.generated)
                                            for r in self.sched.running))
        return out

    def _offload_metrics(self) -> Dict[str, float]:
        hits, spec_hits, demand, spec = (
            int(c) for c in np.asarray(self._pstate.counts))
        bytes_h2d = (demand + spec) * self.offload.expert_bytes
        # traffic counters cover every decode step, so normalize by
        # ALL emitted tokens — still-running requests included
        emitted = sum(len(r.generated)
                      for r in self.sched.finished + self.sched.running)
        return {"hits": hits, "spec_hits": spec_hits,
                "demand_loads": demand, "spec_loads": spec,
                "bytes_h2d": bytes_h2d,
                "bytes_per_token": bytes_h2d / max(1, emitted)}

    def _prefix_metrics(self) -> Dict[str, float]:
        out = {"lookups": self._prefix.lookups,
               "hit_tokens": self._prefix_hit_tokens,
               "prefills_skipped": self._prefills_skipped}
        out.update(self._prefix.stats())
        return out

    def _kv_host_metrics(self) -> Dict[str, float]:
        out = dict(self.kv.host_stats())
        out.update(preemptions=self.sched.preemptions,
                   resumes=self.sched.resumes,
                   recomputes=self._recomputes,
                   swapped_now=len(self._swapped))
        return out

    def _faults_metrics(self) -> Dict[str, float]:
        """The ``faults`` namespace (DESIGN.md §14): injector fire
        counts (zeros without an injector), the executor's fetch
        retry/degrade ladder, NaN quarantines, and the terminal-status
        census over every request this engine has ever seen."""
        out = {"enabled": int(self.faults is not None), "injected": 0}
        for s in FAULT_SITES:
            out[f"fired_{s}"] = 0
        if self.faults is not None:
            out.update(self.faults.stats())
        out.update(self._exec.fault_counters)
        out["nan_quarantined"] = self._nan_quarantined
        counts = {"completed": 0, "cancelled": 0,
                  "deadline_exceeded": 0, "failed": 0}
        for r in self.sched.finished:
            counts[r.status] += 1
        counts["rejected"] = self.sched.queue_rejected
        out.update(counts)
        return out

    def metrics(self) -> Dict[str, Dict[str, object]]:
        """Namespaced telemetry snapshot ``{namespace: {key: value}}``
        (``repro.obs.schema``) — collectors pull fresh state at call
        time; timing/roofline namespaces appear when enabled."""
        return self.obs.snapshot()

    def stats(self) -> Dict[str, float]:
        """Legacy flat view — a pure projection of :meth:`metrics`
        through ``repro.obs.flatten_legacy`` (``engine.*`` flattens
        bare, ``kv.*`` → ``kv_*``, ``offload.*`` → ``offload_*``), so
        the two surfaces can never disagree on a value."""
        return self.obs.legacy_flat()
