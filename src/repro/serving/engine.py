"""Batched serving engine (non-offloaded path).

Serves a batch of requests with a shared jitted decode step and per-request
completion tracking.  This is the "has enough accelerator memory" serving
mode; the memory-constrained interactive mode is
``core/offload_engine.OffloadEngine`` (the paper's contribution).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import EOS
from repro.models import transformer as T
from repro.serving.sampler import SamplerConfig, sample


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    completed: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 sampler: Optional[SamplerConfig] = None):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler or SamplerConfig(kind="greedy")
        self._decode = jax.jit(
            lambda p, st, tk: T.decode_step(p, cfg, st, tk, moe_mode="gather"))

    def serve_batch(self, requests: List[Request], seed: int = 0
                    ) -> List[Request]:
        """Left-pads prompts to a common length and decodes the batch."""
        cfg = self.cfg
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad with 0
        pre_logits, state = jax.jit(
            lambda p, b: T.prefill(p, cfg, b, S + max_new))(
            self.params, {"tokens": jnp.asarray(toks)})
        rng = jax.random.key(seed)
        rng, sub = jax.random.split(rng)
        tok = sample(sub, pre_logits[:, -1], self.sampler)
        done = np.zeros(B, bool)
        for i in range(B):
            requests[i].completed.append(int(tok[i]))
        for step in range(max_new - 1):
            logits, state = self._decode(self.params, state, tok[:, None])
            rng, sub = jax.random.split(rng)
            tok = sample(sub, logits[:, -1], self.sampler)
            for i, r in enumerate(requests):
                if done[i] or len(r.completed) >= r.max_new_tokens:
                    done[i] = True
                    continue
                t = int(tok[i])
                r.completed.append(t)
                if t == EOS:
                    done[i] = True
            if done.all():
                break
        return requests
