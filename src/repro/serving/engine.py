"""Serving engines (non-offloaded accelerator path).

Two modes:

* :class:`ServeEngine` — static batch: left-pads a fixed request set to a
  common length and decodes until every request finishes.  Pad positions
  are excluded from attention and from MoE dispatch capacity via the
  ``pad_mask`` threaded through ``T.prefill`` (DESIGN.md §2).
* :class:`ContinuousEngine` — continuous batching: requests join and
  leave a *running* batch (DESIGN.md §4).  A slotted KV state
  (``serving/kv_manager``) holds ``max_slots`` sequences at independent
  positions; each admitted request is prefilled alone (B=1, exact
  length — bitwise identical to the ``generate_plain`` oracle, since MoE
  dispatch capacity depends on batch composition) and scattered into a
  free slot; finished requests release their slot the same step.  Which
  waiting request joins next is the scheduler policy's call — the
  expert-overlap policy groups requests that reuse the experts the
  in-flight batch keeps hot (``serving/scheduler``).

The memory-constrained interactive mode is
``core/offload_engine.OffloadEngine`` (the paper's contribution).
:class:`ContinuousEngine` composes with it: passing a packed offload
engine (``offload=...``) switches decode to the HQQ-packed expert
buffer pool — continuous batching over offloaded experts, with the pool
shared across the running batch (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, parse_block
from repro.core.offload_engine import (ExpertUsageTracker, routing_from_info)
from repro.data.pipeline import EOS
from repro.models import transformer as T
from repro.serving.kv_manager import KVSlotManager
from repro.serving.sampler import SamplerConfig, sample
from repro.serving.scheduler import GenRequest, Scheduler


@dataclass
class Request:
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 32
    completed: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 sampler: Optional[SamplerConfig] = None):
        self.params = params
        self.cfg = cfg
        self.sampler = sampler or SamplerConfig(kind="greedy")
        self._decode = T.cached_jit(
            ("decode_gather", cfg),
            lambda: jax.jit(lambda p, st, tk: T.decode_step(
                p, cfg, st, tk, moe_mode="gather")))
        # one persistent jit so repeated serve_batch calls with the same
        # shapes reuse the compiled prefill instead of retracing
        self._prefill = T.make_prefill(cfg)

    def serve_batch(self, requests: List[Request], seed: int = 0
                    ) -> List[Request]:
        """Left-pads prompts to a common length and decodes the batch.
        The pad mask keeps shorter prompts from attending to (or spending
        MoE capacity on) pad positions; each row decodes from its own
        true length (per-row ``pos``).  Pad isolation only exists for
        causal-attention stacks — recurrent mixers fold pad tokens into
        their state, so unequal-length batches are rejected there."""
        cfg = self.cfg
        B = len(requests)
        S = max(len(r.prompt) for r in requests)
        needs_pad = any(len(r.prompt) != S for r in requests)
        if needs_pad and not cfg.attention_only_stack:
            raise ValueError(
                f"left-padded serve_batch needs a causal-attention stack; "
                f"{cfg.name}'s mixers accumulate state over pad tokens "
                f"— batch equal-length prompts for this arch")
        max_new = max(r.max_new_tokens for r in requests)
        toks = np.zeros((B, S), np.int32)
        mask = np.zeros((B, S), bool)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt  # left-pad with 0
            mask[i, S - len(r.prompt):] = True
        batch = {"tokens": jnp.asarray(toks)}
        if needs_pad:
            batch["pad_mask"] = jnp.asarray(mask)
        pre_logits, state = self._prefill(self.params, batch, S + max_new)
        rng = jax.random.key(seed)
        rng, sub = jax.random.split(rng)
        tok = sample(sub, pre_logits[:, -1], self.sampler)
        done = np.zeros(B, bool)
        for i in range(B):
            requests[i].completed.append(int(tok[i]))
        for step in range(max_new - 1):
            logits, state = self._decode(self.params, state, tok[:, None])
            rng, sub = jax.random.split(rng)
            tok = sample(sub, logits[:, -1], self.sampler)
            for i, r in enumerate(requests):
                if done[i] or len(r.completed) >= r.max_new_tokens:
                    done[i] = True
                    continue
                t = int(tok[i])
                r.completed.append(t)
                if t == EOS:
                    done[i] = True
            if done.all():
                break
        return requests


# ======================================================================
class ContinuousEngine:
    """Continuous-batching decode loop over a slotted KV state.

    Per step: (1) admit policy-selected waiting requests into free slots
    (B=1 prefill, scattered into the slot), (2) one batched
    ``decode_step`` over all slots with per-row positions, (3) sample,
    stream tokens to request callbacks, evict finished requests.  Free
    slots decode a dummy token whose output is ignored and whose state is
    fully overwritten at the next admission.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 4,
                 slot_len: int = 256, sampler: Optional[SamplerConfig] = None,
                 policy=None, eos_id: Optional[int] = EOS,
                 prefill_bucket: int = 1, seed: int = 0, offload=None):
        """``offload``: a packed :class:`~repro.core.offload_engine.
        OffloadEngine` (``quantized=True``) switches this engine into
        **offloaded decode mode** (DESIGN.md §6): experts stay HQQ-packed
        in the offload engine's host store, every decode step serves the
        batch's routed experts from the per-layer device buffer pool
        (shared across requests — the expert-overlap admission policy is
        what makes that sharing pay), and admissions prefill through
        per-slot-dequant expert streaming.  ``params`` is ignored in that
        mode (the offload engine's executable params are used)."""
        self.offload = offload
        if offload is not None:
            if offload._decoder is None:
                raise ValueError("offloaded decode mode needs a packed "
                                 "OffloadEngine (quantized=True)")
            if offload.cfg is not cfg and offload.cfg != cfg:
                raise ValueError("offload engine config mismatch")
            params = offload.params
            self._dec = offload._decoder
            self._pstate = self._dec.init_pool_state()
        self.params = params
        self.cfg = cfg
        self.sampler = sampler or SamplerConfig(kind="greedy")
        self.max_slots = max_slots
        self.slot_len = slot_len
        self.eos_id = eos_id
        self.prefill_bucket = max(1, prefill_bucket)
        self.kv = KVSlotManager(cfg, max_slots, slot_len)
        self.sched = Scheduler(max_slots, policy)
        # routing collection costs per-step host transfers; only pay for
        # it when the admission policy actually reads the usage histogram
        # (the packed path surfaces routing for free)
        self._collect = (cfg.moe is not None
                         and (getattr(policy, "needs_usage", False)
                              or offload is not None))
        self.usage = (ExpertUsageTracker.for_config(cfg)
                      if self._collect else None)
        # greedy decode folds argmax into the jitted step and feeds the
        # token straight back on-device — the host only sees (B,) ints
        self._greedy = self.sampler.kind == "greedy"
        if offload is not None:
            self._decode = None  # layerwise packed path in step()
            self._prefill = lambda p, b, ml: self._dec.prefill(b, ml)
        else:
            collect, greedy = self._collect, self._greedy

            def make():
                if collect:
                    def _step_fn(p, st, tk):
                        logits, st, infos = T.decode_step(
                            p, cfg, st, tk, moe_mode="gather",
                            collect_info=True)
                        nxt = (jnp.argmax(logits[:, -1], -1)
                               .astype(jnp.int32) if greedy
                               else logits[:, -1])
                        return nxt, st, infos
                else:
                    def _step_fn(p, st, tk):
                        logits, st = T.decode_step(p, cfg, st, tk,
                                                   moe_mode="gather")
                        nxt = (jnp.argmax(logits[:, -1], -1)
                               .astype(jnp.int32) if greedy
                               else logits[:, -1])
                        return nxt, st
                return jax.jit(_step_fn, donate_argnums=1)
            self._decode = T.cached_jit(
                ("cont_step", cfg, collect, greedy), make)
            self._prefill = T.make_prefill(cfg)
        # all-SWA stacks roll their window inside the slot, so a request
        # may decode past slot_len; anything else must fit the slot ring
        mixers = {parse_block(k)[0] for k in cfg.block_pattern}
        self._unbounded = (mixers == {"swa"} and cfg.sliding_window
                           and slot_len >= cfg.sliding_window)
        self.tokens = np.zeros((max_slots, 1), np.int32)
        self.step_count = 0
        self._rng = jax.random.key(seed)

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 32, on_token=None,
               on_finish=None) -> GenRequest:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0, "empty prompt"
        if not self._unbounded and prompt.size + max_new_tokens > self.slot_len:
            raise ValueError(
                f"request needs {prompt.size + max_new_tokens} KV positions "
                f"> slot_len={self.slot_len}")
        req = GenRequest(prompt=prompt, max_new_tokens=max_new_tokens,
                         arrival=self.step_count, on_token=on_token,
                         on_finish=on_finish)
        return self.sched.submit(req)

    # ------------------------------------------------------------------
    def _sample(self, logits) -> np.ndarray:
        """logits (B, V) -> (B,) int32 next tokens."""
        if self.sampler.kind == "greedy":
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self._rng, sub = jax.random.split(self._rng)
        return np.asarray(sample(sub, logits, self.sampler))

    def _admit(self) -> List[GenRequest]:
        finished = []
        while self.kv.n_free and self.sched.has_waiting:
            req = self.sched.pop_next(self.usage)
            slot = self.kv.allocate(req.rid)
            req.slot = slot
            S = len(req.prompt)
            Sb = -(-S // self.prefill_bucket) * self.prefill_bucket
            batch = {"tokens": np.zeros((1, Sb), np.int32)}
            batch["tokens"][0, Sb - S:] = req.prompt
            if Sb != S:
                m = np.zeros((1, Sb), bool)
                m[0, Sb - S:] = True
                batch["pad_mask"] = m
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            logits, small = self._prefill(self.params, batch, self.slot_len)
            self.kv.write_prefill(small, slot)
            first = int(self._sample(logits[:, -1])[0])
            req.emit(first)
            if self._done(req, first):
                self.kv.release(slot)
                self.sched.evict(req, self._reason(req, first))
                finished.append(req)
            else:
                self.tokens[slot, 0] = first
        return finished

    def _done(self, req: GenRequest, tok: int) -> bool:
        return (len(req.generated) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))

    def _reason(self, req: GenRequest, tok: int) -> str:
        return ("eos" if self.eos_id is not None and tok == self.eos_id
                else "length")

    # ------------------------------------------------------------------
    def step(self) -> List[GenRequest]:
        """Admit + one decode step.  Returns requests finished this step."""
        finished = self._admit()
        if not self.sched.n_running:
            return finished
        rows = sorted(r.slot for r in self.sched.running)
        if self.offload is not None:
            # offloaded decode: layerwise packed step over the slotted
            # state; free slots bypass the expert pool (active mask), so
            # their dummy tokens never pollute the cache or the stats
            active = np.zeros((self.max_slots,), bool)
            active[rows] = True
            logits, state, self._pstate, route_ids = self._dec.decode(
                self.kv.state, jnp.asarray(self.tokens), self._pstate,
                jnp.asarray(active))
            if self._collect:
                self.usage.update([np.asarray(i) for i in route_ids],
                                  rows=rows)
            nxt_dev = (jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                       if self._greedy else logits[:, -1])
        else:
            out = self._decode(self.params, self.kv.state,
                               jnp.asarray(self.tokens))
            if self._collect:
                nxt_dev, state, (info_stack, _) = out
                ids, _ = routing_from_info(self.cfg, info_stack,
                                           want_hiddens=False)
                self.usage.update(ids, rows=rows)
            else:
                nxt_dev, state = out
        self.kv.state = state
        if self._greedy:
            nxt = np.asarray(nxt_dev)
        else:
            self._rng, sub = jax.random.split(self._rng)
            nxt = np.asarray(sample(sub, nxt_dev, self.sampler))
        for req in list(self.sched.running):
            t = int(nxt[req.slot])
            req.emit(t)
            if self._done(req, t):
                self.kv.release(req.slot)
                self.sched.evict(req, self._reason(req, t))
                finished.append(req)
            else:
                self.tokens[req.slot, 0] = t
        self.step_count += 1
        self.sched.check_invariants()
        return finished

    def run(self, max_steps: Optional[int] = None) -> List[GenRequest]:
        """Drive until every submitted request finishes; returns them in
        completion order."""
        steps = 0
        while self.sched.has_waiting or self.sched.n_running:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.sched.finished

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        toks = sum(len(r.generated) for r in self.sched.finished)
        out = {"steps": self.step_count, "joins": self.sched.joins,
               "evictions": self.sched.evictions,
               "finished": len(self.sched.finished),
               "tokens": toks,
               "tokens_per_step": toks / max(1, self.step_count)}
        if self.offload is not None:
            hits, spec_hits, demand, spec = (
                int(c) for c in np.asarray(self._pstate.counts))
            bytes_h2d = (demand + spec) * self.offload.expert_bytes
            # traffic counters cover every decode step, so normalize by
            # ALL emitted tokens — still-running requests included
            emitted = toks + sum(len(r.generated)
                                 for r in self.sched.running)
            out.update(offload_hits=hits, offload_spec_hits=spec_hits,
                       offload_demand_loads=demand,
                       offload_spec_loads=spec,
                       offload_bytes_h2d=bytes_h2d,
                       offload_bytes_per_token=bytes_h2d / max(1, emitted))
        return out
