"""Expert-activation trace collection (paper Fig. 1 / section 3 analysis).

Runs a (small) MoE model teacher-forced over real token sequences and
records, for every (token, MoE layer):

* the top-k expert ids actually used,
* the pre-MoE hidden state (the gate's input — what speculative loading
  applies the *next* layer's gate to),
* full router probabilities.

These traces feed the Fig-2 benchmarks (`lru_hit_curve`, `recall_curve`)
and the Table-2 cost-model replay.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, parse_block
from repro.models import transformer as T


def moe_positions(cfg: ModelConfig):
    return [i for i, k in enumerate(cfg.block_pattern)
            if parse_block(k)[1] == "moe"]


def stacked_routers(params, cfg: ModelConfig) -> np.ndarray:
    """(n_moe_layers, D, E) router weights, layer-major."""
    pos = moe_positions(cfg)
    per_period = [np.asarray(params["stack"][p]["moe"]["router"]) for p in pos]
    # interleave by period: layer order = period-major over pattern
    layers = []
    for per in range(cfg.n_periods):
        for p_i, p in enumerate(pos):
            layers.append(per_period[p_i][per])
    return np.stack(layers)  # tail layers with moe unsupported here (none)


def collect_trace(params, cfg: ModelConfig, tokens: np.ndarray,
                  progress: bool = False) -> Dict[str, np.ndarray]:
    """Teacher-forced trace over ``tokens`` (1, S) -> trace dict.

    Decode runs token-by-token exactly as interactive generation would
    (paper: "running the model on recorded conversations").
    """
    assert tokens.ndim == 2 and tokens.shape[0] == 1
    S = tokens.shape[1]

    step = jax.jit(lambda p, st, tk: T.decode_step(
        p, cfg, st, tk, moe_mode="gather", collect_info=True))

    state = T.init_decode_state(cfg, 1, max_len=S)
    ids_all, hid_all, probs_all = [], [], []
    for t in range(S):
        logits, state, (info_stack, info_tail) = step(
            params, state, tokens[:, t: t + 1])
        ids_l, hid_l, probs_l = [], [], []
        for per in range(cfg.n_periods):
            for i in range(cfg.pattern_period):
                info = info_stack[i]
                if "route" in info:
                    ids_l.append(np.asarray(info["route"]["ids"][per][0]))
                    probs_l.append(np.asarray(info["route"]["probs"][per][0]))
                    hid_l.append(np.asarray(info["hidden_pre_moe"][per][0]))
        ids_all.append(np.stack(ids_l))
        hid_all.append(np.stack(hid_l))
        probs_all.append(np.stack(probs_l))
    return {
        "ids": np.stack(ids_all),      # (S, L_moe, K)
        "hiddens": np.stack(hid_all),  # (S, L_moe, D)
        "probs": np.stack(probs_all),  # (S, L_moe, E)
        "routers": stacked_routers(params, cfg),  # (L_moe, D, E)
    }
