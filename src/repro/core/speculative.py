"""Speculative expert loading (paper §3.2).

The guess: apply layer ``l+j``'s gating function to the hidden state that
layer ``l``'s gate saw (the residual stream changes slowly, so an early
hidden state is "a decent estimate of next layer's hidden states").

``predict_experts`` is the online predictor used by the offload engine;
``recall_curve`` is the offline Fig-2-right evaluation over a recorded
trace of (hidden-state, actual-expert) pairs.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def predict_experts(router_w: jnp.ndarray, hidden: jnp.ndarray,
                    n_spec: int) -> jnp.ndarray:
    """Top-``n_spec`` experts of the lookahead layer's router applied to the
    *current* layer's pre-MoE hidden state.

    router_w: (D, E) f32; hidden: (T, D).  Returns (T, n_spec) int32.
    For interactive decode T == 1.
    """
    logits = jnp.einsum("td,de->te", hidden.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    _, ids = jax.lax.top_k(logits, n_spec)
    return ids.astype(jnp.int32)


def recall_curve(hiddens: np.ndarray, routers: np.ndarray,
                 actual: np.ndarray, lookaheads: Sequence[int],
                 n_fetch_list: Sequence[int]) -> Dict:
    """Fig-2-right: speculative-loading recall.

    hiddens: (n_tokens, n_layers, D) pre-MoE hidden states (gate inputs);
    routers: (n_layers, D, E) router weights;
    actual:  (n_tokens, n_layers, top_k) expert ids actually used.

    recall@n for lookahead j = fraction of layer-(l+j) active experts
    covered by the top-n prediction made from layer-l hidden states
    ("A recall of 1.0 corresponds to ... both Mixtral active experts
    pre-fetched").
    """
    n_tokens, n_layers, top_k = actual.shape
    out = {}
    for j in lookaheads:
        logits = np.einsum("tld,lde->tle", hiddens[:, : n_layers - j],
                           routers[j:])  # predict layer l+j from hidden l
        order = np.argsort(-logits, axis=-1)  # (T, L-j, E)
        tgt = actual[:, j:]  # (T, L-j, top_k)
        for n in n_fetch_list:
            pred = order[..., :n]  # (T, L-j, n)
            covered = (tgt[..., :, None] == pred[..., None, :]).any(-1)
            out[(j, n)] = float(covered.mean())
    return out
