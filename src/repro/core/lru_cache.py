"""Functional LRU expert cache + speculative staging buffers (paper §3.1/3.3).

The paper keeps, per MoE layer, the ``k`` least-recently-used experts
resident in accelerator memory, plus ``b`` shared staging buffers that hold
speculatively prefetched experts.  Semantics implemented here (exactly the
paper's):

* an expert needed for the current token that is **in the LRU pool** is a
  *hit* (no transfer, refresh recency);
* an expert **in the staging buffers** (speculatively loaded while the
  previous layer computed) is a *speculative hit*: no blocking transfer;
  since it was actually used, it is promoted into the LRU pool, evicting
  the least-recently-used entry ("if a speculatively loaded expert was
  later used ... it will replace the least recently used expert");
* otherwise it is a *demand miss*: one blocking expert-sized host->device
  copy, then inserted into the LRU pool (evicting the LRU entry);
* after serving a layer, the predicted experts for the lookahead layer are
  staged: each prediction not already resident charges one *overlappable*
  transfer ("the newly loaded experts do not replace the currently cached
  experts").

Everything is fixed-shape jnp so the whole decode loop jits; ``PyLRU`` is
the plain-python oracle (property-tested equal in
``tests/test_lru.py::test_jnp_matches_python_oracle``, including the
eviction sequence).

Since the packed-offloading refactor (DESIGN.md §6) this state machine is
not only accounting: :func:`access_plan` / :func:`stage_plan` additionally
report *which pool slot* serves each routed expert and *where its packed
bytes come from* (LRU pool / staging buffer / host store), and
``core/expert_pool`` uses those plans to perform the actual buffer swaps.
The slot index into ``cache_ids`` IS the device-pool slot index.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class LayerCacheState(NamedTuple):
    """State for ONE MoE layer (vmap/stack over layers for the model)."""

    cache_ids: jnp.ndarray   # (k,) int32, -1 = empty
    cache_clock: jnp.ndarray  # (k,) int32 recency stamps
    spec_ids: jnp.ndarray    # (n_spec,) int32 staged experts, -1 = empty
    clock: jnp.ndarray       # () int32 monotone counter


class AccessStats(NamedTuple):
    hits: jnp.ndarray          # () int32 — LRU hits this access
    spec_hits: jnp.ndarray     # () int32 — served from staging buffers
    demand_loads: jnp.ndarray  # () int32 — blocking transfers
    spec_loads: jnp.ndarray    # () int32 — overlappable transfers (staging)


def init_layer_state(k: int, n_spec: int) -> LayerCacheState:
    return LayerCacheState(
        cache_ids=jnp.full((k,), -1, jnp.int32),
        cache_clock=jnp.zeros((k,), jnp.int32),
        spec_ids=jnp.full((n_spec,), -1, jnp.int32),
        clock=jnp.zeros((), jnp.int32),
    )


def init_model_state(n_layers: int, k: int, n_spec: int) -> LayerCacheState:
    one = init_layer_state(k, n_spec)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_layers,) + a.shape).copy(), one)


def layer_slice(state: LayerCacheState, l: int) -> LayerCacheState:
    return jax.tree.map(lambda a: a[l], state)


def set_layer(state: LayerCacheState, l: int, new: LayerCacheState):
    return jax.tree.map(lambda a, b: a.at[l].set(b), state, new)


# ----------------------------------------------------------------------
class AccessPlan(NamedTuple):
    """Per-needed-expert slot decisions of one :func:`access_plan` call.

    ``slots[j]`` is the pool slot (index into ``cache_ids``) that serves
    ``needed[j]`` after the access; ``in_cache``/``in_spec`` say where its
    packed bytes already reside (mutually exclusive; neither = demand load
    from the host store); ``spec_slot`` is the staging-buffer index when
    ``in_spec``; ``evicted`` is the expert id displaced by the insertion
    (-1 when the slot was empty or the access was a cache hit).
    """

    slots: jnp.ndarray      # (K,) int32
    in_cache: jnp.ndarray   # (K,) bool
    in_spec: jnp.ndarray    # (K,) bool
    spec_slot: jnp.ndarray  # (K,) int32
    evicted: jnp.ndarray    # (K,) int32


def access_plan(state: LayerCacheState, needed: jnp.ndarray
                ) -> Tuple[LayerCacheState, AccessStats, AccessPlan]:
    """Serve ``needed`` (K,) int32 expert ids for one layer, one token,
    additionally returning the slot plan that lets a buffer pool perform
    the swaps this state transition implies (DESIGN.md §6)."""
    K = needed.shape[0]
    ids, clock_arr, spec, clk = state
    hits = jnp.zeros((), jnp.int32)
    spec_hits = jnp.zeros((), jnp.int32)
    demand = jnp.zeros((), jnp.int32)
    slots, in_cache_a, in_spec_a, spec_slot_a, evicted_a = [], [], [], [], []
    for j in range(K):  # K is static (top_k)
        e = needed[j]
        in_cache = jnp.any(ids == e)
        in_spec = jnp.logical_and(~in_cache, jnp.any(spec == e))
        hit = in_cache
        s_hit = in_spec
        miss = jnp.logical_and(~in_cache, ~in_spec)
        hits += hit.astype(jnp.int32)
        spec_hits += s_hit.astype(jnp.int32)
        demand += miss.astype(jnp.int32)
        # insertion slot: existing slot on hit, else LRU (min clock)
        hit_slot = jnp.argmax(ids == e)
        lru_slot = jnp.argmin(clock_arr)
        slot = jnp.where(in_cache, hit_slot, lru_slot)
        evicted = jnp.where(in_cache, jnp.asarray(-1, jnp.int32),
                            ids[slot]).astype(jnp.int32)
        clk = clk + 1
        ids = ids.at[slot].set(e)
        clock_arr = clock_arr.at[slot].set(clk)
        slots.append(slot.astype(jnp.int32))
        in_cache_a.append(in_cache)
        in_spec_a.append(in_spec)
        # n_spec = 0 (no-speculation ablation): argmax over an empty
        # staging tier is invalid — and in_spec is statically False
        spec_slot_a.append(jnp.argmax(spec == e).astype(jnp.int32)
                           if spec.shape[0] else jnp.zeros((), jnp.int32))
        evicted_a.append(evicted)
    new = LayerCacheState(ids, clock_arr, spec, clk)
    stats = AccessStats(hits, spec_hits, demand, jnp.zeros((), jnp.int32))
    plan = AccessPlan(jnp.stack(slots), jnp.stack(in_cache_a),
                      jnp.stack(in_spec_a), jnp.stack(spec_slot_a),
                      jnp.stack(evicted_a))
    return new, stats, plan


def access(state: LayerCacheState, needed: jnp.ndarray
           ) -> Tuple[LayerCacheState, AccessStats]:
    """Serve ``needed`` (K,) int32 expert ids for one layer, one token."""
    new, stats, _ = access_plan(state, needed)
    return new, stats


class BatchAccessPlan(NamedTuple):
    """Whole-batch slot decisions of one :func:`access_plan_batch` call
    (DESIGN.md §7) — everything a buffer pool needs to perform ALL of a
    batch's swaps as one gather/scatter instead of T*K sequential updates.

    ``slots[t, j]`` is the pool slot serving access (t, j) *at access
    time*; ``survives[t, j]`` says whether that expert still owns a pool
    slot after the whole batch (False when a later access within the same
    batch evicted it — those reads must fall back to the source store);
    ``written[s]`` marks pool slots whose contents changed (some active
    access inserted into them), i.e. the scatter targets.
    """

    slots: jnp.ndarray     # (T, K) int32
    survives: jnp.ndarray  # (T, K) bool
    written: jnp.ndarray   # (k,) bool


def access_plan_batch(state: LayerCacheState, needed: jnp.ndarray,
                      active: Optional[jnp.ndarray] = None
                      ) -> Tuple[LayerCacheState, jnp.ndarray,
                                 BatchAccessPlan]:
    """Serve a whole batch ``needed`` (T, K) of routed experts through the
    LRU state machine in one call, folding the per-token ``active`` mask
    (continuous batching: inactive rows must not mutate state or counts)
    into the plan itself.

    Returns ``(new_state, delta, plan)`` where ``delta`` is a (4,) i32
    [hits, spec_hits, demand_loads, 0] counter delta over the *active*
    tokens and ``plan`` is the :class:`BatchAccessPlan`.  The state
    transitions are exactly T sequential :func:`access_plan` calls — the
    int state machine stays a (cheap) host-unrolled loop; what this
    batched form buys is that the *data plane* consumes one plan instead
    of T*K full-tensor updates (``core/expert_pool.acquire``).
    """
    T, K = needed.shape
    k = state.cache_ids.shape[0]
    lru = state
    delta = jnp.zeros((4,), jnp.int32)
    written = jnp.zeros((k,), bool)
    slots_all = []
    for t in range(T):  # T is static (batch slots)
        act = None if active is None else active[t]
        new_lru, stats, plan = access_plan(lru, needed[t])
        d = jnp.stack([stats.hits, stats.spec_hits, stats.demand_loads,
                       jnp.zeros((), jnp.int32)])
        inserts = ~plan.in_cache  # spec hit or demand miss -> slot write
        if act is not None:
            new_lru = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                   new_lru, lru)
            d = jnp.where(act, d, 0)
            inserts = inserts & act
        written = written | jnp.any(
            (plan.slots[:, None] == jnp.arange(k)) & inserts[:, None],
            axis=0)
        slots_all.append(plan.slots)
        delta = delta + d
        lru = new_lru
    slots = jnp.stack(slots_all)  # (T, K)
    # an access survives iff the expert it served still owns its slot
    # after the whole batch (later evictions within the batch steal it)
    survives = lru.cache_ids[slots] == needed
    return lru, delta, BatchAccessPlan(slots, survives, written)


class StagePlan(NamedTuple):
    """Per-prediction sourcing decisions of one :func:`stage_plan` call.

    ``loads[j]`` charges one overlappable host->device transfer (the
    prediction is resident nowhere); otherwise the staging buffer is
    filled from the LRU pool slot ``cache_slot[j]`` (when ``in_cache``)
    or from the *previous* staging buffer ``old_spec_slot[j]`` (when
    ``in_old_spec``) — device-local copies that cost no host traffic.
    """

    loads: jnp.ndarray         # (n_spec,) bool
    in_cache: jnp.ndarray      # (n_spec,) bool
    cache_slot: jnp.ndarray    # (n_spec,) int32
    in_old_spec: jnp.ndarray   # (n_spec,) bool
    old_spec_slot: jnp.ndarray  # (n_spec,) int32


def stage_plan(state: LayerCacheState, predicted: jnp.ndarray
               ) -> Tuple[LayerCacheState, StagePlan, jnp.ndarray]:
    """Stage ``predicted`` (n_spec,) experts into this layer's buffers,
    returning the transfer/copy plan alongside the transfer count."""
    ids, clock_arr, old_spec, clk = state
    n = predicted.shape[0]
    transfers = jnp.zeros((), jnp.int32)
    loads, in_cache_a, cache_slot_a, in_old_a, old_slot_a = [], [], [], [], []
    for j in range(n):
        e = predicted[j]
        in_cache = jnp.any(ids == e)
        in_old = jnp.any(old_spec == e)
        resident = in_cache | in_old
        if j > 0:
            resident = resident | jnp.any(predicted[:j] == e)
        load = jnp.logical_and(e >= 0, ~resident)
        transfers += load.astype(jnp.int32)
        loads.append(load)
        in_cache_a.append(in_cache)
        cache_slot_a.append(jnp.argmax(ids == e).astype(jnp.int32))
        in_old_a.append(jnp.logical_and(~in_cache, in_old))
        old_slot_a.append(jnp.argmax(old_spec == e).astype(jnp.int32))
    new = LayerCacheState(ids, clock_arr, predicted.astype(jnp.int32), clk)
    if n == 0:
        z = jnp.zeros((0,), jnp.int32)
        plan = StagePlan(z.astype(bool), z.astype(bool), z,
                         z.astype(bool), z)
    else:
        plan = StagePlan(jnp.stack(loads), jnp.stack(in_cache_a),
                         jnp.stack(cache_slot_a), jnp.stack(in_old_a),
                         jnp.stack(old_slot_a))
    return new, plan, transfers


def stage_speculative(state: LayerCacheState, predicted: jnp.ndarray
                      ) -> Tuple[LayerCacheState, jnp.ndarray]:
    """Stage ``predicted`` (n_spec,) experts into this layer's buffers.

    Returns (new_state, n_transfers) — transfers are charged only for
    predictions not already resident (cache or previous staging).
    """
    new, _, transfers = stage_plan(state, predicted)
    return new, transfers


# ----------------------------------------------------------------------
class PyLRU:
    """Plain-python oracle with identical semantics (property-tested
    against :func:`access_plan`/:func:`stage_plan`, down to the eviction
    sequence — ``tests/test_lru.py::test_jnp_matches_python_oracle``)."""

    def __init__(self, k: int, n_spec: int):
        self.k = k
        self.cache: List[int] = []   # most-recent-last
        self.spec: List[int] = []
        self.hits = self.spec_hits = self.demand = self.spec_loads = 0
        self.evictions: List[int] = []  # expert ids displaced, in order

    def access(self, needed: Sequence[int]):
        for e in needed:
            if e in self.cache:
                self.hits += 1
                self.cache.remove(e)
                self.cache.append(e)
            else:
                if e in self.spec:
                    self.spec_hits += 1
                else:
                    self.demand += 1
                if self.k > 0:  # k=0 = caching disabled (ablation)
                    while len(self.cache) >= self.k:
                        self.evictions.append(self.cache.pop(0))
                    self.cache.append(e)

    def stage(self, predicted: Sequence[int]):
        fresh = []
        seen = set()
        for e in predicted:
            if e >= 0 and e not in self.cache and e not in self.spec \
                    and e not in seen:
                self.spec_loads += 1
            seen.add(e)
            fresh.append(e)
        self.spec = [e for e in fresh if e >= 0]


# ----------------------------------------------------------------------
# Beyond-paper cache policies (the paper: "LRU is a very simple strategy
# that does not consider factors like expert activation frequencies ...")
class PyLFUDecay:
    """Frequency cache with exponential decay (half-life in accesses)."""

    def __init__(self, k: int, decay: float = 0.95):
        self.k = k
        self.decay = decay
        self.score: dict = {}
        self.cache: set = set()
        self.hits = self.demand = 0

    def access(self, needed: Sequence[int]):
        for key in list(self.score):
            self.score[key] *= self.decay
        for e in needed:
            self.score[e] = self.score.get(e, 0.0) + 1.0
            if e in self.cache:
                self.hits += 1
            else:
                self.demand += 1
                self.cache.add(e)
                if len(self.cache) > self.k:
                    victim = min(self.cache, key=lambda x: self.score.get(x, 0))
                    self.cache.discard(victim)


def belady_hit_ratio(layer_trace: np.ndarray, k: int) -> float:
    """Clairvoyant (Belady/MIN) eviction upper bound for one layer's
    access sequence. layer_trace: (n_tokens, top_k) expert ids."""
    seq = [int(e) for row in layer_trace for e in row]
    n = len(seq)
    nxt_use = [float("inf")] * n
    last = {}
    for i in range(n - 1, -1, -1):
        nxt_use[i] = last.get(seq[i], float("inf"))
        last[seq[i]] = i
    cache: dict = {}  # expert -> next use index
    hits = 0
    for i, e in enumerate(seq):
        if e in cache:
            hits += 1
            cache[e] = nxt_use[i]
            continue
        if len(cache) >= k:
            # true MIN: consider bypassing the incoming item if its own
            # next use is the farthest
            victim = max(cache, key=lambda x: cache[x])
            if cache[victim] <= nxt_use[i]:
                continue  # bypass — don't cache e at all
            del cache[victim]
        cache[e] = nxt_use[i]
    return hits / max(1, n)


def policy_comparison(trace: np.ndarray, cache_sizes: Sequence[int]) -> dict:
    """hit ratios per policy x k: LRU (paper) vs LFU-decay vs Belady."""
    n_tokens, n_layers, top_k = trace.shape
    out = {}
    for k in cache_sizes:
        lru = [PyLRU(k, 0) for _ in range(n_layers)]
        lfu = [PyLFUDecay(k) for _ in range(n_layers)]
        for t in range(n_tokens):
            for l in range(n_layers):
                lru[l].access(trace[t, l])
                lfu[l].access(trace[t, l])
        tot = n_tokens * n_layers * top_k
        out[("lru", k)] = sum(c.hits for c in lru) / tot
        out[("lfu_decay", k)] = sum(c.hits for c in lfu) / tot
        out[("belady", k)] = float(np.mean(
            [belady_hit_ratio(trace[:, l], k) for l in range(n_layers)]))
    return out


def lru_hit_curve(trace: np.ndarray, cache_sizes: Sequence[int]
                  ) -> dict:
    """Fig-2-left evaluation: replay an expert-activation trace through an
    LRU cache for each size k and report the hit ratio.

    trace: (n_tokens, n_layers, top_k) int expert ids.
    """
    n_tokens, n_layers, top_k = trace.shape
    out = {}
    for k in cache_sizes:
        hits = total = 0
        caches = [PyLRU(k, 0) for _ in range(n_layers)]
        for t in range(n_tokens):
            for l in range(n_layers):
                caches[l].access(trace[t, l])
        hits = sum(c.hits for c in caches)
        total = n_tokens * n_layers * top_k
        out[k] = hits / total
    return out
