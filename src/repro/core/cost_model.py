"""Analytic offloading cost model -> tokens/s (paper Table 2).

This container has no GPU/TPU, so wall-clock tokens/s cannot be measured;
instead we reproduce Table 2 with a calibrated model driven by *measured*
cache statistics (LRU hits / speculative hits / demand misses from real
routing traces).  The model:

    t_token = t_compute + t_demand + t_spec_spill + t_fixed

* ``t_compute``  — interactive (batch-1) decode is **memory-bound** on the
  accelerator: reading the active parameters once per token,
  ``active_bytes / (mem_bw * eff)`` plus a per-layer launch overhead.
* ``t_demand``   — blocking host->device copies for cache misses:
  ``n_miss * (expert_bytes / pcie_bw + copy_latency)``.
* ``t_spec_spill`` — speculative loads overlap with the next layer's
  compute; only the part exceeding the per-layer compute window blocks.
* naive offloading streams whole MoE layers (one big copy per layer) and
  can overlap the *next* layer perfectly (dense-style schedule), so it is
  purely ``total_bytes / pcie_bw`` + per-layer latency — matching the
  paper's observation that all schemes beat it by avoiding ~E/top_k of
  the traffic.

Calibration: effective PCIe bandwidths are backed out of the paper's own
"naive offloading" rows (14.65 GB/token at 2-bit / Table 2), which give
T4=10, RTX3060=13, 3080M=15.5, A100=20.4 GB/s — all consistent with
PCIe Gen3/Gen4 practical rates.  Copy latency and launch overheads are
fitted once against the full-algorithm rows and then held fixed across
all ablations (so the *structure* of Table 2 is predicted, not fitted).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import ModelConfig, parse_block

# bits/param including group scale/zero + meta-quant overhead (measured by
# quant/hqq.bits_per_param on the paper's group-size schemes)
EFFECTIVE_BITS = {16: 16.0, 8: 8.5, 4: 4.5, 3: 3.5, 2: 3.25}


@dataclass(frozen=True)
class Hardware:
    name: str
    pcie_gbps: float        # effective host->device GB/s
    mem_bw_gbps: float      # device memory bandwidth GB/s
    mem_eff: float          # achievable fraction for GEMV-ish decode
    copy_latency_s: float   # per host->device copy fixed cost
    layer_overhead_s: float  # per-layer launch/dequant overhead
    vram_gb: float
    # per-token software overhead of the interactive serving loop
    # (python/framework dispatch, sampling, tokenization).  The paper's own
    # A100 row — 3.06 tok/s with k=4 caching on a GPU whose compute and
    # transfers account for <100ms — implies ~0.2s/token of fixed software
    # cost; calibrated once on the (2-bit, full, A100) cell and held fixed
    # for every other cell/ablation/hardware.
    sw_overhead_s: float = 0.21


HARDWARE = {
    "a100": Hardware("A100-80GB", 20.4, 2039.0, 0.55, 1.2e-3, 0.8e-3, 80),
    "3080m": Hardware("RTX 3080 Mobile", 15.5, 760.0, 0.50, 2.0e-3, 1.2e-3, 16),
    "3060": Hardware("RTX 3060", 13.0, 360.0, 0.50, 2.0e-3, 1.2e-3, 12),
    "t4": Hardware("T4 (Colab)", 10.0, 320.0, 0.45, 2.5e-3, 1.5e-3, 16),
}


# ----------------------------------------------------------------------
def expert_param_count(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff  # swiglu experts (gate/up/down)


def expert_bytes(cfg: ModelConfig, bits: int) -> float:
    return expert_param_count(cfg) * EFFECTIVE_BITS[bits] / 8.0


def active_param_bytes(cfg: ModelConfig, expert_bits: int,
                       attn_bits: int) -> float:
    """Bytes read from device memory per generated token (active params).

    Dense models are the E=1 case (DESIGN.md §12): with no router their
    whole FFN is "active" every token, so its parameters count in the
    dense read at ``attn_bits`` — a MoE arch only reads its top-k
    experts' worth, which is the whole point of the paper's traffic
    model."""
    moe_layers = cfg.moe_layer_count
    n_expert_active = (moe_layers * cfg.moe.top_k * expert_param_count(cfg)
                       if cfg.moe is not None else 0)
    attn_per_layer = cfg.d_model * cfg.head_dim * (cfg.n_heads * 2
                                                   + cfg.n_kv_heads * 2)
    mlp_layers = sum(1 for k in cfg.layer_kinds()
                     if parse_block(k)[1] == "mlp")
    mats = 2 if cfg.mlp_act == "gelu" else 3  # gated acts add a matrix
    dense = (cfg.n_layers * attn_per_layer
             + mlp_layers * mats * cfg.d_model * cfg.d_ff
             + cfg.vocab_size * cfg.d_model)
    return (n_expert_active * EFFECTIVE_BITS[expert_bits] / 8.0
            + dense * EFFECTIVE_BITS[attn_bits] / 8.0)


def recurrent_state_bytes(cfg: ModelConfig) -> int:
    """Fixed-size recurrent decode state of ONE sequence, summed over
    layers (mirrors ``models/recurrent.init_*_state``: f32 carries, conv
    prefix at the param dtype).  This is the "rec" plane of DESIGN.md
    §12 — the footprint is FLAT in context length, which is exactly why
    recurrent per-token decode cost must not grow with it."""
    import jax.numpy as jnp

    H = cfg.n_heads
    dh = cfg.d_model // H
    D = cfg.d_model
    pdt = jnp.dtype(cfg.dtype).itemsize
    total = 0
    for kind in cfg.layer_kinds():
        mixer = parse_block(kind)[0]
        if mixer == "rglru":   # h (D, f32) + conv prefix ((cw-1)*D)
            total += 4 * D + pdt * (cfg.rglru_conv_width - 1) * D
        elif mixer == "mlstm":  # C (H,dh,dh) + n (H,dh) + m (H), f32
            total += 4 * (H * dh * dh + H * dh + H)
        elif mixer == "slstm":  # h/c/n/m each (H,dh), f32
            total += 4 * 4 * H * dh
    return total


def kv_read_bytes_per_token(cfg: ModelConfig, context_len: float,
                            kv_bits: int = 16) -> float:
    """Device-memory bytes of KV cache read per generated token at a
    given *live* context length.

    Decode attention reads every live K and V entry of every attention
    layer once per token — traffic that grows linearly with context and
    that the weight-only roofline ignored.  Sliding-window layers cap
    their span at the window (exactly the page-skip bound the ragged
    kernel enforces, DESIGN.md §9); recurrent mixers hold O(1) state and
    contribute nothing.  ``kv_bits`` models a quantized cache (the KV
    analogue of the paper's expert compression).
    """
    per_pos = 2 * cfg.n_kv_heads * cfg.head_dim * kv_bits / 8.0  # K and V
    total = 0.0
    for kind in cfg.layer_kinds():
        mixer = parse_block(kind)[0]
        if mixer == "attn":
            span = context_len
        elif mixer == "xattn":
            # self-KV over the decoded context PLUS the precomputed
            # encoder K/V the cross sub-block reads every token
            span = context_len + (cfg.encoder_seq or 0)
        elif mixer == "swa":
            span = min(context_len, cfg.sliding_window or context_len)
        else:
            # rglru/mlstm/slstm hold O(1) recurrent state; encattn is an
            # encoder-only mixer that runs once per prompt, not per token
            continue
        total += span * per_pos
    return total


@dataclass
class TokenStats:
    """Per-token averages measured from a routing trace replay."""

    demand_loads: float   # blocking expert copies / token (total over layers)
    spec_loads: float     # speculative copies / token
    hits: float
    spec_hits: float


def tokens_per_second(cfg: ModelConfig, hw: Hardware, stats: TokenStats,
                      expert_bits: int, attn_bits: int = 4,
                      naive: bool = False, context_len: float = 0.0,
                      kv_bits: int = 16) -> float:
    """``context_len`` adds the KV-cache read traffic of decode
    attention at that live context (:func:`kv_read_bytes_per_token`) to
    the memory-bound compute term — the roofline's attention tax, which
    the paged/ragged plane keeps proportional to live tokens.  The
    default 0 reproduces the weight-only Table-2 numbers.

    Per-layer-kind state planes (DESIGN.md §12) each carry their own
    sequence-state traffic term: attention layers read live KV
    (growing in ``context_len``; xattn additionally reads the
    precomputed encoder KV every token), recurrent layers read AND
    write their fixed carries (flat in ``context_len`` — the
    structural reason a pure-recurrent stack's predicted tokens/s does
    not change with context, tests/test_zoo_serving.py), and dense
    models are the E=1 case with zero expert-streaming terms."""
    eb = expert_bytes(cfg, expert_bits) if cfg.moe is not None else 0.0
    moe_layers = cfg.moe_layer_count
    t_compute = ((active_param_bytes(cfg, expert_bits, attn_bits)
                  + kv_read_bytes_per_token(cfg, context_len, kv_bits)
                  + 2 * recurrent_state_bytes(cfg))  # read + write
                 / (hw.mem_bw_gbps * 1e9 * hw.mem_eff)
                 + cfg.n_layers * hw.layer_overhead_s)
    if naive:
        if cfg.moe is None:
            raise ValueError("naive offloading models per-layer expert "
                             "streaming; there are no experts to stream "
                             f"in dense arch {cfg.name}")
        total_bytes = moe_layers * cfg.moe.num_experts * eb
        t_transfer = total_bytes / (hw.pcie_gbps * 1e9) \
            + moe_layers * hw.copy_latency_s
        return 1.0 / (hw.sw_overhead_s
                      + max(t_transfer, t_compute) + 0.1 * t_compute)

    t_demand = stats.demand_loads * (eb / (hw.pcie_gbps * 1e9)
                                     + hw.copy_latency_s)
    # speculative copies overlap with one layer's compute window each
    per_layer_window = t_compute / max(cfg.n_layers, 1)
    t_spec_each = eb / (hw.pcie_gbps * 1e9) + hw.copy_latency_s
    spill_each = max(0.0, t_spec_each - per_layer_window)
    t_spec_spill = stats.spec_loads * spill_each * 0.5  # partial overlap
    return 1.0 / (hw.sw_overhead_s + t_compute + t_demand + t_spec_spill)


# ----------------------------------------------------------------------
def replay_policies(trace_ids, hiddens=None, routers=None, k: int = 4,
                    n_spec: int = 2, lookahead: int = 1) -> Dict[str, TokenStats]:
    """Replay a routing trace through the paper's policy ablations.

    trace_ids: (n_tokens, n_layers, top_k) numpy int array.
    hiddens/routers enable the speculative policy (Fig-2-right machinery).
    Returns per-policy TokenStats (averages per token).
    """
    import numpy as np

    from repro.core.lru_cache import PyLRU
    from repro.core import speculative as spec

    n_tokens, n_layers, top_k = trace_ids.shape
    out = {}

    preds = None
    if hiddens is not None and routers is not None:
        E = routers.shape[-1]
        logits = np.einsum("tld,lde->tle", hiddens[:, : n_layers - lookahead],
                           routers[lookahead:])
        order = np.argsort(-logits, axis=-1)
        preds = order[..., :n_spec]  # (T, L-lookahead, n_spec)

    def run(policy_k, use_spec):
        caches = [PyLRU(policy_k, n_spec) for _ in range(n_layers)]
        for t in range(n_tokens):
            for l in range(n_layers):
                caches[l].access(trace_ids[t, l])
                if use_spec and preds is not None and l + lookahead < n_layers:
                    caches[l + lookahead].stage(preds[t, l])
        tot = lambda f: sum(getattr(c, f) for c in caches) / n_tokens
        return TokenStats(demand_loads=tot("demand"), spec_loads=tot("spec_loads"),
                          hits=tot("hits"), spec_hits=tot("spec_hits"))

    out["full"] = run(k, True)
    out["no_spec"] = run(k, False)
    out["no_lru_no_spec"] = run(0, False)
    # naive handled analytically in tokens_per_second(naive=True)
    out["naive"] = TokenStats(0, 0, 0, 0)
    return out
