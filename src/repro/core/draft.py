"""Draft models + acceptance rule for token-level draft-and-verify
decoding (DESIGN.md §11).

The paper amortizes *expert* transfer with speculative expert loading
(§3.2); token-level speculation amortizes it further — one packed verify
chunk (C = k+1 through ``runtime.Executor.decode``) serves several
accepted tokens, so each h2d expert fetch pays for more than one emitted
token.  The pieces here are engine-agnostic:

* :func:`accept_length` / :func:`verify_round` — the pure acceptance
  rule.  The target's greedy argmax at chunk position ``j`` is computed
  from exactly the canonical prefix whenever every earlier draft token
  matched, so emitting ``target[:a+1]`` (longest matching prefix plus
  the target's own next token) is bitwise identical to non-speculative
  greedy decode *for any draft whatsoever* — the draft only ever
  controls speed, never output.
* :class:`DenseDraft` — a real dense draft model (a ``configs/`` dense
  config sharing the target's vocab, e.g. ``tiny-draft``) run through a
  plain-plane Executor with its own KV state and rollback bookkeeping.
* :class:`ReplayDraft` — replays a precomputed reference continuation
  with a controllable miss rate.  This is the measurement instrument:
  it pins the acceptance rate, which is what lets tests exercise every
  partial-rollback path deterministically and lets the benchmark report
  machinery speedup *at a stated acceptance rate* instead of at
  whatever an untrained draft happens to produce.

Draft-side bookkeeping contract (both drafts): ``consumed`` counts the
canonical tokens the draft has folded into its state.  ``propose(tail,
k)`` first consumes ``tail`` (the canonical tokens emitted since the
draft last saw the stream — length 1, or 2 after a fully-accepted
round), then proposes ``k`` greedy tokens, feeding itself the first
``k−1`` of them.  ``accept(a)`` keeps ``min(a, k−1)`` of those fed
draft tokens as canonical (they matched the target) and rolls the
position back over the rest — for the dense draft the rollback is a pos
reset only: ring entries beyond ``pos`` are dead by the attention
validity mask and are overwritten when the real token lands at the same
position.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# ----------------------------------------------------------------------
# the acceptance rule (pure; property-tested in tests/test_spec_decode)
def accept_length(draft_tokens: Sequence[int],
                  target_tokens: Sequence[int]) -> int:
    """Longest prefix of ``draft_tokens`` matching the target's greedy
    choices.  ``target_tokens[j]`` is the target argmax at chunk
    position j (i.e. its prediction for the token *after* draft token
    j); draft token j is accepted iff every draft token before it
    matched and ``draft_tokens[j] == target_tokens[j]``."""
    a = 0
    for d, t in zip(draft_tokens, target_tokens):
        if int(d) != int(t):
            break
        a += 1
    return a


def verify_round(draft_tokens: Sequence[int],
                 target_tokens: Sequence[int]):
    """One round's emission: ``target_tokens`` has k+1 entries (the
    argmax rows of the C = k+1 verify chunk), ``draft_tokens`` has k.
    Returns ``(emitted, a)`` — the accepted prefix plus the target's own
    next token (``a+1 ≤ k+1`` tokens), and the acceptance length ``a``
    the KV/draft rollback uses."""
    a = accept_length(draft_tokens, target_tokens)
    return [int(t) for t in target_tokens[: a + 1]], a


# ----------------------------------------------------------------------
class DenseDraft:
    """A dense draft model behind the standard draft contract (module
    docstring): plain-plane Executor, own KV ring, pos-reset rollback."""

    kind = "dense"

    def __init__(self, params, cfg: ModelConfig):
        from repro.runtime.executor import Executor
        if not cfg.attention_only_stack:
            raise ValueError(f"draft {cfg.name!r} must be a causal "
                             f"attention stack (rollback = pos reset)")
        if cfg.moe is not None:
            raise ValueError(f"draft {cfg.name!r} must be dense — an MoE "
                             f"draft would compete for the h2d bus")
        self.cfg = cfg
        self._exec = Executor(params, cfg)
        self._state = None
        self._consumed = 0
        self._n_fed = 0

    @property
    def consumed(self) -> int:
        return self._consumed

    def start(self, prompt, max_len: int) -> None:
        """Prefill the draft on the prompt (1, S); the draft's KV ring is
        sized ``max_len`` (callers pass target length + k headroom so
        rejected draft feeds never wrap)."""
        prompt = jnp.asarray(prompt)
        assert prompt.ndim == 2 and prompt.shape[0] == 1
        _, self._state, _ = self._exec.prefill(prompt, max_len)
        self._consumed = int(prompt.shape[1])
        self._n_fed = 0

    def _feed(self, tok: int):
        logits, self._state, _, _ = self._exec.decode(
            self._state, jnp.asarray([[tok]], jnp.int32))
        return int(jnp.argmax(logits[0, -1]))

    def propose(self, tail: Sequence[int], k: int) -> np.ndarray:
        """Consume canonical ``tail`` (length 1 or 2), then propose k
        greedy draft tokens d_1..d_k (feeding d_1..d_{k−1})."""
        assert len(tail) >= 1, "tail must contain the last emitted token"
        # rollback: reposition over any rejected draft feeds — their ring
        # entries are masked out (kpos <= qpos) and will be overwritten
        st = self._state
        self._state = dict(st, pos=jnp.full_like(st["pos"], self._consumed))
        for t in tail:
            d = self._feed(int(t))
        self._consumed += len(tail)
        out = [d]
        self._n_fed = 0
        for _ in range(k - 1):
            d = self._feed(d)
            self._n_fed += 1
            out.append(d)
        return np.asarray(out, np.int64)

    def accept(self, a: int) -> None:
        """Round outcome: the first ``a`` proposed tokens matched the
        target and are now canonical; of those the draft fed itself
        ``min(a, k−1)`` — keep them, roll position back over the rest."""
        self._consumed += min(int(a), self._n_fed)
        self._n_fed = 0


# ----------------------------------------------------------------------
class ReplayDraft:
    """Replays a reference continuation as the draft (module docstring).

    ``reference`` is the full canonical stream (prompt + greedy
    continuation of the *target*), so proposals are exactly what the
    target will emit — acceptance 1.0 — except every ``miss_every``-th
    proposal is deliberately corrupted to force a rejection
    (``miss_every=0`` never misses).  Mirrors the dense draft's
    ``consumed`` arithmetic exactly so the engines cannot tell them
    apart."""

    kind = "replay"

    def __init__(self, reference, *, miss_every: int = 0,
                 vocab_size: int = 512):
        self._ref = np.asarray(reference).reshape(-1).astype(np.int64)
        self.miss_every = int(miss_every)
        self.vocab_size = int(vocab_size)
        self._consumed = 0
        self._n_fed = 0
        self._n_proposed = 0

    @property
    def consumed(self) -> int:
        return self._consumed

    def start(self, prompt, max_len: int) -> None:
        prompt = np.asarray(prompt).reshape(-1)
        assert prompt.size <= self._ref.size and \
            np.array_equal(prompt, self._ref[: prompt.size]), \
            "replay reference must start with the prompt"
        self._consumed = int(prompt.size)
        self._n_fed = 0
        self._n_proposed = 0

    def propose(self, tail: Sequence[int], k: int) -> np.ndarray:
        self._consumed += len(tail)
        out: List[int] = []
        for j in range(k):
            idx = self._consumed + j
            t = int(self._ref[idx]) if idx < self._ref.size else 0
            self._n_proposed += 1
            if self.miss_every and self._n_proposed % self.miss_every == 0:
                t = (t + 1) % self.vocab_size
            out.append(t)
        self._n_fed = k - 1
        return np.asarray(out, np.int64)

    def accept(self, a: int) -> None:
        self._consumed += min(int(a), self._n_fed)
        self._n_fed = 0


def make_draft(name: Optional[str], seed: int = 0):
    """Build a :class:`DenseDraft` from a registered config name (the
    ``--draft-config`` path).  Random-init weights, like every in-repo
    engine — output parity never depends on draft quality."""
    if name is None:
        return None
    import jax

    from repro.configs import get_config
    from repro.models.transformer import init_model
    cfg = get_config(name)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    return DenseDraft(params, cfg)
