"""Packed expert weights: host-side store + per-layer device buffer pool.

This is the data plane of the paper's offloading system (DESIGN.md §6).
Expert weights are HQQ-quantized once and then *stay packed*:

* :class:`PackedExperts` — one triple of stacked :class:`~repro.quant.hqq.QTensor`
  (``w_gate``/``w_up``/``w_down``).  The same container describes all three
  residency tiers, distinguished only by its leading axes:

  - **host store**  ``(L_moe, E, ...)`` — every expert of every MoE layer,
    host-resident (on TPU: pinned host memory; on this CPU host: plain
    arrays).  Never dequantized as a whole.
  - **LRU pool**    ``(L_moe, cache_size, ...)`` — the per-layer device
    buffer pool of ``k`` expert slots the paper keeps resident.
  - **staging**     ``(L_moe, num_speculative, ...)`` — the speculative
    prefetch buffers ("the newly loaded experts do not replace the
    currently cached experts").

* :class:`PoolState` — the jit-carried mutable state: the stacked LRU
  state machine (``core/lru_cache``), both buffer tiers, and the transfer
  counters.

* :func:`acquire` — serve one layer's routed experts: the LRU state
  machine (:func:`~repro.core.lru_cache.access_plan`) decides slots and
  byte sources, and this function *performs* the implied swaps —
  host-store gathers for demand misses, staging→pool promotion for
  speculative hits — returning the packed slot contents the MoE kernel
  computes with (``models/moe.moe_apply_packed``).

* :func:`stage` — speculative prefetch into the lookahead layer's staging
  buffers (:func:`~repro.core.lru_cache.stage_plan` decides which
  predictions cost a host transfer vs a device-local copy).

Everything below is pure/jittable; the slot index of ``cache_ids`` in the
LRU state IS the pool slot index, so the state machine and the buffers
cannot drift apart.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OffloadSpec, parse_block
from repro.core import lru_cache as LC
from repro.quant import hqq

EXPERT_MATS = ("w_gate", "w_up", "w_down")

# Fault-injection site name for the h2d fetch this module's ``acquire``
# performs (DESIGN.md §14).  ``acquire`` itself is jit-pure — it cannot
# consult a host-side injector — so the executor's per-layer Python loop
# injects AT this boundary: a fired fault means "the gather h2d for this
# layer's routed experts failed", and the executor retries or degrades
# to store-direct streaming (``models/moe.moe_apply_packed_stream``)
# without ever entering the pool path for that layer.
FAULT_SITE = "expert_fetch"


class PackedExperts(NamedTuple):
    """Stacked packed expert weights (see module docstring for tiers)."""

    w_gate: hqq.QTensor
    w_up: hqq.QTensor
    w_down: hqq.QTensor

    @property
    def n_layers(self) -> int:
        return self.w_gate.shape[0]

    @property
    def n_slots(self) -> int:
        """Second leading axis: E for the store, k/n_spec for the pools."""
        return self.w_gate.shape[1]

    def slice(self, *idx) -> "PackedExperts":
        return PackedExperts(*(hqq.slice_leading(qt, idx) for qt in self))

    def nbytes(self) -> int:
        return sum(hqq.nbytes(qt) for qt in self)


class PoolState(NamedTuple):
    """Jit-carried offload state (donated through the decode loop)."""

    lru: LC.LayerCacheState   # leaves stacked (L_moe, ...)
    pool: PackedExperts       # (L_moe, cache_size, ...)
    staging: PackedExperts    # (L_moe, num_speculative, ...)
    counts: jnp.ndarray  # (4,) i32: hits, spec_hits, demand, spec_loads


# ----------------------------------------------------------------------
# construction
def _reshape_leading(qt: hqq.QTensor, lead: Tuple[int, ...]) -> hqq.QTensor:
    """(P*E, ...) leaves -> ``lead``-shaped leading axes."""
    r = lambda a: a.reshape(lead + a.shape[1:])
    meta = None if qt.meta is None else {k: r(v) for k, v in qt.meta.items()}
    return hqq.QTensor(r(qt.packed), r(qt.scale), r(qt.zero), meta,
                       qt.bits, qt.group_size, lead + qt.shape[1:])


def build_store(params, cfg: ModelConfig, spec: OffloadSpec) -> PackedExperts:
    """Quantize every MoE layer's experts into the layer-major packed host
    store, bitwise the same quantization ``quantize_for_offload`` applies
    before its (oracle-only) dequantization — the packed-execution parity
    invariant rests on this.
    """
    assert cfg.moe is not None, "packed store targets MoE architectures"
    if any(parse_block(k)[1] == "moe" for k in cfg.tail_kinds()):
        raise ValueError("packed offloading supports fully-scanned MoE "
                         "stacks (no MoE tail layers)")
    pos = [i for i, k in enumerate(cfg.block_pattern)
           if parse_block(k)[1] == "moe"]
    gs = hqq.PAPER_SCHEMES[spec.expert_bits]["group_size"]
    P = cfg.n_periods
    per_pos = []
    for p in pos:
        leafs = params["stack"][p]["moe"]["experts"]
        mats = {}
        for name in EXPERT_MATS:
            leaf = leafs[name]          # (P, E, K, N)
            K = leaf.shape[-2]
            if K % gs:
                raise ValueError(
                    f"packed offloading needs expert contraction dims "
                    f"divisible by the {spec.expert_bits}-bit group size "
                    f"{gs}; got {name} with K={K}")
            # identical call shape to quantize_for_offload's quant_leaf
            mat = leaf.reshape(-1, *leaf.shape[-2:])
            qt = hqq.quantize(mat, spec.expert_bits)
            mats[name] = _reshape_leading(qt, leaf.shape[:2])  # (P, E, ...)
        per_pos.append(mats)

    def layer_major(name):
        # execution order is period-major over the pattern's MoE positions
        qts = [m[name] for m in per_pos]
        E = qts[0].shape[1]
        tail = qts[0].shape[2:]
        L = P * len(qts)
        if len(qts) == 1:
            src = qts[0]
            leaves = (src.packed, src.scale, src.zero)
            meta = src.meta
        else:
            st = lambda f: jnp.stack([getattr(q, f) for q in qts], axis=1)
            leaves = (st("packed"), st("scale"), st("zero"))
            meta = None if qts[0].meta is None else \
                {k: jnp.stack([q.meta[k] for q in qts], axis=1)
                 for k in qts[0].meta}
        nlead = 1 if len(qts) == 1 else 2
        r = lambda a: a.reshape((L, E) + a.shape[nlead + 1:])
        meta = None if meta is None else {k: r(v) for k, v in meta.items()}
        return hqq.QTensor(r(leaves[0]), r(leaves[1]), r(leaves[2]), meta,
                           qts[0].bits, qts[0].group_size, (L, E) + tail)

    return PackedExperts(*(layer_major(n) for n in EXPERT_MATS))


def init_pool_state(store: PackedExperts, spec: OffloadSpec) -> PoolState:
    """Zero-filled buffer pool + staging tier + cold LRU state for a store."""
    L = store.n_layers

    def tier(n_slots: int) -> PackedExperts:
        def zqt(qt: hqq.QTensor) -> hqq.QTensor:
            z = lambda a: jnp.zeros((L, n_slots) + a.shape[2:], a.dtype)
            meta = None if qt.meta is None else \
                {k: z(v) for k, v in qt.meta.items()}
            return hqq.QTensor(z(qt.packed), z(qt.scale), z(qt.zero), meta,
                               qt.bits, qt.group_size,
                               (L, n_slots) + qt.shape[2:])
        return PackedExperts(*(zqt(qt) for qt in store))

    return PoolState(
        lru=LC.init_model_state(L, spec.cache_size, spec.num_speculative),
        pool=tier(spec.cache_size),
        staging=tier(spec.num_speculative),
        counts=jnp.zeros((4,), jnp.int32),
    )


def per_expert_nbytes(store: PackedExperts) -> float:
    """Measured packed bytes of ONE expert (all three matrices) — what a
    demand load or speculative prefetch actually copies host->device."""
    return store.nbytes() / (store.n_layers * store.n_slots)


# ----------------------------------------------------------------------
# jit-side slot plumbing
def _qt_where(pred, a: hqq.QTensor, b: hqq.QTensor) -> hqq.QTensor:
    w = lambda x, y: jnp.where(pred, x, y)
    meta = None if a.meta is None else \
        {k: w(a.meta[k], b.meta[k]) for k in a.meta}
    return hqq.QTensor(w(a.packed, b.packed), w(a.scale, b.scale),
                       w(a.zero, b.zero), meta, a.bits, a.group_size,
                       a.shape)


def _qt_set(qt: hqq.QTensor, l, s, sub: hqq.QTensor) -> hqq.QTensor:
    u = lambda a, v: a.at[l, s].set(v)
    meta = None if qt.meta is None else \
        {k: u(qt.meta[k], sub.meta[k]) for k in qt.meta}
    return hqq.QTensor(u(qt.packed, sub.packed), u(qt.scale, sub.scale),
                       u(qt.zero, sub.zero), meta, qt.bits, qt.group_size,
                       qt.shape)


def _pe_set(pe: PackedExperts, l, s, sub: PackedExperts) -> PackedExperts:
    return PackedExperts(*(_qt_set(qt, l, s, sq)
                           for qt, sq in zip(pe, sub)))


def _pe_where(pred, a: PackedExperts, b: PackedExperts) -> PackedExperts:
    return PackedExperts(*(_qt_where(pred, x, y) for x, y in zip(a, b)))


def qt_stack(qts) -> hqq.QTensor:
    """Stack homogeneous QTensors along a new leading axis."""
    st = lambda xs: jnp.stack(xs)
    q0 = qts[0]
    meta = None if q0.meta is None else \
        {k: st([q.meta[k] for q in qts]) for k in q0.meta}
    return hqq.QTensor(st([q.packed for q in qts]),
                       st([q.scale for q in qts]),
                       st([q.zero for q in qts]), meta,
                       q0.bits, q0.group_size, (len(qts),) + q0.shape)


def pe_stack(pes) -> PackedExperts:
    return PackedExperts(*(qt_stack([getattr(p, n) for p in pes])
                           for n in EXPERT_MATS))


def _qt_gather(qt: hqq.QTensor, l, idx: jnp.ndarray) -> hqq.QTensor:
    """Gather ``idx`` (n,) slices of layer ``l`` from a (L, S, ...) stacked
    QTensor as ONE indexed read per leaf — the vectorized replacement for
    n sequential ``slice_leading`` + ``qt_stack`` round trips."""
    g = lambda a: a[l, idx]
    meta = None if qt.meta is None else {k: g(v) for k, v in qt.meta.items()}
    return hqq.QTensor(g(qt.packed), g(qt.scale), g(qt.zero), meta,
                       qt.bits, qt.group_size,
                       (idx.shape[0],) + tuple(qt.shape[2:]))


def pe_gather(pe: PackedExperts, l, idx: jnp.ndarray) -> PackedExperts:
    """(L, S, ...) tier -> (n, ...) gathered slices at ``idx`` (n,)."""
    return PackedExperts(*(_qt_gather(qt, l, idx) for qt in pe))


def _qt_where_rows(mask: jnp.ndarray, a: hqq.QTensor, b: hqq.QTensor
                   ) -> hqq.QTensor:
    """Row-wise select between two (n, ...) stacked QTensors; ``mask`` is
    (n,) bool, broadcast over each leaf's trailing axes."""
    def w(x, y):
        m = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    meta = None if a.meta is None else \
        {k: w(a.meta[k], b.meta[k]) for k in a.meta}
    return hqq.QTensor(w(a.packed, b.packed), w(a.scale, b.scale),
                       w(a.zero, b.zero), meta, a.bits, a.group_size,
                       a.shape)


def pe_where_rows(mask, a: PackedExperts, b: PackedExperts) -> PackedExperts:
    return PackedExperts(*(_qt_where_rows(mask, x, y) for x, y in zip(a, b)))


def _pe_set_row(pe: PackedExperts, l, mask: jnp.ndarray,
                new_row: PackedExperts) -> PackedExperts:
    """Write layer ``l``'s whole (S, ...) row of a tier in one update,
    keeping old contents where ``mask`` (S,) is False."""
    def upd(qt: hqq.QTensor, sub: hqq.QTensor) -> hqq.QTensor:
        def u(a, v):
            m = mask.reshape(mask.shape + (1,) * (v.ndim - 1))
            return a.at[l].set(jnp.where(m, v, a[l]))
        meta = None if qt.meta is None else \
            {k: u(qt.meta[k], sub.meta[k]) for k in qt.meta}
        return hqq.QTensor(u(qt.packed, sub.packed), u(qt.scale, sub.scale),
                           u(qt.zero, sub.zero), meta, qt.bits,
                           qt.group_size, qt.shape)
    return PackedExperts(*(upd(qt, sq) for qt, sq in zip(pe, new_row)))


# ----------------------------------------------------------------------
def acquire(store: PackedExperts, st: PoolState, l, ids: jnp.ndarray,
            active: Optional[jnp.ndarray] = None, *,
            vectorized: bool = True) -> Tuple[PoolState, PackedExperts]:
    """Serve layer ``l``'s routed experts ``ids`` (T, K) from its buffer
    pool, performing the slot swaps the LRU state machine decides.

    Returns ``(st', served)`` where ``served`` holds the packed weights
    each (token, k) pair computes with, stacked ``(T*K, ...)`` leading —
    captured *at access time*, so a later eviction within the same batch
    cannot corrupt an earlier token's weights.

    ``active`` (T,) bool masks rows whose output is discarded (free slots
    of a continuous-batching batch): they bypass the cache entirely —
    weights straight from the host store, no state change, no accounting.

    ``vectorized`` (default) performs all swaps as one batched
    gather/scatter over the whole-batch plan (DESIGN.md §7);
    ``vectorized=False`` is the PR-2 per-(token, k) sequential data plane,
    kept as the measured baseline of ``benchmarks/offload_bench.py``.
    Both are bitwise-identical (tested).
    """
    if vectorized:
        return _acquire_vectorized(store, st, l, ids, active)
    return _acquire_unrolled(store, st, l, ids, active)


def _acquire_vectorized(store: PackedExperts, st: PoolState, l,
                        ids: jnp.ndarray,
                        active: Optional[jnp.ndarray] = None
                        ) -> Tuple[PoolState, PackedExperts]:
    """One-gather/one-scatter data plane (DESIGN.md §7).

    The state machine plans the whole batch (:func:`~repro.core.lru_cache.
    access_plan_batch`); the pool row is then rewritten in ONE masked
    scatter — every written slot receives the store bytes of the expert
    the final LRU table says lives there, which is exactly what the
    sequential swap sequence leaves behind (slot contents are a function
    of the final ``cache_ids``, the coherence invariant §6 tests) — and
    the served weights come from ONE batched gather: pool slots for
    accesses that survive the batch, host store for the rest (bitwise
    identical either way, since a pool slot always holds its expert's
    store bytes).
    """
    T, K = ids.shape
    lru = LC.layer_slice(st.lru, l)
    new_lru, delta, plan = LC.access_plan_batch(lru, ids, active)
    # scatter: rewrite the written pool slots from the store in one update
    safe_ids = jnp.clip(new_lru.cache_ids, 0, store.n_slots - 1)
    pool = _pe_set_row(st.pool, l, plan.written,
                       pe_gather(store, l, safe_ids))
    # gather: serve every access from its pool slot when it survived the
    # batch, else from the store (access-time capture)
    flat = ids.reshape(T * K)
    from_pool = pe_gather(pool, l, plan.slots.reshape(T * K))
    from_store = pe_gather(store, l, flat)
    served = pe_where_rows(plan.survives.reshape(T * K),
                           from_pool, from_store)
    st = PoolState(LC.set_layer(st.lru, l, new_lru), pool, st.staging,
                   st.counts + delta)
    return st, served


def _acquire_unrolled(store: PackedExperts, st: PoolState, l,
                      ids: jnp.ndarray,
                      active: Optional[jnp.ndarray] = None
                      ) -> Tuple[PoolState, PackedExperts]:
    """PR-2 sequential data plane: T*K full-tensor where/set updates plus
    a ``pe_stack`` of per-access weight copies.  Kept (unused by the
    engines) as the synchronous baseline ``benchmarks/offload_bench.py``
    measures the vectorized plane against."""
    T, K = ids.shape
    lru = LC.layer_slice(st.lru, l)
    pool, staging = st.pool, st.staging
    counts = st.counts
    served = []
    for t in range(T):
        act = None if active is None else active[t]
        new_lru, stats, plan = LC.access_plan(lru, ids[t])
        for j in range(K):
            from_store = store.slice(l, ids[t, j])
            from_pool = pool.slice(l, plan.slots[j])
            from_stag = staging.slice(l, plan.spec_slot[j])
            content = _pe_where(
                plan.in_cache[j], from_pool,
                _pe_where(plan.in_spec[j], from_stag, from_store))
            if act is not None:
                content = _pe_where(act, content, from_store)
                write = _pe_where(act, content, from_pool)
            else:
                write = content
            pool = _pe_set(pool, l, plan.slots[j], write)
            served.append(content)
        delta = jnp.stack([stats.hits, stats.spec_hits, stats.demand_loads,
                           jnp.zeros((), jnp.int32)])
        if act is not None:
            new_lru = jax.tree.map(lambda n, o: jnp.where(act, n, o),
                                   new_lru, lru)
            delta = jnp.where(act, delta, 0)
        lru = new_lru
        counts = counts + delta
    st = PoolState(LC.set_layer(st.lru, l, lru), pool, staging, counts)
    return st, pe_stack(served)


def stage(store: PackedExperts, st: PoolState, tgt, predicted: jnp.ndarray,
          valid, *, vectorized: bool = True) -> PoolState:
    """Stage ``predicted`` (n_spec,) experts into layer ``tgt``'s staging
    buffers (the paper's speculative prefetch, fired while the current
    layer computes).  ``valid`` gates the whole update (False when the
    lookahead runs past the last MoE layer).  Buffer contents are sourced
    per :func:`~repro.core.lru_cache.stage_plan`: residents copy
    device-locally (pool slot / previous staging buffer), everything else
    streams from the host store — only those count as transfers.

    ``vectorized`` (default) fills the whole staging row with one gather
    (DESIGN.md §7); ``vectorized=False`` is the PR-2 per-buffer loop,
    kept for the offload benchmark's baseline.  Bitwise identical: every
    staged buffer ends up holding its prediction's store bytes whichever
    resident tier the sequential plane copies them from.
    """
    n_spec = predicted.shape[0]
    if n_spec == 0:
        return st
    L = store.n_layers
    tgt_c = jnp.clip(tgt, 0, L - 1)
    lru = LC.layer_slice(st.lru, tgt_c)
    new_lru, plan, transfers = LC.stage_plan(lru, predicted)
    if vectorized:
        # one gather fills the whole staging row with the predictions'
        # store bytes (== whatever resident tier the sequential plane
        # would have copied them from)
        fill = pe_gather(store, tgt_c,
                         jnp.clip(predicted, 0, store.n_slots - 1))
        mask = jnp.broadcast_to(jnp.asarray(valid), (n_spec,))
        staging = _pe_set_row(st.staging, tgt_c, mask, fill)
    else:
        old_staging = st.staging  # pre-update contents: sources intact
        staging = st.staging
        for j in range(n_spec):
            content = _pe_where(
                plan.in_cache[j], st.pool.slice(tgt_c, plan.cache_slot[j]),
                _pe_where(plan.in_old_spec[j],
                          old_staging.slice(tgt_c, plan.old_spec_slot[j]),
                          store.slice(tgt_c, predicted[j])))
            keep = old_staging.slice(tgt_c, j)
            staging = _pe_set(staging, tgt_c, j,
                              _pe_where(valid, content, keep))
    new_lru = jax.tree.map(lambda n, o: jnp.where(valid, n, o), new_lru, lru)
    counts = st.counts + jnp.where(valid, transfers, 0) * \
        jnp.asarray([0, 0, 0, 1], jnp.int32)
    return PoolState(LC.set_layer(st.lru, tgt_c, new_lru), st.pool,
                     staging, counts)
