"""The offloaded-inference engine (paper §3.3 system design).

Drives interactive (batch-1) autoregressive generation of an MoE model
under the paper's full algorithm:

* per-layer **LRU cache** of ``k`` experts (``core/lru_cache``),
* **speculative prefetch** of the lookahead layer's likely experts from the
  current layer's hidden state (``core/speculative``),
* **mixed quantization**: experts at 2/3-bit HQQ, shared layers at 4-bit
  (``quant/hqq``),
* byte-accurate transfer accounting (contiguous per-expert buffers — one
  copy per expert, matching the paper's pinned-buffer design).

Two execution modes (DESIGN.md §3/§6):

* **accounting** (``quantized=False``): the model decodes normally and
  the engine replays its routing decisions through ``PyLRU`` — offloading
  as *pure scheduling*, so generated tokens are bit-identical to plain
  decoding (tested).  This is the trace/ablation mode behind the Fig-2 /
  Table-2 benchmarks.
* **packed** (``quantized=True``, the default for quantized engines):
  expert weights stay HQQ-packed in a host-side store and stream through
  a per-layer device buffer pool of ``cache_size`` slots, driven by the
  jit-compatible LRU state machine (``core/lru_cache.access_plan`` /
  ``stage_plan`` decide the slot swaps, ``core/expert_pool`` performs
  them).  MoE compute reads the packed slots directly
  (``models/moe.moe_apply_packed`` -> ``kernels/ops.dequant_matmul``).
  Generated tokens are bit-identical to decoding the dequantized model
  (tested), transfer byte counts are *measured* packed copies, and no
  dense expert stack is ever materialized outside per-slot dequant.

``PyLRU`` and the jit state machine are property-tested equal — including
the eviction sequence — in
``tests/test_lru.py::test_jnp_matches_python_oracle``.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OffloadSpec, parse_block
from repro.core import cost_model, expert_pool as EP, speculative
from repro.core.lru_cache import PyLRU
from repro.core.trace import moe_positions, stacked_routers
from repro.models import transformer as T
from repro.quant import hqq
from repro.runtime import Executor
from repro.serving.sampler import SamplerConfig, sample


@dataclass
class OffloadStats:
    n_tokens: int = 0
    hits: int = 0
    spec_hits: int = 0
    demand_loads: int = 0
    spec_loads: int = 0
    expert_bytes: float = 0.0  # per expert (quantized)

    @property
    def accesses(self) -> int:
        return self.hits + self.spec_hits + self.demand_loads

    @property
    def hit_ratio(self) -> float:
        return (self.hits + self.spec_hits) / max(1, self.accesses)

    def per_token(self) -> cost_model.TokenStats:
        n = max(1, self.n_tokens)
        return cost_model.TokenStats(
            demand_loads=self.demand_loads / n,
            spec_loads=self.spec_loads / n,
            hits=self.hits / n,
            spec_hits=self.spec_hits / n,
        )

    @property
    def bytes_h2d(self) -> float:
        return (self.demand_loads + self.spec_loads) * self.expert_bytes


# ----------------------------------------------------------------------
def routing_from_info(cfg: ModelConfig, info_stack, want_hiddens=True):
    """Unpack one ``decode_step(..., collect_info=True)`` result into
    layer-major per-MoE-layer routing: returns (ids, hiddens) lists of
    length n_moe_layers with arrays (B, top_k) int32 and (B, D)
    (``hiddens`` is empty with ``want_hiddens=False``, skipping that
    device->host transfer for callers that only count expert ids).

    This is the single decode-side source of routing truth, shared by the
    offload accounting below and by the serving scheduler's expert-overlap
    policy (``serving/scheduler.ExpertOverlapPolicy``).
    """
    ids, hiddens = [], []
    for per in range(cfg.n_periods):
        for i in range(cfg.pattern_period):
            info = info_stack[i]
            if "route" not in info:
                continue
            ids.append(np.asarray(info["route"]["ids"][per]))
            if want_hiddens:
                hiddens.append(np.asarray(info["hidden_pre_moe"][per]))
    return ids, hiddens


class ExpertUsageTracker:
    """Decayed per-MoE-layer histogram of expert activations.

    Tracks which experts the in-flight batch has recently routed to —
    i.e. what the offload engine's per-layer caches are hot with.  The
    continuous-batching admission policy scores waiting requests by
    overlap with this histogram (MoBiLE-style expert-aware grouping:
    admitting requests that reuse the already-loaded experts amortises
    expert-load cost on memory-constrained hardware).
    """

    def __init__(self, n_layers: int, n_experts: int, decay: float = 0.9):
        self.n_layers = n_layers
        self.n_experts = n_experts
        self.decay = decay
        self.counts = np.zeros((n_layers, n_experts), np.float64)

    @classmethod
    def for_config(cls, cfg: ModelConfig, decay: float = 0.9
                   ) -> "ExpertUsageTracker":
        n = len(moe_positions(cfg)) * cfg.n_periods
        return cls(n, cfg.moe.num_experts, decay)

    def update(self, ids_per_layer, rows=None) -> None:
        """ids_per_layer: list of (B, K) int32 (from ``routing_from_info``);
        ``rows`` restricts accounting to the active batch rows."""
        self.counts *= self.decay
        for l, ids in enumerate(ids_per_layer):
            sel = ids if rows is None else ids[np.asarray(rows, np.int64)]
            np.add.at(self.counts[l], np.asarray(sel).ravel(), 1.0)

    def normalized(self) -> np.ndarray:
        """(L, E) rows summing to 1 (uniform when a layer has no counts)."""
        tot = self.counts.sum(-1, keepdims=True)
        uniform = np.full_like(self.counts, 1.0 / self.n_experts)
        return np.where(tot > 0, self.counts / np.maximum(tot, 1e-9), uniform)

    def overlap(self, pred_ids_per_layer) -> float:
        """Score a candidate's predicted expert set against the in-flight
        histogram: expected fraction of its expert hits already hot.
        Normalized by the layers actually scored — a candidate supplying
        more prediction lists than the tracker holds layers must not have
        its score deflated by the unscored surplus."""
        hist = self.normalized()
        score = 0.0
        scored = pred_ids_per_layer[: self.n_layers]
        for l, ids in enumerate(scored):
            score += float(hist[l, np.asarray(ids, np.int64).ravel()].sum())
        return score / max(1, len(scored))


# ----------------------------------------------------------------------
def quantize_for_offload(params, cfg: ModelConfig, spec: OffloadSpec, *,
                         pack_experts: bool = False):
    """Mixed quantization of the model (paper §3.3): experts at
    ``spec.expert_bits``, attention/shared weights at ``spec.attn_bits``;
    embeddings / router / norms stay 16-bit.

    By default returns ``(exec_params, size_report)`` with every
    quantized weight eagerly dequantized back to dense — this is the
    *parity oracle* (what a dequantize-then-matmul execution computes),
    NOT the memory-saving path; ``size_report`` carries the true packed
    sizes.

    With ``pack_experts=True`` expert weights are never dequantized:
    returns ``(exec_params, size_report, store)`` where ``store`` is the
    packed host store (``core/expert_pool.build_store``, bitwise the same
    quantization as the oracle path) and ``exec_params`` carries
    zero-size placeholders for the expert stacks — the packed engine
    below computes MoE straight from the store/pool, so no dense expert
    tensor exists to materialize.  ``size_report["experts"]`` is then the
    measured store size.
    """
    qsizes = {"experts": 0, "attn": 0, "fp16": 0}
    dtype = jnp.dtype(cfg.dtype)
    store = EP.build_store(params, cfg, spec) if pack_experts else None

    def quant_leaf(path, leaf, bits):
        if leaf.ndim < 2:
            qsizes["fp16"] += leaf.size * 2
            return leaf
        name = path[-1]
        if "experts" in path:
            mat = leaf.reshape(-1, *leaf.shape[-2:])  # (E, K, N)
        elif name in ("wq", "wk", "wv"):
            mat = leaf.reshape(leaf.shape[0], -1)  # (D, H*hd)
        elif name == "wo":
            mat = leaf.reshape(-1, leaf.shape[-1])  # (H*hd, D)
        else:
            mat = leaf
        gs = hqq.PAPER_SCHEMES[bits]["group_size"]
        if mat.shape[-2] % gs:
            qsizes["fp16"] += leaf.size * 2
            return leaf
        qt = hqq.quantize(mat, bits)
        key = "experts" if "experts" in path else "attn"
        qsizes[key] += hqq.nbytes(qt)
        return hqq.dequantize(qt, dtype).reshape(leaf.shape)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        name = path[-1]
        if "experts" in path:
            if pack_experts:
                # weights live packed in the host store; leave a zero-size
                # placeholder so the param tree keeps its structure (and
                # nothing dense can be computed with by accident)
                return jnp.zeros(tree.shape[:1] + (0,), tree.dtype)
            return quant_leaf(path, tree, spec.expert_bits)
        if name in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                    "w_in", "w_out"):
            return quant_leaf(path, tree, spec.attn_bits)
        qsizes["fp16"] += tree.size * 2
        return tree

    exec_params = walk(params, ())
    if pack_experts:
        qsizes["experts"] = store.nbytes()
    qsizes["total"] = qsizes["experts"] + qsizes["attn"] + qsizes["fp16"]
    if pack_experts:
        return exec_params, qsizes, store
    return exec_params, qsizes


# ----------------------------------------------------------------------
def PackedDecoder(params, cfg: ModelConfig, spec: OffloadSpec, store,
                  *, fused: bool = True, pipelined: bool = True,
                  vectorized: bool = True) -> Executor:
    """Compat constructor for the pre-runtime layerwise packed decoder:
    the block programs now live in :class:`repro.runtime.Executor`
    (DESIGN.md §8) — this returns a packed-plane executor with the same
    ``decode`` / ``prefill`` / ``init_pool_state`` surface and the same
    cached program keys."""
    plane = "packed_pipelined" if pipelined else "packed_vectorized"
    return Executor(params, cfg, plane=plane, spec=spec, store=store,
                    fused=fused, vectorized=vectorized)


# ----------------------------------------------------------------------
class OffloadEngine:
    """Stateful wrapper around one model + offload configuration.

    ``quantized=False`` — accounting mode (pure scheduling, PyLRU replay).
    ``quantized=True``  — packed mode: real HQQ-packed execution through
    the device buffer pool (module docstring).  ``packed=False`` opts a
    quantized engine back into accounting over the eagerly-dequantized
    model (the parity oracle the packed mode is tested against).
    """

    def __init__(self, params, cfg: ModelConfig,
                 spec: Optional[OffloadSpec] = None, quantized: bool = False,
                 *, packed: Optional[bool] = None, fused: bool = True,
                 pipelined: bool = True, vectorized: bool = True,
                 telemetry=None, draft=None, num_draft_tokens: int = 0):
        assert cfg.moe is not None, "offloading targets MoE architectures"
        self.cfg = cfg
        self.spec = spec or cfg.offload or OffloadSpec()
        self.size_report = None
        self.packed = bool(quantized) if packed is None else bool(packed)
        if self.packed and not quantized:
            raise ValueError("packed execution requires quantized=True "
                             "(the store holds HQQ-packed experts)")
        self.store = None
        self._decoder = None
        self._last_pool_state = None
        if quantized:
            if self.packed:
                params, self.size_report, self.store = quantize_for_offload(
                    params, cfg, self.spec, pack_experts=True)
            else:
                params, self.size_report = quantize_for_offload(
                    params, cfg, self.spec)
        self.params = params
        self.routers = stacked_routers(params, cfg)  # (L_moe, D, E)
        self.n_moe_layers = self.routers.shape[0]
        if self.packed:
            # packed planes of the unified runtime (DESIGN.md §8)
            self._decoder = PackedDecoder(params, cfg, self.spec, self.store,
                                          fused=fused, pipelined=pipelined,
                                          vectorized=vectorized)
            self._exec = self._decoder
            # measured: what one demand load / prefetch actually copies
            self.expert_bytes = EP.per_expert_nbytes(self.store)
        else:
            self._exec = Executor(params, cfg)
            eff_bits = cost_model.EFFECTIVE_BITS[
                self.spec.expert_bits if quantized else 16]
            self.expert_bytes = (cost_model.expert_param_count(cfg)
                                 * eff_bits / 8.0)
        # live routing histogram, readable by serving-admission policies
        self.usage = ExpertUsageTracker(self.n_moe_layers,
                                        cfg.moe.num_experts)
        # telemetry plane (DESIGN.md §10): cumulative transfer accounting
        # feeds the offload collector; each generate() closes one
        # roofline window from the stats it already computed (zero extra
        # device fetches) and traces its prefill/decode spans
        from repro.obs import Telemetry, jit_cache_metrics
        self.obs = telemetry if telemetry is not None else Telemetry.off()
        self.last_stats: Optional[OffloadStats] = None
        self._cum = OffloadStats(expert_bytes=self.expert_bytes)
        self.obs.registry.register_collector("offload", self._offload_metrics)
        self.obs.registry.register_collector("jit", jit_cache_metrics)
        self._gen_count = 0
        # token-level draft-and-verify (DESIGN.md §11): engine-level
        # defaults; generate(draft=, num_draft_tokens=) overrides
        self.draft = draft
        self.num_draft_tokens = int(num_draft_tokens or 0)
        self._spec_metrics = None
        if self.draft is not None and self.num_draft_tokens >= 1:
            self._ensure_spec_metrics()
        if self.obs.timing:
            self.obs.declare_request_schema()
            self._exec.set_observer(self.obs.exec_observer(self._exec.plane))
            self.obs.attach_roofline(
                cfg,
                expert_bits=self.spec.expert_bits if quantized else 16,
                attn_bits=self.spec.attn_bits if quantized else 16,
                expert_bytes=self.expert_bytes)

    # ------------------------------------------------------------------
    def _ensure_spec_metrics(self):
        if self._spec_metrics is None:
            from repro.obs import SpecMetrics
            self._spec_metrics = SpecMetrics(self.obs.registry)
        return self._spec_metrics

    # ------------------------------------------------------------------
    def _offload_metrics(self):
        """Telemetry ``offload`` namespace: cumulative across generates
        (the same numbers every returned :class:`OffloadStats` carries —
        ``benchmarks/offload_bench.py`` asserts the two never drift)."""
        c = self._cum
        return {"hits": c.hits, "spec_hits": c.spec_hits,
                "demand_loads": c.demand_loads, "spec_loads": c.spec_loads,
                "bytes_h2d": c.bytes_h2d,
                "bytes_per_token": c.bytes_h2d / max(1, c.n_tokens)}

    def _record_generate(self, stats: OffloadStats, prompt_len: int,
                         decode_s: float) -> None:
        """Fold one generate()'s measured stats into the telemetry plane."""
        self.last_stats = stats
        c = self._cum
        c.n_tokens += stats.n_tokens
        c.hits += stats.hits
        c.spec_hits += stats.spec_hits
        c.demand_loads += stats.demand_loads
        c.spec_loads += stats.spec_loads
        if self.obs.roofline is not None and decode_s > 0:
            self.obs.roofline.add_window(
                stats.n_tokens, decode_s,
                demand_loads=stats.demand_loads,
                spec_loads=stats.spec_loads,
                hits=stats.hits, spec_hits=stats.spec_hits,
                context_len=prompt_len + stats.n_tokens / 2.0)

    def metrics(self):
        """Namespaced telemetry snapshot (``repro.obs.schema``)."""
        return self.obs.snapshot()

    # ------------------------------------------------------------------
    def generate(self, prompt: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, rng=None,
                 sampler: Optional[SamplerConfig] = None, *,
                 prefill_chunk: Optional[int] = None, draft=None,
                 num_draft_tokens: Optional[int] = None
                 ) -> Tuple[np.ndarray, OffloadStats]:
        """prompt: (1, S) int32.  Returns (generated (1, n), stats).

        Packed engines really perform the slot swaps (stats are measured
        copies); accounting engines replay routing through PyLRU.  All
        sampling routes through ``serving/sampler.py``: ``greedy=False``
        is shorthand for a plain categorical :class:`SamplerConfig`, and
        ``sampler=`` overrides (top-k / top-p / temperature).  ``rng``
        may be omitted, in which case a fixed seeded key makes sampled
        runs reproducible.  ``prefill_chunk`` chunks the prompt's prefill
        (bitwise-identical to whole-prompt prefill on every plane —
        DESIGN.md §8).

        ``draft``/``num_draft_tokens`` (defaulting to the engine-level
        settings; explicit ``num_draft_tokens=0`` disables) switch greedy
        decode to draft-and-verify speculation (DESIGN.md §11) — bitwise
        identical output, several tokens per verify chunk."""
        sampler = sampler or SamplerConfig(
            kind="greedy" if greedy else "categorical")
        if sampler.kind != "greedy" and rng is None:
            rng = jax.random.key(0)  # seeded default, not a crash in split
        draft = self.draft if draft is None else draft
        k = self.num_draft_tokens if num_draft_tokens is None \
            else int(num_draft_tokens)
        if draft is not None and k >= 1:
            if sampler.kind != "greedy":
                raise ValueError("draft-and-verify speculation is greedy "
                                 "decoding only (DESIGN.md §11)")
            return self._generate_speculative(
                prompt, max_new_tokens, draft, k,
                prefill_chunk=prefill_chunk)
        if self._decoder is not None:
            return self._generate_packed(prompt, max_new_tokens,
                                         sampler=sampler, rng=rng,
                                         prefill_chunk=prefill_chunk)
        cfg, spec = self.cfg, self.spec
        caches = [PyLRU(spec.cache_size, spec.num_speculative)
                  for _ in range(self.n_moe_layers)]
        stats = OffloadStats(expert_bytes=self.expert_bytes)

        obs = self.obs
        rid = self._gen_count
        self._gen_count += 1
        obs.req_submitted(rid, rid)
        obs.req_admitted(rid, 0)
        t_pre = obs.clock_ns() if obs.tracer is not None else 0
        max_len = prompt.shape[1] + max_new_tokens
        pre_logits, state, _ = self._exec.prefill(
            jnp.asarray(prompt), max_len, chunk=prefill_chunk)
        obs.req_chunk(rid, 0, int(prompt.shape[1]), t_pre)
        # prefill loads each layer once (paper: the encode phase "works
        # relatively well with existing algorithms"); generation-phase
        # accounting starts below.  First token comes from prefill logits.
        rng, tok = self._next_token(rng, pre_logits, sampler)
        out = [int(tok[0, 0])]
        obs.req_decode_start(rid)
        t0 = time.perf_counter() if obs.timing else 0.0
        for step_i in range(max_new_tokens - 1):
            logits, state, _, (info_stack, _) = self._exec.decode(
                state, tok, collect_info=True)
            self._account(info_stack, caches, stats)
            stats.n_tokens += 1
            rng, tok = self._next_token(rng, logits, sampler)
            out.append(int(tok[0, 0]))
        decode_s = time.perf_counter() - t0 if obs.timing else 0.0
        for c in caches:
            stats.hits += c.hits
            stats.spec_hits += c.spec_hits
            stats.demand_loads += c.demand
            stats.spec_loads += c.spec_loads
        self._record_generate(stats, int(prompt.shape[1]), decode_s)
        obs.req_finished(rid, len(out), "length")
        return np.asarray(out)[None], stats

    # ------------------------------------------------------------------
    def _next_token(self, rng, logits, sampler: SamplerConfig):
        """One sampler step over the last-position logits -> (rng', tok
        (B, 1) int32).  Greedy keeps the on-device argmax (no rng)."""
        if sampler.kind == "greedy":
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        else:
            rng, sub = jax.random.split(rng)
            nxt = sample(sub, logits[:, -1], sampler)
        return rng, nxt[:, None].astype(jnp.int32)

    # ------------------------------------------------------------------
    def _generate_packed(self, prompt: np.ndarray, max_new_tokens: int,
                         sampler: SamplerConfig, rng=None,
                         prefill_chunk: Optional[int] = None
                         ) -> Tuple[np.ndarray, OffloadStats]:
        """Packed-execution generate: prefill streams the routed experts
        from the host store chunk by chunk (``moe_apply_packed_stream``,
        no pool traffic); every decode token is served from the device
        buffer pool with the LRU/speculative machinery performing real
        slot swaps (DESIGN.md §6/§8)."""
        dec = self._decoder
        pstate = dec.init_pool_state()
        obs = self.obs
        rid = self._gen_count
        self._gen_count += 1
        obs.req_submitted(rid, rid)
        obs.req_admitted(rid, 0)
        t_pre = obs.clock_ns() if obs.tracer is not None else 0
        max_len = prompt.shape[1] + max_new_tokens
        pre_logits, state, _ = dec.prefill(jnp.asarray(prompt), max_len,
                                           chunk=prefill_chunk)
        obs.req_chunk(rid, 0, int(prompt.shape[1]), t_pre)
        rng, tok = self._next_token(rng, pre_logits, sampler)
        out = [int(tok[0, 0])]
        obs.req_decode_start(rid)
        t0 = time.perf_counter() if obs.timing else 0.0
        for _ in range(max_new_tokens - 1):
            logits, state, pstate, route_ids = dec.decode(state, tok, pstate)
            self.usage.update([np.asarray(i) for i in route_ids])
            rng, tok = self._next_token(rng, logits, sampler)
            out.append(int(tok[0, 0]))
        decode_s = time.perf_counter() - t0 if obs.timing else 0.0
        counts = np.asarray(pstate.counts)
        stats = OffloadStats(
            n_tokens=max_new_tokens - 1,
            hits=int(counts[0]), spec_hits=int(counts[1]),
            demand_loads=int(counts[2]), spec_loads=int(counts[3]),
            expert_bytes=self.expert_bytes)
        self._last_pool_state = pstate  # inspectable by tests/examples
        self._record_generate(stats, int(prompt.shape[1]), decode_s)
        obs.req_finished(rid, len(out), "length")
        return np.asarray(out)[None], stats

    # ------------------------------------------------------------------
    def _generate_speculative(self, prompt: np.ndarray, max_new_tokens: int,
                              draft, k: int, *,
                              prefill_chunk: Optional[int] = None
                              ) -> Tuple[np.ndarray, OffloadStats]:
        """Draft-and-verify greedy decode (DESIGN.md §11).

        Per round the draft proposes ``k_eff = min(k, remaining−1)``
        tokens; the target verifies them in ONE ``C = k_eff+1`` chunk
        through :meth:`Executor.decode` (one pool acquire per MoE layer
        per chunk), accepts the longest matching prefix plus its own
        next token, then rolls back: the target KV rollback is a pos
        reset only — ring/page entries past ``pos`` are dead under the
        attention validity mask and get overwritten when real tokens
        land at the same positions.  The invariant ``pos = S + n − 1``
        (n tokens emitted) holds at every round boundary, which is what
        makes the output bitwise identical to non-speculative greedy:
        each chunk position's argmax conditions on exactly the canonical
        prefix as long as every earlier draft token matched."""
        from repro.core.draft import verify_round
        # rollback is a pos reset, which only works while the KV ring has
        # never wrapped: a wrapped SWA ring would have rejected verify-
        # chunk writes overwrite the live entry `window` positions back
        win = self.cfg.sliding_window
        if (win and any(parse_block(b)[0] == "swa"
                        for b in self.cfg.block_pattern)
                and int(prompt.shape[1]) + max_new_tokens > win):
            raise ValueError(
                f"speculative decoding needs the request inside the SWA "
                f"window ({int(prompt.shape[1])} + {max_new_tokens} > "
                f"window={win}): a wrapped ring cannot roll back rejected "
                f"verify chunks")
        packed = self._decoder is not None
        dec = self._exec
        pstate = dec.init_pool_state() if packed else None
        caches = None if packed else [
            PyLRU(self.spec.cache_size, self.spec.num_speculative)
            for _ in range(self.n_moe_layers)]
        stats = OffloadStats(expert_bytes=self.expert_bytes)
        spec_m = self._ensure_spec_metrics()
        obs = self.obs
        rid = self._gen_count
        self._gen_count += 1
        obs.req_submitted(rid, rid)
        obs.req_admitted(rid, 0)
        t_pre = obs.clock_ns() if obs.tracer is not None else 0
        S = int(prompt.shape[1])
        max_len = S + max_new_tokens
        pre_logits, state, _ = dec.prefill(jnp.asarray(prompt), max_len,
                                           chunk=prefill_chunk)
        obs.req_chunk(rid, 0, S, t_pre)
        out = [int(jnp.argmax(pre_logits[0, -1]))]
        # the draft's KV ring needs k extra positions of headroom: after
        # a rejection it has fed itself up to k−1 tokens past the stream
        draft.start(np.asarray(prompt), max_len + k)
        prompt_list = [int(t) for t in np.asarray(prompt).reshape(-1)]
        obs.req_decode_start(rid)
        t0 = time.perf_counter() if obs.timing else 0.0

        def _one_step(tok):
            nonlocal state, pstate
            if packed:
                logits, state, pstate, route_ids = dec.decode(
                    state, tok, pstate)
                self.usage.update([np.asarray(i) for i in route_ids])
            else:
                logits, state, _, (info_stack, _) = dec.decode(
                    state, tok, collect_info=True)
                self._account(info_stack, caches, stats)
            return logits

        while len(out) < max_new_tokens:
            k_eff = min(k, max_new_tokens - len(out) - 1)
            if k_eff < 1:
                # last token: a plain C=1 step
                logits = _one_step(jnp.asarray([[out[-1]]], jnp.int32))
                stats.n_tokens += 1
                out.append(int(jnp.argmax(logits[0, -1])))
                continue
            canon = prompt_list + out
            d = draft.propose(canon[draft.consumed:], k_eff)
            chunk = np.concatenate(
                [[out[-1]], np.asarray(d)]).astype(np.int32)[None]
            logits = _one_step(jnp.asarray(chunk))
            tgt = np.asarray(jnp.argmax(logits[0], -1))  # (k_eff+1,)
            emitted, a = verify_round(d, tgt)
            out.extend(emitted)
            stats.n_tokens += len(emitted)
            # target KV rollback: pos reset to the canonical frontier
            state = dict(state, pos=jnp.full_like(state["pos"],
                                                  S + len(out) - 1))
            draft.accept(a)
            spec_m.round(k_eff, a)

        decode_s = time.perf_counter() - t0 if obs.timing else 0.0
        if packed:
            counts = np.asarray(pstate.counts)
            stats.hits = int(counts[0])
            stats.spec_hits = int(counts[1])
            stats.demand_loads = int(counts[2])
            stats.spec_loads = int(counts[3])
            self._last_pool_state = pstate
        else:
            for c in caches:
                stats.hits += c.hits
                stats.spec_hits += c.spec_hits
                stats.demand_loads += c.demand
                stats.spec_loads += c.spec_loads
        spec_m.add_bytes(stats.bytes_h2d)
        self._record_generate(stats, S, decode_s)
        obs.req_finished(rid, len(out), "length")
        return np.asarray(out)[None], stats

    # ------------------------------------------------------------------
    def _account(self, info_stack, caches: List[PyLRU], stats: OffloadStats):
        """Feed one decode chunk's routing decisions to the cache
        machinery, position by position, layer by layer.  Expert staging
        (prefetch for l+j fires while 'computing' layer l) runs only for
        C = 1 steps — the same ``T == 1`` gate the packed planes apply,
        so a C = k+1 speculative verify chunk never stages on either
        execution mode (DESIGN.md §11)."""
        spec = self.spec
        ids, hiddens = routing_from_info(self.cfg, info_stack)
        self.usage.update(ids)
        n_pos = int(ids[0].shape[0]) if ids else 1
        for t in range(n_pos):
            for l in range(self.n_moe_layers):
                caches[l].access(ids[l][t])
                tgt = l + spec.lookahead
                if n_pos == 1 and tgt < self.n_moe_layers:
                    pred = speculative.predict_experts(
                        jnp.asarray(self.routers[tgt]),
                        jnp.asarray(hiddens[l][t])[None],
                        spec.num_speculative)
                    caches[tgt].stage(np.asarray(pred[0]))

    # ------------------------------------------------------------------
    def throughput_estimate(self, stats: OffloadStats, hw_name: str) -> float:
        hw = cost_model.HARDWARE[hw_name]
        bits = self.spec.expert_bits if self.size_report else 16
        return cost_model.tokens_per_second(self.cfg, hw, stats.per_token(),
                                            bits, self.spec.attn_bits)


# ----------------------------------------------------------------------
def generate_plain(params, cfg: ModelConfig, prompt: np.ndarray,
                   max_new_tokens: int, *,
                   prefill_chunk: Optional[int] = None,
                   extras=None) -> np.ndarray:
    """Greedy decode without any offload bookkeeping (parity oracle).

    Dispatches through the plain plane of the unified runtime
    (DESIGN.md §8): prompt prefill is the C = S case of the chunked
    block program — every engine that must match this oracle bitwise
    (continuous batching, packed offloading) runs the very same
    programs, and ``prefill_chunk`` splits the prompt without changing
    a single output bit.  Works for every layer kind in the config zoo
    (DESIGN.md §12); enc-dec archs pass
    ``extras={"audio_embeds": ...}``."""
    ex = Executor(params, cfg)
    return ex.generate_greedy(prompt, max_new_tokens,
                              prefill_chunk=prefill_chunk, extras=extras)
