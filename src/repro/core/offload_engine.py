"""The offloaded-inference engine (paper §3.3 system design).

Drives interactive (batch-1) autoregressive generation of an MoE model
under the paper's full algorithm:

* per-layer **LRU cache** of ``k`` experts (``core/lru_cache``),
* **speculative prefetch** of the lookahead layer's likely experts from the
  current layer's hidden state (``core/speculative``),
* **mixed quantization**: experts at 2/3-bit HQQ, shared layers at 4-bit
  (``quant/hqq``),
* byte-accurate transfer accounting (contiguous per-expert buffers — one
  copy per expert, matching the paper's pinned-buffer design).

Key invariant (tested): offloading is *pure scheduling* — with
quantization disabled the generated tokens and logits are bit-identical
to plain decoding; with quantization they are identical to decoding the
dequantized model.  The engine consumes the model's real routing
decisions online, exactly as the CUDA-stream implementation would, and
the cost model turns the counted transfers into wall-clock estimates for
the paper's hardware table.

On a real TPU deployment the ``PyLRU`` bookkeeping below is replaced by
the jit-compatible state machine in ``core/lru_cache`` driving async host
DMA; both implementations are property-tested equal.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OffloadSpec, parse_block
from repro.core import cost_model, speculative
from repro.core.lru_cache import PyLRU
from repro.core.trace import moe_positions, stacked_routers
from repro.models import transformer as T
from repro.quant import hqq


@dataclass
class OffloadStats:
    n_tokens: int = 0
    hits: int = 0
    spec_hits: int = 0
    demand_loads: int = 0
    spec_loads: int = 0
    expert_bytes: float = 0.0  # per expert (quantized)

    @property
    def accesses(self) -> int:
        return self.hits + self.spec_hits + self.demand_loads

    @property
    def hit_ratio(self) -> float:
        return (self.hits + self.spec_hits) / max(1, self.accesses)

    def per_token(self) -> cost_model.TokenStats:
        n = max(1, self.n_tokens)
        return cost_model.TokenStats(
            demand_loads=self.demand_loads / n,
            spec_loads=self.spec_loads / n,
            hits=self.hits / n,
            spec_hits=self.spec_hits / n,
        )

    @property
    def bytes_h2d(self) -> float:
        return (self.demand_loads + self.spec_loads) * self.expert_bytes


# ----------------------------------------------------------------------
def routing_from_info(cfg: ModelConfig, info_stack, want_hiddens=True):
    """Unpack one ``decode_step(..., collect_info=True)`` result into
    layer-major per-MoE-layer routing: returns (ids, hiddens) lists of
    length n_moe_layers with arrays (B, top_k) int32 and (B, D)
    (``hiddens`` is empty with ``want_hiddens=False``, skipping that
    device->host transfer for callers that only count expert ids).

    This is the single decode-side source of routing truth, shared by the
    offload accounting below and by the serving scheduler's expert-overlap
    policy (``serving/scheduler.ExpertOverlapPolicy``).
    """
    ids, hiddens = [], []
    for per in range(cfg.n_periods):
        for i in range(cfg.pattern_period):
            info = info_stack[i]
            if "route" not in info:
                continue
            ids.append(np.asarray(info["route"]["ids"][per]))
            if want_hiddens:
                hiddens.append(np.asarray(info["hidden_pre_moe"][per]))
    return ids, hiddens


class ExpertUsageTracker:
    """Decayed per-MoE-layer histogram of expert activations.

    Tracks which experts the in-flight batch has recently routed to —
    i.e. what the offload engine's per-layer caches are hot with.  The
    continuous-batching admission policy scores waiting requests by
    overlap with this histogram (MoBiLE-style expert-aware grouping:
    admitting requests that reuse the already-loaded experts amortises
    expert-load cost on memory-constrained hardware).
    """

    def __init__(self, n_layers: int, n_experts: int, decay: float = 0.9):
        self.n_layers = n_layers
        self.n_experts = n_experts
        self.decay = decay
        self.counts = np.zeros((n_layers, n_experts), np.float64)

    @classmethod
    def for_config(cls, cfg: ModelConfig, decay: float = 0.9
                   ) -> "ExpertUsageTracker":
        n = len(moe_positions(cfg)) * cfg.n_periods
        return cls(n, cfg.moe.num_experts, decay)

    def update(self, ids_per_layer, rows=None) -> None:
        """ids_per_layer: list of (B, K) int32 (from ``routing_from_info``);
        ``rows`` restricts accounting to the active batch rows."""
        self.counts *= self.decay
        for l, ids in enumerate(ids_per_layer):
            sel = ids if rows is None else ids[np.asarray(rows, np.int64)]
            np.add.at(self.counts[l], np.asarray(sel).ravel(), 1.0)

    def normalized(self) -> np.ndarray:
        """(L, E) rows summing to 1 (uniform when a layer has no counts)."""
        tot = self.counts.sum(-1, keepdims=True)
        uniform = np.full_like(self.counts, 1.0 / self.n_experts)
        return np.where(tot > 0, self.counts / np.maximum(tot, 1e-9), uniform)

    def overlap(self, pred_ids_per_layer) -> float:
        """Score a candidate's predicted expert set against the in-flight
        histogram: expected fraction of its expert hits already hot."""
        hist = self.normalized()
        score = 0.0
        for l, ids in enumerate(pred_ids_per_layer[: self.n_layers]):
            score += float(hist[l, np.asarray(ids, np.int64).ravel()].sum())
        return score / max(1, len(pred_ids_per_layer))


# ----------------------------------------------------------------------
def quantize_for_offload(params, cfg: ModelConfig, spec: OffloadSpec):
    """Mixed quantization of the model (paper §3.3): experts at
    ``spec.expert_bits``, attention/shared weights at ``spec.attn_bits``;
    embeddings / router / norms stay 16-bit.

    Returns (exec_params, size_report).  ``exec_params`` carries the
    dequantized weights (what the accelerator computes with after the HQQ
    dequant kernel); ``size_report`` carries the true packed sizes.
    """
    qsizes = {"experts": 0, "attn": 0, "fp16": 0}
    dtype = jnp.dtype(cfg.dtype)

    def quant_leaf(path, leaf, bits):
        if leaf.ndim < 2:
            qsizes["fp16"] += leaf.size * 2
            return leaf
        name = path[-1]
        if "experts" in path:
            mat = leaf.reshape(-1, *leaf.shape[-2:])  # (E, K, N)
        elif name in ("wq", "wk", "wv"):
            mat = leaf.reshape(leaf.shape[0], -1)  # (D, H*hd)
        elif name == "wo":
            mat = leaf.reshape(-1, leaf.shape[-1])  # (H*hd, D)
        else:
            mat = leaf
        gs = hqq.PAPER_SCHEMES[bits]["group_size"]
        if mat.shape[-2] % gs:
            qsizes["fp16"] += leaf.size * 2
            return leaf
        qt = hqq.quantize(mat, bits)
        key = "experts" if "experts" in path else "attn"
        qsizes[key] += hqq.nbytes(qt)
        return hqq.dequantize(qt, dtype).reshape(leaf.shape)

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        name = path[-1]
        if "experts" in path:
            return quant_leaf(path, tree, spec.expert_bits)
        if name in ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                    "w_in", "w_out"):
            return quant_leaf(path, tree, spec.attn_bits)
        qsizes["fp16"] += tree.size * 2
        return tree

    exec_params = walk(params, ())
    qsizes["total"] = qsizes["experts"] + qsizes["attn"] + qsizes["fp16"]
    return exec_params, qsizes


# ----------------------------------------------------------------------
class OffloadEngine:
    """Stateful wrapper around one model + offload configuration."""

    def __init__(self, params, cfg: ModelConfig,
                 spec: Optional[OffloadSpec] = None, quantized: bool = False):
        assert cfg.moe is not None, "offloading targets MoE architectures"
        self.cfg = cfg
        self.spec = spec or cfg.offload or OffloadSpec()
        self.size_report = None
        if quantized:
            params, self.size_report = quantize_for_offload(params, cfg, self.spec)
        self.params = params
        self.routers = stacked_routers(params, cfg)  # (L_moe, D, E)
        self.n_moe_layers = self.routers.shape[0]
        eff_bits = cost_model.EFFECTIVE_BITS[self.spec.expert_bits if quantized else 16]
        self.expert_bytes = cost_model.expert_param_count(cfg) * eff_bits / 8.0
        self._step = jax.jit(lambda p, st, tk: T.decode_step(
            p, cfg, st, tk, moe_mode="gather", collect_info=True))
        self._prefill = T.make_prefill(cfg)
        # live routing histogram, readable by serving-admission policies
        self.usage = ExpertUsageTracker(self.n_moe_layers,
                                        cfg.moe.num_experts)

    # ------------------------------------------------------------------
    def generate(self, prompt: np.ndarray, max_new_tokens: int,
                 greedy: bool = True, rng=None
                 ) -> Tuple[np.ndarray, OffloadStats]:
        """prompt: (1, S) int32.  Returns (generated (1, n), stats)."""
        cfg, spec = self.cfg, self.spec
        caches = [PyLRU(spec.cache_size, spec.num_speculative)
                  for _ in range(self.n_moe_layers)]
        stats = OffloadStats(expert_bytes=self.expert_bytes)

        max_len = prompt.shape[1] + max_new_tokens
        pre_logits, state = self._prefill(
            self.params, {"tokens": jnp.asarray(prompt)}, max_len)
        # prefill loads each layer once (paper: the encode phase "works
        # relatively well with existing algorithms"); generation-phase
        # accounting starts below.  First token comes from prefill logits.
        first = jnp.argmax(pre_logits[:, -1], axis=-1)
        out = [int(first[0])]
        tok = first[:, None].astype(jnp.int32)
        logits = None
        for step_i in range(max_new_tokens - 1):
            logits, state, (info_stack, _) = self._step(self.params, state, tok)
            self._account(info_stack, caches, stats)
            stats.n_tokens += 1
            if greedy:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            else:
                rng, sub = jax.random.split(rng)
                nxt = jax.random.categorical(sub, logits[:, -1])
            tok = nxt[:, None].astype(jnp.int32)
            out.append(int(nxt[0]))
        for c in caches:
            stats.hits += c.hits
            stats.spec_hits += c.spec_hits
            stats.demand_loads += c.demand
            stats.spec_loads += c.spec_loads
        return np.asarray(out)[None], stats

    # ------------------------------------------------------------------
    def _account(self, info_stack, caches: List[PyLRU], stats: OffloadStats):
        """Feed one decode step's routing decisions to the cache machinery,
        layer by layer, staging lookahead predictions as the paper does
        (prefetch for l+j fires while 'computing' layer l)."""
        spec = self.spec
        ids, hiddens = routing_from_info(self.cfg, info_stack)
        self.usage.update(ids)
        for l in range(self.n_moe_layers):
            caches[l].access(ids[l][0])
            tgt = l + spec.lookahead
            if tgt < self.n_moe_layers:
                pred = speculative.predict_experts(
                    jnp.asarray(self.routers[tgt]),
                    jnp.asarray(hiddens[l][0])[None],
                    spec.num_speculative)
                caches[tgt].stage(np.asarray(pred[0]))

    # ------------------------------------------------------------------
    def throughput_estimate(self, stats: OffloadStats, hw_name: str) -> float:
        hw = cost_model.HARDWARE[hw_name]
        bits = self.spec.expert_bits if self.size_report else 16
        return cost_model.tokens_per_second(self.cfg, hw, stats.per_token(),
                                            bits, self.spec.attn_bits)


# ----------------------------------------------------------------------
def generate_plain(params, cfg: ModelConfig, prompt: np.ndarray,
                   max_new_tokens: int) -> np.ndarray:
    """Greedy decode without any offload bookkeeping (parity oracle)."""
    step = jax.jit(lambda p, st, tk: T.decode_step(p, cfg, st, tk,
                                                   moe_mode="gather"))
    max_len = prompt.shape[1] + max_new_tokens
    pre_logits, state = jax.jit(lambda p, b: T.prefill(p, cfg, b, max_len))(
        params, {"tokens": jnp.asarray(prompt)})
    first = jnp.argmax(pre_logits[:, -1], axis=-1)
    out = [int(first[0])]
    tok = first[:, None].astype(jnp.int32)
    for _ in range(max_new_tokens - 1):
        logits, state = step(params, state, tok)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        tok = nxt[:, None].astype(jnp.int32)
        out.append(int(nxt[0]))
    return np.asarray(out)[None]
