"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth
swept against in tests/test_kernels_*.py)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.quant import hqq


def dequant_matmul_ref(x, packed, scale, zero, *, bits, group_size,
                       out_dtype=jnp.float32):
    """x: (M, K); packed: (G, g*bits//8, N) uint8; scale/zero: (G, 1, N).

    Returns x @ dequant(W) in f32 accumulate.  W layout: grouped along K
    (G = K // group_size), exactly `quant/hqq.quantize`'s layout for a 2-D
    weight.
    """
    q = hqq.unpack_codes(packed, bits, group_size).astype(jnp.float32)
    w = (q - zero.astype(jnp.float32)) * scale.astype(jnp.float32)
    K = packed.shape[0] * group_size
    w = w.reshape(K, packed.shape[-1])
    return jnp.dot(x.astype(jnp.float32), w).astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None,
                        q_offset=0):
    """q: (BH, Sq, d); k, v: (BKV, Skv, d) with BH = BKV * G (GQA).

    Query row i has absolute position ``q_offset + i``; key column j has
    position ``j``.  f32 softmax, matches the kernel bit-for-bit up to
    accumulation order.
    """
    BH, Sq, d = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(d)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    valid = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        valid &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        valid &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(valid[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vv).astype(q.dtype)
