"""Pallas TPU kernel: fused group-dequantize (HQQ packed 2/4/8-bit) x matmul.

This is the paper's perf-critical compute adapted to TPU: on GPU the HQQ
reference dequantizes expert weights with CUDA kernels before cuBLAS; on
TPU we instead keep the weight **packed in VMEM** and unpack/dequantize
blockwise right before feeding the MXU, so HBM traffic is the *quantized*
bytes (the whole point of compression-for-offloading, section 3.3: "model
compression has a natural synergy with offloading").

Tiling: grid (M/bm, N/bn, K/bk), K innermost for accumulation.  ``bk`` must
be a multiple of ``group_size`` so each K-block covers whole quant groups;
block shapes default to MXU-aligned (128) multiples.  The f32 accumulator
lives in the output block (revisited across the K grid dimension — Pallas
keeps it in VMEM).

3-bit codes don't unpack with static strides (8 codes span 3 bytes), so
3-bit uses the jnp reference path (``ops.dequant_matmul`` dispatches);
noted in DESIGN.md §6.

This kernel is the compute path of packed-offloaded MoE execution:
``models/moe.moe_apply_packed`` feeds each served pool slot's packed
weights through ``ops.dequant_matmul`` (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.hqq import unpack_codes


def _kernel(x_ref, p_ref, s_ref, z_ref, o_ref, *, bits, group_size):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (bm, bk)
    packed = p_ref[...]  # (bk//g, g*bits//8, bn)
    scale = s_ref[...].astype(jnp.float32)  # (bk//g, 1, bn)
    zero = z_ref[...].astype(jnp.float32)
    q = unpack_codes(packed, bits, group_size).astype(jnp.float32)
    w = (q - zero) * scale  # (bk//g, g, bn)
    w = w.reshape(x.shape[1], -1)  # (bk, bn)
    o_ref[...] += jnp.dot(x.astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "bn", "bk", "interpret"))
def dequant_matmul_pallas(x, packed, scale, zero, *, bits, group_size,
                          bm=128, bn=128, bk=128, interpret=True):
    """x: (M, K) @ packed W (G, g*bits//8, N) -> (M, N) f32."""
    M, K = x.shape
    G, pg, N = packed.shape
    assert G * group_size == K
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    assert bk % group_size == 0 and K % bk == 0 and M % bm == 0 and N % bn == 0
    gb = bk // group_size  # groups per K block
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, group_size=group_size),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((gb, pg, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((gb, 1, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((gb, 1, bn), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=interpret,
    )(x, packed, scale, zero)


# ----------------------------------------------------------------------
# Batched / slot-gather variants (DESIGN.md §7): the compute side of the
# vectorized packed-expert data plane.  One kernel launch covers every
# (token, k) pair of an MoE layer's batch instead of T*K separate calls.
def _batched_kernel(x_ref, p_ref, s_ref, z_ref, o_ref, *, bits, group_size):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[0]  # (bm, bk)
    packed = p_ref[0]  # (gb, g*bits//8, bn)
    scale = s_ref[0].astype(jnp.float32)
    zero = z_ref[0].astype(jnp.float32)
    q = unpack_codes(packed, bits, group_size).astype(jnp.float32)
    w = ((q - zero) * scale).reshape(x.shape[1], -1)  # (bk, bn)
    o_ref[0] += jnp.dot(x.astype(jnp.float32), w,
                        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "bn", "bk", "interpret"))
def dequant_matmul_batched_pallas(x, packed, scale, zero, *, bits,
                                  group_size, bm=128, bn=128, bk=128,
                                  interpret=True):
    """x (B, M, K) @ per-row packed W (B, G, g*bits//8, N) -> (B, M, N)."""
    B, M, K = x.shape
    _, G, pg, N = packed.shape
    assert G * group_size == K
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % group_size == 0 and K % bk == 0 and M % bm == 0 \
        and N % bn == 0
    gb = bk // group_size
    grid = (B, M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_batched_kernel, bits=bits, group_size=group_size),
        out_shape=jax.ShapeDtypeStruct((B, M, N), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, j, k: (b, i, k)),
            pl.BlockSpec((1, gb, pg, bn), lambda b, i, j, k: (b, k, 0, j)),
            pl.BlockSpec((1, gb, 1, bn), lambda b, i, j, k: (b, k, 0, j)),
            pl.BlockSpec((1, gb, 1, bn), lambda b, i, j, k: (b, k, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, k: (b, i, j)),
        interpret=interpret,
    )(x, packed, scale, zero)


def _slots_kernel(slots_ref, x_ref, p_ref, s_ref, z_ref, o_ref, *, bits,
                  group_size):
    del slots_ref  # consumed by the index maps (scalar prefetch)
    _batched_kernel(x_ref, p_ref, s_ref, z_ref, o_ref, bits=bits,
                    group_size=group_size)


@functools.partial(jax.jit, static_argnames=("bits", "group_size", "bm",
                                             "bn", "bk", "interpret"))
def dequant_matmul_slots_pallas(x, packed, scale, zero, slots, *, bits,
                                group_size, bm=128, bn=128, bk=128,
                                interpret=True):
    """x (B, M, K) @ dequant(W[slots[b]]) -> (B, M, N) where the packed
    weight tier W (S, G, g*bits//8, N) stays whole: ``slots`` (B,) int32
    rides in as a scalar-prefetch argument and the *index maps* pick each
    program's source block, so the gather happens inside the kernel's DMA
    schedule — no gathered copy of the packed tier is ever materialized
    (the slot-serving read of the vectorized expert pool, DESIGN.md §7).
    """
    B, M, K = x.shape
    S, G, pg, N = packed.shape
    assert G * group_size == K
    assert slots.shape == (B,)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert bk % group_size == 0 and K % bk == 0 and M % bm == 0 \
        and N % bn == 0
    gb = bk // group_size
    grid = (B, M // bm, N // bn, K // bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, j, k, sl: (b, i, k)),
            pl.BlockSpec((1, gb, pg, bn),
                         lambda b, i, j, k, sl: (sl[b], k, 0, j)),
            pl.BlockSpec((1, gb, 1, bn),
                         lambda b, i, j, k, sl: (sl[b], k, 0, j)),
            pl.BlockSpec((1, gb, 1, bn),
                         lambda b, i, j, k, sl: (sl[b], k, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda b, i, j, k, sl: (b, i, j)),
    )
    return pl.pallas_call(
        functools.partial(_slots_kernel, bits=bits, group_size=group_size),
        out_shape=jax.ShapeDtypeStruct((B, M, N), jnp.float32),
        grid_spec=grid_spec,
        interpret=interpret,
    )(slots.astype(jnp.int32), x, packed, scale, zero)
