"""Pallas TPU kernel: blockwise (flash) causal attention with GQA and
optional sliding window.

Grid: (batch*q_heads, Sq/bq, Skv/bk) with the KV dimension innermost; the
online-softmax running max / normalizer / accumulator live in VMEM scratch
and the normalized output is written on the last KV step.  GQA is handled
by the KV index map (``bh // group`` selects the shared KV head) — no KV
replication in memory.

With a causal sliding window the KV grid dimension shrinks to the blocks
that can intersect ``(qpos − window, qpos]`` for the step's query block:
the KV index map offsets each step by the block's window floor
(``lo(qi) + j``, clamped), so mask-only blocks are **dropped from the
grid** instead of visited-and-masked.  This is bitwise-neutral: a fully
masked *leading* block leaves ``m = −inf`` junk that the first valid
block's ``alpha = exp(−inf) = 0`` rescale wipes exactly, and a fully
masked *trailing* block contributes ``p = exp(−inf) = 0`` exactly — so
skipped-vs-visited produces identical bits (tests/test_kernels.py).
``skip_window_blocks=False`` keeps the dense grid for that comparison.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _window_lo_block(qi, *, q_offset, window, bq, bk):
    """First KV block that can intersect the query block's window span
    ``(q_offset + qi*bq − window, q_offset + (qi+1)*bq − 1]``."""
    return jnp.maximum(0, (q_offset + qi * bq - window + 1) // bk)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, bq, bk, q_offset, n_k_steps, skip):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    if skip:
        # windowed grid: step j visits KV block lo(qi) + j; kpos below
        # uses the UNCLAMPED index, so steps the index map clamped to the
        # last block land beyond the causal frontier and mask to exactly
        # zero weight (module docstring)
        ki = _window_lo_block(qi, q_offset=q_offset, window=window,
                              bq=bq, bk=bk) + ki

    @pl.when(pl.program_id(2) == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = jnp.ones((bq, bk), bool)
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= (qpos - kpos) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(pl.program_id(2) == n_k_steps - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_offset", "interpret",
                                             "skip_window_blocks"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, bq=128,
                           bk=128, q_offset=0, interpret=True,
                           skip_window_blocks=True):
    """q: (BH, Sq, d); k, v: (BKV, Skv, d), BH = BKV * G. -> (BH, Sq, d).

    With ``causal`` + ``window`` the KV grid covers only the blocks a
    query block's window can reach (module docstring);
    ``skip_window_blocks=False`` restores the dense grid (identical
    bits, more steps — kept for the parity test and as the fallback for
    non-causal windows)."""
    BH, Sq, d = q.shape
    BKV, Skv, _ = k.shape
    assert BH % BKV == 0
    G = BH // BKV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_k = Skv // bk
    scale = 1.0 / math.sqrt(d)

    from jax.experimental.pallas import tpu as pltpu

    # window + bq − 1 positions can span at most ceil(.../bk) + 1 blocks
    n_vis = n_k
    if causal and window is not None and skip_window_blocks:
        n_vis = min(n_k, -(-(window + bq - 1) // bk) + 1)
    skip = n_vis < n_k

    def kv_index(b, i, j, g=G):
        if not skip:
            return (b // g, j, 0)
        lo = _window_lo_block(i, q_offset=q_offset, window=window,
                              bq=bq, bk=bk)
        return (b // g, jnp.minimum(lo + j, n_k - 1), 0)

    grid = (BH, Sq // bq, n_vis)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, q_offset=q_offset, n_k_steps=n_vis,
                          skip=skip),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
