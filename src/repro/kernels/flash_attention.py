"""Pallas TPU kernel: blockwise (flash) causal attention with GQA and
optional sliding window.

Grid: (batch*q_heads, Sq/bq, Skv/bk) with the KV dimension innermost; the
online-softmax running max / normalizer / accumulator live in VMEM scratch
and the normalized output is written on the last KV step.  GQA is handled
by the KV index map (``bh // group`` selects the shared KV head) — no KV
replication in memory.  Sliding-window blocks outside the window are still
visited but fully masked (a production kernel would skip them via the
grid; noted as a perf iteration in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, bq, bk, q_offset, n_k_steps):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0].astype(jnp.float32)  # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = jnp.ones((bq, bk), bool)
    if causal:
        valid &= kpos <= qpos
    if window is not None:
        valid &= (qpos - kpos) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_k_steps - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "q_offset", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, bq=128,
                           bk=128, q_offset=0, interpret=True):
    """q: (BH, Sq, d); k, v: (BKV, Skv, d), BH = BKV * G. -> (BH, Sq, d)."""
    BH, Sq, d = q.shape
    BKV, Skv, _ = k.shape
    assert BH % BKV == 0
    G = BH // BKV
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    n_k = Skv // bk
    scale = 1.0 / math.sqrt(d)

    from jax.experimental.pallas import tpu as pltpu

    grid = (BH, Sq // bq, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, q_offset=q_offset, n_k_steps=n_k),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=G: (b // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j, g=G: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
