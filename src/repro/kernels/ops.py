"""Jit'd public wrappers around the Pallas kernels with automatic fallback
to the pure-jnp oracle for shapes/bitwidths the kernels don't tile
(3-bit codes, non-divisible shapes, scalar decode queries).

``dequant_matmul`` is the dispatch point for packed-offloaded MoE
execution (``models/moe.moe_apply_packed``, DESIGN.md §6): batch-1 decode
and 3-bit codes take the reference path on this host; MXU-aligned 2/4/8-
bit shapes take the fused Pallas kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.quant.hqq import QTensor, _meta_dequantize

KERNEL_BITS = (2, 4, 8)


def dequant_matmul(x, qt: QTensor, *, interpret=True, use_kernel=True):
    """x (M, K) @ dequant(qt) where qt quantizes a (K, N) weight."""
    assert len(qt.shape) == 2, "2-D weights (reshape heads first)"
    scale, zero = _meta_dequantize(qt)
    M, K = x.shape
    N = qt.shape[-1]
    ok = (use_kernel and qt.bits in KERNEL_BITS
          and M % 8 == 0 and N % 128 == 0
          and K % max(128, qt.group_size) == 0)
    if ok:
        bm = 128 if M % 128 == 0 else 8
        return dequant_matmul_pallas(
            x, qt.packed, scale, zero, bits=qt.bits,
            group_size=qt.group_size, bm=bm, interpret=interpret)
    return ref.dequant_matmul_ref(x, qt.packed, scale, zero, bits=qt.bits,
                                  group_size=qt.group_size)


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    interpret=True, use_kernel=True):
    BH, Sq, d = q.shape
    ok = (use_kernel and Sq % 8 == 0 and k.shape[1] % 128 == 0
          and d % 8 == 0)
    if ok:
        bq = 128 if Sq % 128 == 0 else 8
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, q_offset=q_offset,
                                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
