"""Jit'd public wrappers around the Pallas kernels with automatic fallback
to the pure-jnp oracle for shapes/bitwidths the kernels don't tile
(3-bit codes, non-divisible shapes, scalar decode queries).

``dequant_matmul`` is the dispatch point for packed-offloaded MoE
execution (``models/moe.moe_apply_packed``, DESIGN.md §6): batch-1 decode
and 3-bit codes take the reference path on this host; MXU-aligned 2/4/8-
bit shapes take the fused Pallas kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import (dequant_matmul_batched_pallas,
                                          dequant_matmul_pallas,
                                          dequant_matmul_slots_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.quant.hqq import QTensor, _meta_dequantize, unpack_codes

KERNEL_BITS = (2, 4, 8)


def dequant_matmul(x, qt: QTensor, *, interpret=True, use_kernel=True):
    """x (M, K) @ dequant(qt) where qt quantizes a (K, N) weight."""
    assert len(qt.shape) == 2, "2-D weights (reshape heads first)"
    scale, zero = _meta_dequantize(qt)
    M, K = x.shape
    N = qt.shape[-1]
    if _batched_ok(use_kernel, qt.bits, M, N, K, qt.group_size):
        bm = 128 if M % 128 == 0 else 8
        return dequant_matmul_pallas(
            x, qt.packed, scale, zero, bits=qt.bits,
            group_size=qt.group_size, bm=bm, interpret=interpret)
    return ref.dequant_matmul_ref(x, qt.packed, scale, zero, bits=qt.bits,
                                  group_size=qt.group_size)


def _batched_ok(use_kernel, bits, M, N, K, group_size):
    """Shared kernel-eligibility gate (single-slice and batched paths)."""
    return (use_kernel and bits in KERNEL_BITS and M % 8 == 0
            and N % 128 == 0 and K % max(128, group_size) == 0)


def _dequant_rows(qt_stacked: QTensor, scale, zero):
    """Dequantize a (B, G, pg, N)-packed row stack to (B, K, N) f32."""
    B, G, _, N = qt_stacked.packed.shape
    q = unpack_codes(qt_stacked.packed, qt_stacked.bits,
                     qt_stacked.group_size).astype(jnp.float32)
    w = (q - zero.astype(jnp.float32)) * scale.astype(jnp.float32)
    return w.reshape(B, G * qt_stacked.group_size, N)


def dequant_matmul_batched(x, qt: QTensor, *, interpret=True,
                           use_kernel=True):
    """x (B, M, K) @ dequant(qt[b]) per row, qt stacked (B, K, N) packed.

    ONE dispatch covers the whole batch of per-(token, k) expert matmuls
    of the vectorized packed MoE path (DESIGN.md §7) — the replacement
    for B separate :func:`dequant_matmul` calls.  Pallas batched kernel
    when shapes/bits tile; jnp batched reference otherwise (bitwise equal
    to the per-slice path on this backend — tested)."""
    assert len(qt.shape) == 3, "expect (B,)-stacked 2-D weights"
    scale, zero = _meta_dequantize(qt)
    B, M, K = x.shape
    N = qt.shape[-1]
    if _batched_ok(use_kernel, qt.bits, M, N, K, qt.group_size):
        bm = 128 if M % 128 == 0 else 8
        return dequant_matmul_batched_pallas(
            x, qt.packed, scale, zero, bits=qt.bits,
            group_size=qt.group_size, bm=bm, interpret=interpret)
    w = _dequant_rows(qt, scale, zero)
    return jnp.einsum("bmk,bkn->bmn", x.astype(jnp.float32), w)


def dequant_matmul_slots(x, qt: QTensor, slots, *, interpret=True,
                         use_kernel=True):
    """x (B, M, K) @ dequant(qt[slots[b]]): serve a batch of matmuls by
    *slot index* into a stacked packed tier (S, K, N) without gathering
    it — the Pallas kernel reads each program's source block through a
    scalar-prefetched ``slots`` (B,) array (DESIGN.md §7).  Off-kernel
    shapes gather the (small) packed leaves and run the batched
    reference."""
    assert len(qt.shape) == 3, "expect (S,)-stacked 2-D weights"
    scale, zero = _meta_dequantize(qt)
    B, M, K = x.shape
    N = qt.shape[-1]
    if _batched_ok(use_kernel, qt.bits, M, N, K, qt.group_size):
        bm = 128 if M % 128 == 0 else 8
        return dequant_matmul_slots_pallas(
            x, qt.packed, scale, zero, slots, bits=qt.bits,
            group_size=qt.group_size, bm=bm, interpret=interpret)
    gathered = QTensor(qt.packed[slots], scale[slots], zero[slots], None,
                       qt.bits, qt.group_size, (B,) + tuple(qt.shape[1:]))
    w = _dequant_rows(gathered, gathered.scale, gathered.zero)
    return jnp.einsum("bmk,bkn->bmn", x.astype(jnp.float32), w)


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    interpret=True, use_kernel=True):
    BH, Sq, d = q.shape
    ok = (use_kernel and Sq % 8 == 0 and k.shape[1] % 128 == 0
          and d % 8 == 0)
    if ok:
        bq = 128 if Sq % 128 == 0 else 8
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      bq=bq, q_offset=q_offset,
                                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset)
