"""Ragged, page-aware decode attention over block-paged KV (DESIGN.md §9).

The paged KV plane stores every layer's KV in a shared pool of fixed-size
pages — ``kp/vp: (P, page_size, Hkv, hd)`` with per-page absolute
positions ``ppos: (P, page_size)`` (−1 = never written) — and each batch
row owns an ordered *page table* row ``pages: (B, max_pages)`` (−1 =
unallocated).  Logical position ``p`` of a row lives at page
``pages[b, p // page_size]``, offset ``p % page_size``.  This module is
the attention read side of that layout, in two tiers:

* :func:`ragged_attention_reference` — the CPU/tier-1 fallback: gathers
  the rows' pages into a dense ``(B, max_pages*page_size)`` KV view and
  runs the model's own ``attention_core`` on it.  Because the gathered
  view reproduces the ring layout index-for-index (position ``p`` at
  index ``p``; unallocated slots carry ``kpos = −1`` exactly like empty
  ring slots), its output is **bitwise identical** to the dense path at
  matched width — the engines' paged mode exercises the same semantics
  the pre-paged tests froze (tests/test_paged_kv.py).  Cost scales with
  the *table width it is handed*: callers slice the table to the live
  page horizon (``serving.kv_manager.PagedKVManager.live_width``) so
  decode attention pays for live context, not slot capacity.

* :func:`ragged_attention_pallas` — the accelerator kernel: a flat
  *work list* of (row, page) pairs rides in as scalar-prefetch arrays
  (the ``dequant_matmul_slots`` pattern) and **is** the grid — pages
  beyond a row's live length or wholly outside the sliding window are
  never visited, so per-step attention work is O(total live pages), per
  row, not O(batch × table width).  Online softmax runs in VMEM scratch
  with accumulators reset/flushed at each row's first/last work item.

:func:`build_page_worklist` derives the kernel's work list host-side
from the page tables + per-row query spans; its length is the kernel's
grid size and the quantity ``benchmarks/attention_bench.py`` shows
scaling with live tokens.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Dense gather + reference (the tier-1 fallback path)
def ragged_gather(kp, vp, ppos, pages, layer=None):
    """Gather each row's pages into a dense KV view.

    kp/vp: (P, ps, Hkv, hd); ppos: (P, ps); pages: (B, T) int32 page ids
    (−1 = unallocated).  Returns (k, v, kpos) with k/v (B, T*ps, Hkv, hd)
    and kpos (B, T*ps); entries under unallocated table slots carry
    kpos = −1 (their k/v values are whatever page 0 holds — masked out of
    every attention exactly like empty ring slots).

    ``layer`` reads layer-stacked pools — kp/vp (L, P, ps, Hkv, hd) —
    through ONE fused gather, so a scanned decode step never slices a
    whole layer's pool out of its carry (that copy is what made paged
    cost scale with pool size instead of live pages; DESIGN.md §9).
    """
    B, T = pages.shape
    pidc = jnp.maximum(pages, 0)                       # (B, T)
    if layer is None:
        k = kp[pidc]                                   # (B, T, ps, Hkv, hd)
        v = vp[pidc]
        kpos = jnp.where(pages[:, :, None] >= 0, ppos[pidc], -1)
    else:
        k = kp[layer, pidc]
        v = vp[layer, pidc]
        kpos = jnp.where(pages[:, :, None] >= 0, ppos[layer, pidc], -1)
    ps = k.shape[2]
    return (k.reshape(B, T * ps, *k.shape[3:]),
            v.reshape(B, T * ps, *v.shape[3:]),
            kpos.reshape(B, T * ps))


def ragged_attention_reference(q, kp, vp, ppos, pages, qpos, *,
                               window: Optional[int] = None,
                               q_chunk: Optional[int] = None, layer=None):
    """Blockwise (page-gather) reference: bitwise the model's
    ``attention_core`` over the gathered dense view (module docstring).

    q: (B, C, H, hd); qpos: (B, C) int32 absolute query positions.
    """
    from repro.models.layers import attention_core  # lazy: layers imports us
    k, v, kpos = ragged_gather(kp, vp, ppos, pages, layer=layer)
    return attention_core(q, k, v, qpos, kpos, causal=True, window=window,
                          q_chunk=q_chunk or q.shape[1])


# ----------------------------------------------------------------------
# Host-side work-list construction (the kernel's grid)
def build_page_worklist(pages, n_live, q_lo, q_hi, page_size: int, *,
                        window: Optional[int] = None,
                        pad_to: Optional[int] = None
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Flatten (row, page) work for one ragged decode/chunk step.

    pages: (B, T) int page tables; n_live[b]: live token count of row b
    (0 = row idle — emits no work); queries of row b sit at absolute
    positions ``[q_lo[b], q_hi[b]]``.  A page is listed only if it holds
    a position ``<= q_hi`` (causal / live-length skip) and, with a
    sliding ``window``, a position ``> q_lo − window`` (window skip) —
    the two grid-level skips the dense path pays masking for.

    Returns (wrow, wpage, wflags) int32 arrays of equal length (padded
    to ``pad_to`` with inert entries); wflags[:, 0/1/2] = first/last/
    valid.  The un-padded length is the kernel's real work — the
    quantity that scales with live tokens.
    """
    pages = np.asarray(pages)
    n_live = np.asarray(n_live)
    q_lo = np.broadcast_to(np.asarray(q_lo), (pages.shape[0],))
    q_hi = np.broadcast_to(np.asarray(q_hi), (pages.shape[0],))
    B, T = pages.shape
    wrow, wpage, wflags = [], [], []
    for b in range(B):
        n_pages = -(-int(n_live[b]) // page_size)  # ceil
        keep = []
        for o in range(min(n_pages, T)):
            pid = int(pages[b, o])
            if pid < 0:
                continue
            page_lo, page_hi = o * page_size, (o + 1) * page_size - 1
            if page_lo > q_hi[b]:
                continue  # wholly beyond the causal frontier
            if window is not None and page_hi <= q_lo[b] - window:
                continue  # wholly outside the sliding window
            keep.append(pid)
        for j, pid in enumerate(keep):
            wrow.append(b)
            wpage.append(pid)
            wflags.append((int(j == 0), int(j == len(keep) - 1), 1))
    n = len(wrow)
    pad_to = max(pad_to or n, n, 1)
    # inert padding repeats the LAST real (row, page) pair: a pad step
    # revisits a block whose VMEM already holds that row's finalized
    # output, so the compiled kernel's block writeback is a no-op.
    # Padding with (0, 0) would instead revisit row 0's output block
    # without writing it and flush stale scratch over it on TPU.
    pr, pp = (wrow[-1], wpage[-1]) if n else (0, 0)
    while len(wrow) < pad_to:
        wrow.append(pr)
        wpage.append(pp)
        wflags.append((0, 0, 0))
    return (np.asarray(wrow, np.int32), np.asarray(wpage, np.int32),
            np.asarray(wflags, np.int32).reshape(pad_to, 3))


# ----------------------------------------------------------------------
# Pallas kernel: the work list IS the grid
def _ragged_kernel(wrow_ref, wpage_ref, wflags_ref, qpos_ref,
                   q_ref, kp_ref, vp_ref, ppos_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, window, n_groups):
    i = pl.program_id(0)
    first = wflags_ref[i, 0]
    last = wflags_ref[i, 1]
    valid = wflags_ref[i, 2]

    @pl.when(first == 1)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(valid == 1)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale      # (C, H, hd)
        k = kp_ref[0].astype(jnp.float32)             # (ps, Hkv, hd)
        v = vp_ref[0].astype(jnp.float32)
        kpos = ppos_ref[0]                            # (ps,)
        C, H, hd = q.shape
        Hkv = k.shape[1]
        qg = q.reshape(C, Hkv, n_groups, hd)
        s = jnp.einsum("chgd,thd->chgt", qg, k,
                       preferred_element_type=jnp.float32)  # (C,Hkv,G,ps)
        qp = qpos_ref[wrow_ref[i]]                    # (C,) this row's qpos
        ok = (kpos[None, :] >= 0) & (kpos[None, :] <= qp[:, None])
        if window is not None:
            ok &= (qp[:, None] - kpos[None, :]) < window
        s = jnp.where(ok[:, None, None, :], s, NEG_INF).reshape(C, H, -1)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])             # (C, H, ps)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        pv = jnp.einsum("chgt,thd->chgd",
                        p.reshape(C, Hkv, n_groups, -1), v,
                        preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + \
            pv.reshape(C, H, hd)
        m_scr[...] = m_new

    @pl.when(last == 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def ragged_attention_pallas(q, kp, vp, ppos, qpos, wrow, wpage, wflags, *,
                            window: Optional[int] = None, interpret=True):
    """q: (B, C, H, hd) against paged KV via a (row, page) work list.

    The work list arrays ride in as scalar-prefetch arguments; the grid
    has ONE step per listed page — skipped pages (beyond live length /
    outside the window, see :func:`build_page_worklist`) cost nothing.
    Rows that contribute no work items keep undefined output (callers
    mask them — they are the engines' idle slots).
    """
    from jax.experimental.pallas import tpu as pltpu

    B, C, H, hd = q.shape
    P, ps, Hkv, _ = kp.shape
    assert H % Hkv == 0
    n_work = wrow.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n_work,),
        in_specs=[
            pl.BlockSpec((1, C, H, hd),
                         lambda i, wr, wp, wf, qp: (wr[i], 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, hd),
                         lambda i, wr, wp, wf, qp: (wp[i], 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, hd),
                         lambda i, wr, wp, wf, qp: (wp[i], 0, 0, 0)),
            pl.BlockSpec((1, ps), lambda i, wr, wp, wf, qp: (wp[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, C, H, hd),
                               lambda i, wr, wp, wf, qp: (wr[i], 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, H), jnp.float32),
            pltpu.VMEM((C, H), jnp.float32),
            pltpu.VMEM((C, H, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_kernel, scale=1.0 / (hd ** 0.5),
                          window=window, n_groups=H // Hkv),
        out_shape=jax.ShapeDtypeStruct((B, C, H, hd), q.dtype),
        grid_spec=grid_spec,
        interpret=interpret,
    )(wrow.astype(jnp.int32), wpage.astype(jnp.int32),
      wflags.astype(jnp.int32), qpos.astype(jnp.int32), q, kp, vp, ppos)


# ----------------------------------------------------------------------
def ragged_attention(q, kp, vp, ppos, pages, qpos, *,
                     window: Optional[int] = None,
                     q_chunk: Optional[int] = None,
                     worklist=None, interpret=True, layer=None):
    """Dispatch: with a host-built ``worklist`` (wrow, wpage, wflags)
    run the Pallas page-skip kernel; inside jitted model programs (no
    host work list) the gather reference runs — on this CPU host that
    is the production path, and it is bitwise ``attention_core``."""
    if worklist is not None:
        assert layer is None, "worklist kernel takes per-layer pools"
        wrow, wpage, wflags = worklist
        return ragged_attention_pallas(q, kp, vp, ppos, qpos,
                                       jnp.asarray(wrow), jnp.asarray(wpage),
                                       jnp.asarray(wflags), window=window,
                                       interpret=interpret)
    return ragged_attention_reference(q, kp, vp, ppos, pages, qpos,
                                      window=window, q_chunk=q_chunk,
                                      layer=layer)
