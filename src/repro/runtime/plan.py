"""Step plans: what each sequence does in one engine step (DESIGN.md §8).

A serving step is no longer "decode every running row": with chunked
prefill, one step mixes *decode items* (one token for a running row) and
*prefill chunks* (``[lo, hi)`` of an admitting request's prompt, written
into its KV slot at that offset).  :class:`StepPlan` is the pure
description of such a mixed batch; :class:`TokenBudgetPolicy` builds one
per step under a hard token budget, so a long prompt can never
head-of-line-block the in-flight decodes — the scheduling lever the MoE
serving literature (Liu et al. 2024 survey; MoBiLE) identifies for
keeping the expert stream busy through prompt processing.

Invariants (property-tested in ``tests/test_runtime.py``):

* a plan never exceeds ``token_budget`` total tokens;
* a request's chunks are emitted in order and partition its prompt;
* decode rows are never starved — every running row decodes every step
  (prefill only spends the *surplus* budget), so the starvation bound
  is zero steps;
* the first admission always makes progress (liveness): the constructor
  rejects budgets below ``chunk_size + max_rows``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Sequence


@dataclass
class ChunkTask:
    """One prefill chunk ``[lo, hi)`` of one request's prompt."""

    rid: int
    slot: int
    lo: int
    hi: int
    last: bool  # final chunk: sample the first token, row joins decode


@dataclass
class Admission:
    """Engine-side record of a request being chunk-prefilled into its
    slot: the B=1 decode state accumulates chunk KV between steps and is
    scattered into the slotted state after the last chunk."""

    rid: int
    slot: int
    total: int              # prompt length
    next_lo: int = 0        # > 0 at creation for a prefix-cache hit: the
    #                         matched full pages are already mapped, so
    #                         the chunk plan starts at the divergence
    #                         point (DESIGN.md §13)
    state: Any = None       # B=1 decode state under construction
    pstate: Any = None      # unused by packed chunks (store-streamed)
    req: Any = None         # engine-side request handle
    # recompute-resume (DESIGN.md §13): a preempted request whose KV was
    # dropped re-prefills prompt+generated[:-1] (``tokens`` overrides the
    # chunk source) and the final chunk feeds ``resume_tok`` instead of
    # sampling — greedy decode makes the continuation bitwise
    tokens: Any = None      # chunk token source override (else req.prompt)
    resume_tok: Any = None  # pending token to feed instead of sampling

    @property
    def done(self) -> bool:
        return self.next_lo >= self.total


@dataclass
class StepPlan:
    """The mixed batch one engine step executes."""

    decode_rows: List[int] = field(default_factory=list)
    chunks: List[ChunkTask] = field(default_factory=list)

    @property
    def prefill_tokens(self) -> int:
        return sum(c.hi - c.lo for c in self.chunks)

    @property
    def total_tokens(self) -> int:
        return len(self.decode_rows) + self.prefill_tokens


@dataclass(frozen=True)
class TokenBudgetPolicy:
    """Per-step token budget packing decode rows + prefill chunks.

    Decode rows are always scheduled (they are the latency-critical
    tokens and each costs 1); the remaining budget is filled with prefill
    chunks in admission order.  Chunks are ``chunk_size`` tokens except a
    request's final remainder, so the set of compiled chunk shapes stays
    bounded by the distinct remainders (jit retraces per shape).
    """

    chunk_size: int
    token_budget: int
    max_rows: int  # engine slot count — bounds the decode-row reserve

    def __post_init__(self):
        if self.chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got "
                             f"{self.chunk_size}")
        floor = self.chunk_size + self.max_rows
        if self.token_budget < floor:
            raise ValueError(
                f"token_budget={self.token_budget} cannot make progress: "
                f"needs >= chunk_size + max_rows = {floor} so one chunk "
                f"always fits beside a full decode batch")

    def plan(self, decode_rows: Sequence[int],
             admissions: Sequence[Admission]) -> StepPlan:
        plan = StepPlan(decode_rows=list(decode_rows))
        budget = self.token_budget - len(plan.decode_rows)
        for adm in admissions:
            lo = adm.next_lo
            while lo < adm.total:
                take = min(self.chunk_size, adm.total - lo)
                if take > budget:
                    break
                plan.chunks.append(ChunkTask(
                    rid=adm.rid, slot=adm.slot, lo=lo, hi=lo + take,
                    last=(lo + take) >= adm.total))
                budget -= take
                lo += take
            if lo < adm.total:
                break  # keep admission order: don't leapfrog a stalled one
        return plan
