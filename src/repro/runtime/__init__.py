"""Unified step-plan runtime (DESIGN.md §8): one block-execution core
(:class:`~repro.runtime.executor.Executor`) with interchangeable planes
(plain / packed_vectorized / packed_pipelined), plus the step-plan data
model (:class:`~repro.runtime.plan.StepPlan`) and the chunked-prefill
token-budget policy (:class:`~repro.runtime.plan.TokenBudgetPolicy`)
every serving engine schedules with."""
from repro.runtime.executor import PLANES, Executor
from repro.runtime.plan import (Admission, ChunkTask, StepPlan,
                                TokenBudgetPolicy)

__all__ = ["Executor", "PLANES", "Admission", "ChunkTask", "StepPlan",
           "TokenBudgetPolicy"]
