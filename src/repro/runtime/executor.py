"""The unified block-execution core every engine dispatches through
(DESIGN.md §8).

One :class:`Executor` owns the per-layer-kind jitted block programs for a
model and runs them in three interchangeable **planes**:

* ``plain``             — dense resident weights, per-token expert gather
  (``moe_apply_gather``) through the scanned ``transformer.decode_step``;
* ``packed_vectorized`` — HQQ-packed experts served from the device
  buffer pool with the vectorized slot plans, staging synchronous inside
  the block program (DESIGN.md §6/§7);
* ``packed_pipelined``  — same data plane, but each MoE block splits into
  mixer / MoE / staging dispatches so speculative host→device copies
  overlap the next block's compute (DESIGN.md §7).

Every step is a **chunk**: decode is the C = 1 case and a prefill chunk
is the C > 1 case of the same block program (``decode_step`` /
``decode_block_packed*`` — the KV caches are written at positions
``pos .. pos+C−1``).  Whole-prompt prefill is therefore *chunked prefill
with one chunk*, which is what makes chunked ≡ whole bitwise: chunk size
only changes the number of query rows per dispatch, and every reduction
(softmax over the KV width, per-row matmuls) keeps its shape
(tests/test_runtime.py asserts bitwise equality on all planes).

Packed-plane prefill chunks stream their routed experts straight from
the host store (one ``pe_gather`` batch plan per layer per chunk,
``moe_apply_packed_stream``) and leave the LRU pool, staging tiers and
transfer counters untouched — prefill is the encode phase the paper's
cache does not manage, so chunking adds zero pool traffic.

All programs go through ``transformer.cached_jit`` under config-keyed
names, so every engine and every Executor instance of the same
(cfg, plane, mode) shares one compiled program per process
(``cached_jit_stats`` asserts this in the tests).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, OffloadSpec, parse_block
from repro.core import expert_pool as EP
from repro.core import speculative
from repro.core.trace import stacked_routers
from repro.models import moe as M
from repro.models import transformer as T

PLANES = ("plain", "packed_vectorized", "packed_pipelined")


class Executor:
    """Unified step-plan executor (module docstring; DESIGN.md §8).

    ``spec``/``store`` are required for the packed planes (the offload
    configuration and the packed host store from
    ``quantize_for_offload(..., pack_experts=True)``).  ``fused`` /
    ``vectorized`` select the packed data plane (fused dequant-matmul
    kernels; batched vs PR-2 sequential slot swaps) — kept for the
    offload benchmark's measured baselines.
    """

    def __init__(self, params, cfg: ModelConfig, *, plane: str = "plain",
                 spec: Optional[OffloadSpec] = None, store=None,
                 fused: bool = True, vectorized: bool = True):
        if plane not in PLANES:
            raise ValueError(f"unknown plane {plane!r}; one of {PLANES}")
        self.plane = plane
        self.packed = plane != "plain"
        self.pipelined = plane == "packed_pipelined"
        self.params = params
        self.cfg = cfg
        self.spec = spec
        self.store = store
        self.fused = fused
        self.vectorized = vectorized
        # optional dispatch-phase observer (repro.obs.ExecPhases);
        # host-side timestamps around dispatches only — never a device
        # sync.  Executors can be shared across engines (the offload
        # engine hands its decoder to ContinuousEngine), so the LAST
        # attached observer wins.
        self._obs = None
        # fault-injection plane (DESIGN.md §14): consulted host-side at
        # the expert-fetch boundary of the per-layer decode loop — jit
        # programs never see it.  Present on every plane (the plain
        # plane simply has no fetch site) so engines can attach/detach
        # unconditionally, mirroring the observer protocol.
        self._finj = None
        self._fetch_retries = 2
        self._fetch_backoff_ms = 0.0
        self.fault_counters = {"fetch_retries": 0, "fetch_degraded": 0}
        if self.packed:
            if spec is None or store is None:
                raise ValueError("packed planes need spec= and store= "
                                 "(see quantize_for_offload)")
            self.routers = jnp.asarray(stacked_routers(params, cfg))
            self.n_moe_layers = int(self.routers.shape[0])
            self.kinds = cfg.layer_kinds()
            # MoE ordinal of each absolute layer (period-major — the
            # order stacked_routers / the store use)
            self.moe_ordinal: Dict[int, int] = {}
            for l, k in enumerate(self.kinds):
                if parse_block(k)[1] == "moe":
                    self.moe_ordinal[l] = len(self.moe_ordinal)
            self._layer_p = [T.layer_params(params, cfg, l)
                             for l in range(cfg.n_layers)]
            self._jit_embed = T.cached_jit(
                ("embed", cfg), lambda: jax.jit(
                    lambda p, t: T.embed_tokens(p, cfg, t)))
            self._jit_head = T.cached_jit(
                ("head", cfg), lambda: jax.jit(
                    lambda p, x: T.apply_head(p, cfg, x)))
            # mode key: packed-block executables are shared across
            # executor instances with identical config+flags
            self._mode = (cfg, spec, fused, self.pipelined, vectorized)
            self._blk: Dict[Any, Any] = {}

    # ------------------------------------------------------------------
    def set_observer(self, obs) -> None:
        """Attach (or detach with ``None``) the dispatch-phase observer
        for :meth:`decode` / :meth:`decode_sampled` — an object with
        ``begin()`` / ``mark(phase)`` whose phases match this plane's
        ``repro.obs.schema.EXEC_KEYS_BY_PLANE`` entry."""
        self._obs = obs

    def set_fault_injector(self, inj, *, max_retries: int = 2,
                           backoff_ms: float = 0.0) -> None:
        """Attach (or clear with ``None``) the seeded fault plane
        (DESIGN.md §14).  Site here: ``expert_fetch``
        (``core.expert_pool.FAULT_SITE``) — a fired fault means the
        pool-path h2d gather for one MoE layer failed; the decode loop
        retries up to ``max_retries`` times (sleeping ``backoff_ms``
        between attempts) and then degrades that layer to store-direct
        streaming, dropping speculative staging for the step.  Executors
        are shared across engines, so — like the observer — the LAST
        attached injector wins."""
        self._finj = inj
        self._fetch_retries = int(max_retries)
        self._fetch_backoff_ms = float(backoff_ms)
        # shared-executor semantics: each engine attaches on construction,
        # so the ladder counters always describe the CURRENT engine's run
        self.fault_counters = {"fetch_retries": 0, "fetch_degraded": 0}

    def _fetch_faulted(self) -> bool:
        """One MoE layer's fetch boundary: did the (retried) h2d fetch
        ultimately fail?  True = degrade this layer."""
        inj = self._finj
        if inj is None or not inj.fires(EP.FAULT_SITE):
            return False
        for _ in range(self._fetch_retries):
            self.fault_counters["fetch_retries"] += 1
            if self._fetch_backoff_ms > 0.0:
                time.sleep(self._fetch_backoff_ms / 1e3)
            if not inj.fires(EP.FAULT_SITE):
                return False  # a retry went through
        self.fault_counters["fetch_degraded"] += 1
        return True

    # ------------------------------------------------------------------
    # state / pool construction
    def init_state(self, batch: int, max_len: int):
        """Fresh decode state (stacked layout, scalar pos 0)."""
        return T.init_decode_state(self.cfg, batch, max_len)

    def encode(self, audio_embeds):
        """Encoder pass + cross-attn K/V collection — the admission-time
        computation of the read-only shared encoder-KV plane
        (DESIGN.md §12).  Returns the ``state["enc_kv"]`` pytree
        ({"k", "v": (n_layers, B, S_e, H_kv, Dh), "pos"}); runs once per
        request, referenced by every decode step, never scattered to."""
        assert self.cfg.is_encoder_decoder, "encode() is the enc-dec frontend"
        cfg = self.cfg
        fn = T.cached_jit(
            ("encode_enc_kv", cfg),
            lambda: jax.jit(lambda p, a: T.encode_enc_kv(p, cfg, a)))
        return fn(self.params, jnp.asarray(audio_embeds))

    def init_pool_state(self) -> "EP.PoolState":
        assert self.packed, "buffer pools exist on packed planes only"
        return EP.init_pool_state(self.store, self.spec)

    # ------------------------------------------------------------------
    # plain-plane programs (shared cache keys predate the runtime
    # refactor — every engine keeps reusing the same executables)
    def _plain_step(self, collect_info: bool):
        key = ("decode_gather_info" if collect_info else "decode_gather",
               self.cfg)
        cfg = self.cfg
        if collect_info:
            make = lambda: jax.jit(lambda p, st, tk, act: T.decode_step(
                p, cfg, st, tk, moe_mode="gather", collect_info=True,
                active=act))
        else:
            make = lambda: jax.jit(lambda p, st, tk, act: T.decode_step(
                p, cfg, st, tk, moe_mode="gather", active=act))
        return T.cached_jit(key, make)

    def _plain_step_sampled(self, collect_info: bool, greedy: bool):
        cfg, collect = self.cfg, collect_info

        def make():
            if collect:
                def _step_fn(p, st, tk, act):
                    logits, st, infos = T.decode_step(
                        p, cfg, st, tk, moe_mode="gather",
                        collect_info=True, active=act)
                    nxt = (jnp.argmax(logits[:, -1], -1)
                           .astype(jnp.int32) if greedy
                           else logits[:, -1])
                    return nxt, st, infos
            else:
                def _step_fn(p, st, tk, act):
                    logits, st = T.decode_step(p, cfg, st, tk,
                                               moe_mode="gather",
                                               active=act)
                    nxt = (jnp.argmax(logits[:, -1], -1)
                           .astype(jnp.int32) if greedy
                           else logits[:, -1])
                    return nxt, st
            return jax.jit(_step_fn, donate_argnums=1)
        return T.cached_jit(("cont_step", cfg, collect, greedy), make)

    def _row_chunk_step(self):
        """B=1 prefill chunk of one slot against the shared page pools
        (paged admission, DESIGN.md §9): ``decode_step(row=slot)`` —
        the chunk's KV lands in the pages the slot owns, no install.
        The state is donated (callers hand in a fresh view and adopt
        the result) so the pool scatters run in place instead of
        copying pool-capacity bytes per chunk."""
        cfg = self.cfg
        return T.cached_jit(
            ("decode_gather_row", cfg),
            lambda: jax.jit(lambda p, st, tk, r: T.decode_step(
                p, cfg, st, tk, moe_mode="gather", row=r),
                donate_argnums=1))

    # ------------------------------------------------------------------
    # packed-plane per-kind block programs (moved from the PR-2/PR-3
    # PackedDecoder — identical cache keys, identical programs)
    def _decode_blk(self, kind: str):
        if kind not in self._blk:
            # locals only in the closures: a `self` capture would pin the
            # whole executor (params + store) in the process-wide cache
            cfg, spec = self.cfg, self.spec
            fused, vectorized = self.fused, self.vectorized
            if parse_block(kind)[1] == "moe":
                def make():
                    fn = lambda p, x, st, pos, store, ps, lm, routers, \
                        act, pages: T.decode_block_packed(
                            p, cfg, kind, x, st, pos, store, ps, lm,
                            routers, lookahead=spec.lookahead,
                            n_spec=spec.num_speculative, fused=fused,
                            active=act, vectorized=vectorized, pages=pages)
                    return jax.jit(fn, donate_argnums=(5,))
                key = ("packed_blk", self._mode, kind)
            else:
                def make():
                    fn = lambda p, x, st, pos, pages, act: T._block_decode(
                        p, cfg, kind, x, st, pos, moe_mode="gather",
                        pages=pages, active=act)
                    return jax.jit(fn)
                # a non-MoE block's program depends only on (cfg, kind) —
                # identical across offload modes
                key = ("packed_blk_plain", cfg, kind)
            self._blk[kind] = T.cached_jit(key, make)
        return self._blk[kind]

    def _mixer_blk(self, kind: str):
        key = ("mixer", kind)
        if key not in self._blk:
            cfg = self.cfg
            self._blk[key] = T.cached_jit(
                ("packed_mixer", cfg, kind),
                lambda: jax.jit(
                    lambda p, x, st, pos, pages, act:
                        T.decode_block_packed_mixer(
                            p, cfg, kind, x, st, pos, pages=pages,
                            active=act)))
        return self._blk[key]

    def _moe_blk(self):
        if "moe_ffn" not in self._blk:
            cfg = self.cfg
            fused, vectorized = self.fused, self.vectorized

            def make():
                fn = lambda p, x, h2, store, ps, lm, act: \
                    T.decode_block_packed_moe(
                        p, cfg, x, h2, store, ps, lm, fused=fused,
                        vectorized=vectorized, active=act)
                return jax.jit(fn, donate_argnums=(4,))
            self._blk["moe_ffn"] = T.cached_jit(("packed_moe", self._mode),
                                                make)
        return self._blk["moe_ffn"]

    def _stage_blk(self):
        if "stage" not in self._blk:
            n_spec = self.spec.num_speculative
            vectorized = self.vectorized

            def make():
                def fn(store, ps, tgt, hidden, routers):
                    pred = speculative.predict_experts(
                        routers[tgt], hidden, n_spec)[0]
                    return EP.stage(store, ps, tgt, pred, True,
                                    vectorized=vectorized)
                return jax.jit(fn, donate_argnums=(1,))
            self._blk["stage"] = T.cached_jit(("packed_stage", self._mode),
                                              make)
        return self._blk["stage"]

    def _chunk_moe_blk(self):
        """Prefill-chunk MoE: route + store-gather + packed compute — no
        pool state in the program at all (DESIGN.md §8)."""
        if "chunk_moe" not in self._blk:
            cfg, fused = self.cfg, self.fused

            def make():
                def fn(p, x, h2, store, lm):
                    B, C, D = h2.shape
                    y2d, _ = M.moe_apply_packed_stream(
                        p["moe"], cfg, h2.reshape(B * C, D), store, lm,
                        fused=fused)
                    return x + y2d.reshape(B, C, D)
                return jax.jit(fn)
            self._blk["chunk_moe"] = T.cached_jit(
                ("packed_chunk_moe", cfg, fused), make)
        return self._blk["chunk_moe"]

    def _chunk_moe_ids_blk(self):
        """Store-direct MoE that also returns the routed expert ids —
        the degraded decode path (DESIGN.md §14): same
        ``moe_apply_packed_stream`` -> ``_packed_compute`` pipeline as
        the pool path, so its activations are bitwise the pool path's;
        only the LRU/transfer counters differ (no pool traffic)."""
        if "chunk_moe_ids" not in self._blk:
            cfg, fused = self.cfg, self.fused

            def make():
                def fn(p, x, h2, store, lm):
                    B, C, D = h2.shape
                    y2d, info = M.moe_apply_packed_stream(
                        p["moe"], cfg, h2.reshape(B * C, D), store, lm,
                        fused=fused)
                    return x + y2d.reshape(B, C, D), info["ids"]
                return jax.jit(fn)
            self._blk["chunk_moe_ids"] = T.cached_jit(
                ("packed_chunk_moe_ids", cfg, fused), make)
        return self._blk["chunk_moe_ids"]

    # ------------------------------------------------------------------
    def decode(self, state, tokens, pstate=None, active=None, *,
               collect_info: bool = False):
        """One decode step for every row — the unified engine entry.

        tokens: (B, C) int32 — C = 1 for plain decode, C = k+1 for a
        speculative verify chunk (DESIGN.md §11); KV is written at
        ``pos .. pos+C−1`` and ``pos`` advances by C (active rows).
        Returns ``(logits, state', pstate',
        info)`` on every plane; ``pstate`` threads the expert buffer pool
        (packed planes; ``None`` on plain), ``active`` (B,) bool masks
        rows whose output is discarded (continuous batching free slots).
        ``info`` is the per-MoE-layer route-id list on packed planes, the
        raw ``decode_step`` info stack when ``collect_info`` on plain,
        else ``None``.

        On paged-KV states (``"pages"`` in state) ``active`` also gates
        KV writes and per-row ``pos`` advance (DESIGN.md §9): frozen
        rows are idle slots or chunked admissions mid-fill.
        """
        obs = self._obs
        if obs is not None:
            obs.begin()
        if not self.packed:
            if collect_info:
                logits, state, infos = self._plain_step(True)(
                    self.params, state, tokens, active)
                if obs is not None:
                    obs.mark("dispatch")
                return logits, state, None, infos
            logits, state = self._plain_step(False)(
                self.params, state, tokens, active)
            if obs is not None:
                obs.mark("dispatch")
            return logits, state, None, None
        cfg = self.cfg
        x = self._jit_embed(self.params, tokens)
        if obs is not None:
            obs.mark("embed")
        pos = state["pos"]
        pages = state.get("pages")
        B = int(tokens.shape[0])
        # speculation is the paper's batch-1 interactive feature (batched
        # continuous decode disables it) — same gate the synchronous
        # block applies inside jit via moe_apply_packed's T == 1 check
        speculate = (self.pipelined and self.spec.num_speculative > 0
                     and B * int(tokens.shape[1]) == 1)
        route_ids = []
        for l, kind in enumerate(self.kinds):
            st_l = T.decode_state_layer(state, cfg, l)
            if l in self.moe_ordinal:
                lm = jnp.asarray(self.moe_ordinal[l], jnp.int32)
                if self._fetch_faulted():
                    # retry ladder exhausted (DESIGN.md §14): degrade
                    # this layer to store-direct streaming — bitwise the
                    # pool path's activations (shared _packed_compute),
                    # zero pool traffic, no speculative staging
                    x, st_l, h2 = self._mixer_blk(kind)(
                        self._layer_p[l], x, st_l, pos, pages, active)
                    if obs is not None:
                        obs.mark("mixer" if self.pipelined else "block")
                    x, ids = self._chunk_moe_ids_blk()(
                        self._layer_p[l], x, h2, self.store, lm)
                    if obs is not None:
                        obs.mark("moe" if self.pipelined else "block")
                    route_ids.append(ids)
                    state = T.set_decode_state_layer(state, cfg, l, st_l)
                    continue
                if self.pipelined:
                    x, st_l, h2 = self._mixer_blk(kind)(
                        self._layer_p[l], x, st_l, pos, pages, active)
                    if obs is not None:
                        obs.mark("mixer")
                    x, pstate, info = self._moe_blk()(
                        self._layer_p[l], x, h2, self.store, pstate, lm,
                        active)
                    if obs is not None:
                        obs.mark("moe")
                    tgt = self.moe_ordinal[l] + self.spec.lookahead
                    if speculate and tgt < self.n_moe_layers:
                        pstate = self._stage_blk()(
                            self.store, pstate,
                            jnp.asarray(tgt, jnp.int32),
                            info["hidden_pre_moe"], self.routers)
                        if obs is not None:
                            obs.mark("stage")
                else:
                    x, st_l, pstate, info = self._decode_blk(kind)(
                        self._layer_p[l], x, st_l, pos, self.store, pstate,
                        lm, self.routers, active, pages)
                    if obs is not None:
                        obs.mark("block")
                route_ids.append(info["route"]["ids"])
            else:
                x, st_l, _ = self._decode_blk(kind)(
                    self._layer_p[l], x, st_l, pos, pages, active)
                if obs is not None:
                    # non-MoE dispatch: the pipelined plane's mixer bucket,
                    # the vectorized plane's block bucket
                    obs.mark("mixer" if self.pipelined else "block")
            state = T.set_decode_state_layer(state, cfg, l, st_l)
        logits = self._jit_head(self.params, x)
        if obs is not None:
            obs.mark("head")
        # decode is the C=1 case of a chunk; a C=k+1 verify chunk
        # (speculative decoding, DESIGN.md §11) advances by its width
        C = int(tokens.shape[1])
        if pages is not None and active is not None:
            pos = pos + jnp.where(active, C, 0).astype(pos.dtype)
        else:
            pos = pos + C
        state = dict(state, pos=pos)
        return logits, state, pstate, route_ids

    def decode_sampled(self, state, tokens, *, collect_info: bool,
                       greedy: bool, active=None):
        """Plain-plane decode with sampling prep fused into the jitted
        step (greedy argmax on-device / last-position logits) and the
        state donated — the continuous engine's hot loop."""
        assert not self.packed, "packed decode returns logits; sample host-side"
        obs = self._obs
        if obs is not None:
            obs.begin()
        out = self._plain_step_sampled(collect_info, greedy)(
            self.params, state, tokens, active)
        if obs is not None:
            obs.mark("dispatch")
        return out

    # ------------------------------------------------------------------
    def prefill_chunk(self, state, tokens, pstate=None):
        """Process prompt chunk ``tokens`` (B, C) at the rows' current
        positions: KV written at ``pos .. pos+C−1``, ``pos`` advances by
        C.  Returns ``(logits (B, C, V), state', pstate')`` — chunk MoE
        never touches the pool state (module docstring)."""
        if not self.packed:
            logits, state = self._plain_step(False)(
                self.params, state, tokens, None)
            return logits, state, pstate
        cfg = self.cfg
        x = self._jit_embed(self.params, tokens)
        pos = state["pos"]
        pages = state.get("pages")
        for l, kind in enumerate(self.kinds):
            st_l = T.decode_state_layer(state, cfg, l)
            if l in self.moe_ordinal:
                lm = jnp.asarray(self.moe_ordinal[l], jnp.int32)
                x, st_l, h2 = self._mixer_blk(kind)(
                    self._layer_p[l], x, st_l, pos, pages, None)
                x = self._chunk_moe_blk()(
                    self._layer_p[l], x, h2, self.store, lm)
            else:
                x, st_l, _ = self._decode_blk(kind)(
                    self._layer_p[l], x, st_l, pos, pages, None)
            state = T.set_decode_state_layer(state, cfg, l, st_l)
        logits = self._jit_head(self.params, x)
        state = dict(state, pos=pos + tokens.shape[1])
        return logits, state, pstate

    def prefill_chunk_row(self, state, tokens, slot: int):
        """One slot's prompt chunk against the shared page pools (paged
        admission, DESIGN.md §9): tokens (1, C) write KV straight into
        the pages ``slot`` owns at its current position and only that
        row's ``pos`` advances.  Returns (logits (1, C, V), state').
        There is no install step — the running batch reads the same
        pools the chunk just wrote."""
        assert "pages" in state, "prefill_chunk_row needs a paged-KV state"
        slot_t = jnp.asarray(slot, jnp.int32)
        if not self.packed:
            return self._row_chunk_step()(self.params, state, tokens, slot_t)
        cfg = self.cfg
        x = self._jit_embed(self.params, tokens)
        pos_row = jax.lax.dynamic_slice(state["pos"], (slot_t,), (1,))
        pages_row = jax.lax.dynamic_slice(
            state["pages"], (slot_t, 0), (1, state["pages"].shape[1]))
        for l, kind in enumerate(self.kinds):
            st_l = T.decode_state_layer(state, cfg, l)
            if l in self.moe_ordinal:
                lm = jnp.asarray(self.moe_ordinal[l], jnp.int32)
                x, st_l, h2 = self._mixer_blk(kind)(
                    self._layer_p[l], x, st_l, pos_row, pages_row, None)
                x = self._chunk_moe_blk()(
                    self._layer_p[l], x, h2, self.store, lm)
            else:
                x, st_l, _ = self._decode_blk(kind)(
                    self._layer_p[l], x, st_l, pos_row, pages_row, None)
            state = T.set_decode_state_layer(state, cfg, l, st_l)
        logits = self._jit_head(self.params, x)
        state = dict(state, pos=jax.lax.dynamic_update_slice(
            state["pos"], pos_row + tokens.shape[1], (slot_t,)))
        return logits, state

    def prefill(self, tokens, max_len: int, *, chunk: Optional[int] = None,
                pstate=None, extras=None):
        """Whole-prompt prefill = chunked prefill over a fresh state —
        for EVERY layer kind in the config zoo (DESIGN.md §12).

        tokens: (B, S) int32, no padding (rows prefill alone or in
        equal-length lock-step; the static engine's left-padded batches
        go through :meth:`prefill_padded`).  ``chunk=None`` processes the
        prompt as ONE chunk; any chunking is bitwise-identical
        (tests/test_runtime.py): attention mixers only change the number
        of query rows per dispatch, recurrent mixers fold chunks through
        their sequential chunk forms whose carry composition is exact
        (``repro.models.recurrent.*_chunk``).  The full-sequence
        ``forward_train`` prefill (chunkwise-parallel train forms) stays
        available via :meth:`prefill_padded` — it matches this path only
        to recurrent-vs-chunkwise tolerance, never bitwise, which is why
        every serving engine and its oracle run THIS path.

        Encoder-decoder stacks need ``extras={"audio_embeds": (B, S_e,
        D)}``; the encoder runs once up front (:meth:`encode`) and the
        chunks read the resulting shared ``enc_kv`` plane.

        Returns (logits of the last chunk, state, pstate).
        """
        tokens = jnp.asarray(tokens)
        B, S = tokens.shape
        C = S if chunk is None else max(1, min(int(chunk), S))
        state = self.init_state(B, max_len)
        if self.cfg.is_encoder_decoder:
            if not extras or "audio_embeds" not in extras:
                raise ValueError(
                    f"{self.cfg.name} is encoder-decoder: prefill needs "
                    "extras={'audio_embeds': (B, encoder_seq, d_model)}")
            state["enc_kv"] = self.encode(extras["audio_embeds"])
        logits = None
        for lo in range(0, S, C):
            logits, state, pstate = self.prefill_chunk(
                state, tokens[:, lo: lo + C], pstate)
        return logits, state, pstate

    def prefill_padded(self, batch, max_len: int):
        """Left-padded batched prefill (static ``ServeEngine`` shape):
        the full-sequence ``forward_train`` pass with pad-mask isolation
        — a *different* program from the chunk path (dispatch MoE, S×S
        attention), kept for throughput-oriented static batches where
        all rows prefill together."""
        assert not self.packed, "packed engines prefill through chunks"
        return T.make_prefill(self.cfg)(self.params, batch, max_len)

    # ------------------------------------------------------------------
    def generate_greedy(self, prompt, max_new_tokens: int, *,
                        prefill_chunk: Optional[int] = None,
                        extras=None) -> np.ndarray:
        """Greedy decode of one prompt (1, S) — the parity oracle loop
        shared by ``generate_plain`` and the tests.  Plain plane only
        (the offload engine drives the packed planes with stats/usage
        accounting around the same Executor calls).  ``extras`` carries
        non-token conditioning (enc-dec ``audio_embeds``)."""
        assert not self.packed
        prompt = jnp.asarray(prompt)
        max_len = int(prompt.shape[1]) + max_new_tokens
        pre_logits, state, _ = self.prefill(prompt, max_len,
                                            chunk=prefill_chunk,
                                            extras=extras)
        first = jnp.argmax(pre_logits[:, -1], axis=-1)
        out = [int(first[0])]
        tok = first[:, None].astype(jnp.int32)
        for _ in range(max_new_tokens - 1):
            logits, state, _, _ = self.decode(state, tok)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            tok = nxt[:, None].astype(jnp.int32)
            out.append(int(nxt[0]))
        return np.asarray(out)[None]
