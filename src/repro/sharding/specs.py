"""Logical-axis sharding rules (FSDP + tensor parallel + expert parallel).

The production mesh is ``("data", "model")`` single-pod or
``("pod", "data", "model")`` multi-pod (launch/mesh.py).  Policy:

* **batch** -> ``("pod", "data")`` (dropped when the global batch is not
  divisible, e.g. long_500k B=1);
* **tensor parallel** -> ``"model"`` on attention head axes / FFN hidden /
  expert hidden, guarded by divisibility (e.g. smollm's 15 heads and
  qwen's 20 heads do not TP on a 16-way axis — their FFN still does);
* **FSDP** -> parameters additionally sharded on ``"data"`` along a
  non-TP axis so params+AdamW state of the 104B config fit 16GB/chip;
* **expert parallel** -> expert axis on ``"model"`` when
  ``num_experts % model_size == 0`` (granite: 32 % 16 = 0 -> EP with
  all-to-all dispatch); otherwise experts are tensor-parallel over their
  hidden dim (mixtral: 8 experts on a 16-way axis -> TP).

``constrain`` is a mesh-aware ``with_sharding_constraint`` that silently
no-ops outside a mesh context (CPU unit tests) and drops axes that are
absent or non-divisible, so model code can state intent unconditionally.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
    except Exception:
        m = None
    if m is None or not getattr(m, "axis_names", ()):
        # jax < 0.5: the ambient mesh is the legacy global-mesh context
        # entered via ``with mesh:`` (launch/mesh.mesh_context)
        try:
            from jax.interpreters import pxla
            m = pxla.thread_resources.env.physical_mesh
        except Exception:
            return None
    if m is None or not getattr(m, "axis_names", ()):
        return None
    return m


def _filter_spec(mesh, shape, spec_entries):
    """Keep only axes present in the mesh and dividing the dim size."""
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        size = 1
        for ax in axes:
            if ax in mesh.axis_names:
                kept.append(ax)
                size *= mesh.shape[ax]
        if kept and dim % size == 0:
            out.append(tuple(kept) if len(kept) > 1 else kept[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x, *spec_entries):
    mesh = _current_mesh()
    if mesh is None:
        return x
    entries = list(spec_entries) + [None] * (x.ndim - len(spec_entries))
    spec = _filter_spec(mesh, x.shape, entries[: x.ndim])
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ----------------------------------------------------------------------
# Parameter / activation spec construction (used by the launchers).
def batch_spec(mesh, global_batch: int):
    """Spec entry for the batch axis: ("pod","data") when divisible."""
    axes = [ax for ax in ("pod", "data") if ax in mesh.axis_names]
    size = 1
    for ax in axes:
        size *= mesh.shape[ax]
    if axes and global_batch % size == 0:
        return tuple(axes) if len(axes) > 1 else axes[0]
    return None


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _tp(mesh, dim: int) -> Optional[str]:
    return "model" if dim % mesh_axis_size(mesh, "model") == 0 else None


def _fsdp(mesh, dim: int):
    """FSDP axis for parameters/optimizer state: all batch-parallel axes
    (ZeRO shards over every data rank, pods included)."""
    axes = tuple(ax for ax in ("data", "pod") if ax in mesh.axis_names)
    size = 1
    for ax in axes:
        size *= mesh.shape[ax]
    if axes and dim % size == 0:
        return axes if len(axes) > 1 else axes[0]
    if dim % mesh_axis_size(mesh, "data") == 0:
        return "data"
    return None


def param_spec_tree(cfg, mesh, params_shape, *, serve_tp_only: bool = False):
    """PartitionSpec pytree mirroring ``init_model``'s parameter tree.

    Works from the *shape* tree (``jax.eval_shape`` output) so no real
    arrays are needed.  Rules are name-based on the leaf path.

    ``serve_tp_only`` drops the FSDP ("data") axis from weights — for
    autoregressive decoding, FSDP means re-all-gathering every weight
    EVERY TOKEN (10.5GB/step on mixtral decode_32k — §Perf); serving uses
    pure tensor parallelism whenever the TP-sharded params fit HBM.
    """
    msz = mesh_axis_size(mesh, "model")
    ep = cfg.moe is not None and cfg.moe.num_experts % msz == 0

    fsdp_fn = _fsdp
    if serve_tp_only:
        def fsdp_fn(mesh_, dim_):
            return None

    def leaf_spec(path: Tuple[str, ...], shape) -> P:
        name = path[-1]
        nd = len(shape)
        stacked = nd >= 1 and path_is_stacked(path)
        pre = (None,) if stacked else ()
        core = shape[1:] if stacked else shape

        def sp(*entries):
            entries = list(entries) + [None] * (len(core) - len(entries))
            return P(*(pre + tuple(entries[: len(core)])))

        # --- embeddings / unembedding ---
        if name == "table":
            return P(_tp(mesh, shape[0]), fsdp_fn(mesh, shape[1]))
        if name == "w" and "lm_head" in path:
            return P(fsdp_fn(mesh, shape[0]), _tp(mesh, shape[1]))
        # --- attention (D, H, hd) / (H, hd, D) ---
        if name in ("wq", "wk", "wv"):
            d, h = core[0], core[1]
            tp_h = _tp(mesh, h)
            if tp_h is None:
                # GQA kv heads not divisible by the model axis: shard the
                # contraction dim D on "model" instead (partial-sum AR of
                # the small kv activations replaces the 936GB/step
                # replicated-weight-grad AR — §Perf iteration 4, 104B)
                f = fsdp_fn(mesh, d)
                fax = f if isinstance(f, tuple) else ((f,) if f else ())
                comb = tuple(fax) + ("model",)
                sz = 1
                for ax in comb:
                    sz *= mesh_axis_size(mesh, ax)
                if d % sz == 0:
                    return sp(comb, None, None)
            return sp(fsdp_fn(mesh, d), tp_h, None)
        if name == "wo":
            h, _, d = core
            return sp(_tp(mesh, h), None, fsdp_fn(mesh, d))
        if name in ("bq", "bk", "bv"):
            return sp(_tp(mesh, core[0]), None)
        # --- dense MLP ---
        if name in ("w_gate", "w_up", "w_in") and "experts" not in path:
            return sp(fsdp_fn(mesh, core[0]), _tp(mesh, core[1]))
        if name in ("w_down", "w_out") and "experts" not in path:
            return sp(_tp(mesh, core[0]), fsdp_fn(mesh, core[1]))
        # --- MoE experts (E, D, F) / (E, F, D) ---
        if "experts" in path and name in ("w_gate", "w_up"):
            e, d, f = core
            if ep:
                return sp("model", fsdp_fn(mesh, d), None)
            return sp(None, fsdp_fn(mesh, d), _tp(mesh, f))
        if "experts" in path and name == "w_down":
            e, f, d = core
            if ep:
                return sp("model", None, fsdp_fn(mesh, d))
            return sp(None, _tp(mesh, f), fsdp_fn(mesh, d))
        if name == "router":
            return sp(fsdp_fn(mesh, core[0]), None)
        # --- recurrent blocks ---
        if name in ("w_qkv",):  # (H, dh, dh) blockdiag
            return sp(None, None, _tp(mesh, core[2]))
        if name in ("w_gates_in",):  # (D, n_gates, H, dh)
            return sp(fsdp_fn(mesh, core[0]), None, None, None)
        if name in ("r_gates",):  # (n_gates, H, dh, dh)
            return sp(None, None, None, _tp(mesh, core[3]))
        if name in ("w_x", "w_gate_br", "w_in_gate", "w_rec_gate", "w_ogate"):
            return sp(fsdp_fn(mesh, core[0]), _tp(mesh, core[1]) if len(core) > 1 else None)
        if name in ("w_out_r", "w_out_x", "out_proj"):
            return sp(_tp(mesh, core[0]), fsdp_fn(mesh, core[1]) if len(core) > 1 else None)
        if name == "img_proj":
            return sp(fsdp_fn(mesh, core[0]), None)
        # scales, biases, conv kernels, lambdas, norms: replicate
        return P(*([None] * nd))

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(t)
        return leaf_spec(path, tree.shape)

    return walk(params_shape, ())


def path_is_stacked(path: Tuple[str, ...]) -> bool:
    """Leaves under params["stack"] carry a leading n_periods axis."""
    return "stack" in path


def decode_state_spec_tree(cfg, mesh, global_batch: int, state_shapes):
    """PartitionSpec tree for the decode state (KV caches / recurrent).

    Policy: batch on ("pod","data") when divisible; KV heads on "model"
    when divisible, else the cache sequence axis on "model"; for B==1
    (long_500k) the cache sequence axis additionally takes the batch axes
    (sequence-parallel cache).  Recurrent states shard their elementwise
    feature axis on "model".
    """
    b_ax = batch_spec(mesh, global_batch)
    msz = mesh_axis_size(mesh, "model")

    def kv_spec(shape, lead):
        B, W, Hkv, hd = shape[-4:]
        h_ax = "model" if Hkv % msz == 0 else None
        w_axes = []
        if b_ax is None:
            cand = [ax for ax in ("pod", "data") if ax in mesh.axis_names]
            sz = 1
            for ax in cand:
                sz *= mesh.shape[ax]
            if cand and W % sz == 0:
                w_axes += cand
        if h_ax is None and W % (msz * max(1, math_prod(mesh, w_axes))) == 0:
            w_axes.append("model")
        w = tuple(w_axes) if len(w_axes) > 1 else (w_axes[0] if w_axes else None)
        return P(*(lead + (b_ax, w, h_ax, None)))

    def pos_spec(shape, lead, sibling_kv_shape):
        return P(*(lead + (None,) * (len(shape) - len(lead))))

    def leaf(path, shape):
        lead = (None,) if ("stack" in path or "enc_kv" in path) else ()
        name = path[-1]
        core = shape[len(lead):]
        if name in ("k", "v") and ("kv" in path or "enc_kv" in path) \
                and len(core) >= 4:
            return kv_spec(shape, lead)
        if name == "pos":
            if len(core) == 2:  # per-row (B, W) ring position map: shard
                # batch with the sibling k/v, replicate the ring axis
                return P(*(lead + (b_ax, None)))
            return P(*([None] * len(shape)))
        # recurrent states: shard trailing feature axis on model if divisible
        if name in ("h", "c", "n", "m", "C", "conv", "rec"):
            entries = [b_ax] + [None] * (len(core) - 1)
            if len(core) >= 2 and core[-1] % msz == 0:
                entries[-1] = "model"
            return P(*(lead + tuple(entries)))
        entries = [b_ax] + [None] * (len(core) - 1)
        return P(*(lead + tuple(entries)))

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, path + (str(i),))
                              for i, v in enumerate(tree))
        return leaf(path, tree.shape)

    return walk(state_shapes, ())


def math_prod(mesh, axes):
    out = 1
    for ax in axes:
        out *= mesh.shape[ax]
    return out


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )
