"""Tiny Mixtral-family MoE used for trainable experiments on CPU.

Same block structure as Mixtral (SWA attention + top-2 of 8 experts) at a
size that trains in minutes on this host.  Used by the Fig-2 / Table-1 /
Table-2 reproduction benchmarks and the 100M-scale example driver.
"""
from repro.configs.base import ModelConfig, MoESpec, OffloadSpec

CONFIG = ModelConfig(
    name="tiny-moe",
    arch_type="moe",
    n_layers=6,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,  # byte-level + specials
    block_pattern=("swa+moe",),
    sliding_window=256,
    moe=MoESpec(num_experts=8, top_k=2, aux_loss_weight=0.02),
    offload=OffloadSpec(cache_size=2, num_speculative=2, lookahead=1,
                        expert_bits=3, attn_bits=4),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="float32",
    citation="in-repo trainable proxy for arXiv:2401.04088",
)
