"""Model configuration system.

Every assigned architecture gets one ``configs/<id>.py`` exporting ``CONFIG``
(a :class:`ModelConfig` at the exact published size) plus the registry in
``configs/__init__.py``.  ``ModelConfig.reduced()`` yields the CPU-smoke
variant (2 layers, d_model<=512, <=4 experts, tiny vocab) required by the
per-arch smoke tests; the full config is only ever *lowered* (dry-run), never
allocated on this host.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kind strings used in ``block_pattern``.  A block is "<mixer>+<ffn>".
#   mixers: attn (global causal), swa (sliding-window causal), xattn (self+cross,
#           enc-dec decoder), encattn (bidirectional, encoder), rglru (Griffin
#           recurrent block), mlstm, slstm
#   ffns:   mlp (dense SwiGLU/GeLU), moe (top-k routed experts), none
MIXERS = ("attn", "swa", "xattn", "encattn", "rglru", "mlstm", "slstm")
FFNS = ("mlp", "moe", "none")

# Sequence-state family each mixer carries during decode (DESIGN.md §12).
#   kv  — per-position K/V entries that GROW with context (ring or paged);
#   rec — fixed-size recurrent state: the degenerate "one page per slot"
#         case (no growth, no paging, O(1) truncate);
#   (xattn additionally READS the shared encoder KV, but that plane is
#   computed once at admission and never scattered to — it is a property
#   of the whole config, ``is_encoder_decoder``, not of one layer.)
MIXER_STATE = {"attn": "kv", "swa": "kv", "xattn": "kv",
               "encattn": "none", "rglru": "rec", "mlstm": "rec",
               "slstm": "rec"}


def parse_block(kind: str) -> Tuple[str, str]:
    mixer, _, ffn = kind.partition("+")
    ffn = ffn or "none"
    if mixer not in MIXERS:
        raise ValueError(f"unknown mixer {mixer!r} in block kind {kind!r}")
    if ffn not in FFNS:
        raise ValueError(f"unknown ffn {ffn!r} in block kind {kind!r}")
    return mixer, ffn


@dataclass(frozen=True)
class StatePlaneSpec:
    """What sequence state ONE layer carries during decode (DESIGN.md §12).

    ``plane``: "kv" (growing per-position K/V — ring or paged), "rec"
    (fixed-size recurrent state, the degenerate one-page-per-slot case)
    or "none" (encoder-only mixers; no decode-time state).  ``grows``
    marks planes whose footprint scales with live context — the only
    ones a :class:`~repro.serving.kv_manager.PagePool` should ever hold
    pages for.
    """

    kind: str
    mixer: str
    plane: str
    grows: bool
    window: Optional[int] = None


@dataclass(frozen=True)
class MoESpec:
    """Sparse mixture-of-experts FFN spec (token-level top-k routing)."""

    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # router jitter / z-loss left at 0 for inference-focused repro
    router_z_weight: float = 0.0


@dataclass(frozen=True)
class OffloadSpec:
    """Paper (Eliseev & Mazur 2023) offloading configuration.

    ``cache_size`` is the per-layer LRU size k (paper: k=2 for 12GB GPUs,
    k=4 for 16GB).  ``num_speculative`` is how many experts the speculative
    prefetcher stages (paper: 1-2).  ``lookahead`` is how many layers ahead
    the gate guess is made (paper evaluates 1, 2, 10; system uses 1).
    """

    cache_size: int = 2
    num_speculative: int = 2
    lookahead: int = 1
    expert_bits: int = 3     # mixed quantization: experts at 2-3 bit
    attn_bits: int = 4       # shared/attention layers at 4 bit
    staging_buffers: int = 4  # paper's b=4 on-device copy buffers


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    block_pattern: Tuple[str, ...] = ("attn+mlp",)
    moe: Optional[MoESpec] = None
    offload: Optional[OffloadSpec] = None
    sliding_window: Optional[int] = None
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_act: str = "swiglu"  # swiglu | gelu | geglu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # stablelm-2 uses partial rotary (0.25)
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None
    # --- encoder-decoder (whisper): encoder stack + stub frontend length ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # frontend output frames (whisper-medium: 1500)
    # --- vlm stub: number of image-patch embedding positions at seq start ---
    num_image_tokens: int = 0
    # --- recurrent (griffin / xlstm) ---
    rglru_conv_width: int = 4
    mlstm_chunk: int = 256
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # sequence-parallel activations (Megatron-style): the residual stream
    # between blocks is sharded over ("model", seq) so the remat residual
    # stack shards too — required for the 104B train config to fit HBM.
    act_seq_shard: bool = False
    # MoE dispatch groups (= batch shards on the production mesh): tokens
    # dispatch locally per group with per-group capacity, the real-EP
    # semantics; 1 = single global dispatch (CPU tests).
    moe_dispatch_groups: int = 1
    citation: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        for k in self.block_pattern:
            parse_block(k)

    @property
    def padded_vocab(self) -> int:
        """Embedding/unembedding tables pad the vocab to a multiple of 128
        so the (huge, f32) logits can shard on the model axis (whisper's
        51865 and granite's 49155 otherwise force replicated logits —
        +20GB/chip at train_4k).  The pad region is masked to -inf in
        ``unembed``; real token ids are never affected."""
        return -(-self.vocab_size // 128) * 128

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.pattern_period

    @property
    def n_tail_layers(self) -> int:
        """Layers not covered by full pattern periods (applied unscanned)."""
        return self.n_layers - self.n_periods * self.pattern_period

    def tail_kinds(self) -> Tuple[str, ...]:
        return self.block_pattern[: self.n_tail_layers]

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind of every layer, in order."""
        kinds = []
        for i in range(self.n_layers):
            kinds.append(self.block_pattern[i % self.pattern_period])
        return tuple(kinds)

    @property
    def uses_attention(self) -> bool:
        return any(parse_block(k)[0] in ("attn", "swa", "xattn") for k in self.block_pattern)

    # ------------------------------------------------------------------
    # Per-layer sequence-state descriptor (DESIGN.md §12).  The serving
    # runtime (Executor / StateManager / ContinuousEngine) keys every
    # state-plane decision off this, never off arch_type.
    def state_planes(self) -> Tuple["StatePlaneSpec", ...]:
        """One :class:`StatePlaneSpec` per layer, in layer order."""
        out = []
        for kind in self.layer_kinds():
            mixer = parse_block(kind)[0]
            plane = MIXER_STATE[mixer]
            out.append(StatePlaneSpec(
                kind=kind, mixer=mixer, plane=plane,
                grows=(plane == "kv"),
                window=(self.sliding_window if mixer == "swa" else None)))
        return tuple(out)

    @property
    def has_kv_layers(self) -> bool:
        """Any layer carries a growing per-position KV plane — only then
        do slot rings / page-pool reservations hold real positions.  A
        pure-recurrent stack (xlstm) reserves ZERO pages per request."""
        return any(sp.plane == "kv" for sp in self.state_planes())

    @property
    def has_recurrent_layers(self) -> bool:
        return any(sp.plane == "rec" for sp in self.state_planes())

    @property
    def attention_only_stack(self) -> bool:
        """All mixers are causal self-attention (attn/swa) — the stacks
        that support left-pad isolation and slotted continuous batching
        (recurrent mixers accumulate state over pads; enc-dec adds a
        second KV family)."""
        return (not self.is_encoder_decoder and
                all(parse_block(k)[0] in ("attn", "swa")
                    for k in self.block_pattern))

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def moe_layer_count(self) -> int:
        return sum(1 for k in self.layer_kinds() if parse_block(k)[1] == "moe")

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_sliding_window(self, window: int = 4096) -> "ModelConfig":
        """SWA variant used for long_500k on otherwise-full-attention archs."""
        pattern = tuple(
            k.replace("attn+", "swa+") if k.startswith("attn+") else k
            for k in self.block_pattern
        )
        return self.replace(block_pattern=pattern, sliding_window=window,
                            name=self.name + "-swa")

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family, tiny dims.

        2 layers worth of pattern (>=1 full period), d_model<=512, <=4
        experts, vocab 512.  Keeps mixer/ffn kinds, GQA ratio, biases, act.
        """
        period = self.pattern_period
        n_layers = period if period >= 2 else 2
        d_model = min(self.d_model, 256)
        # preserve head structure at reduced width
        n_heads = min(self.n_heads, 4)
        ratio = max(1, self.n_heads // self.n_kv_heads)
        n_kv = max(1, n_heads // ratio)
        head_dim = max(8, d_model // n_heads)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                top_k=min(2, self.moe.top_k))
        return self.replace(
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=512,
            moe=moe,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 24) if self.encoder_seq else 0,
            num_image_tokens=min(self.num_image_tokens, 8) if self.num_image_tokens else 0,
            mlstm_chunk=16,
            rglru_conv_width=self.rglru_conv_width,
            dtype="float32",
        )


# ----------------------------------------------------------------------
# Input shapes assigned to this paper (see system brief).
@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches init_model exactly; unit-tested)."""
    from repro.models.transformer import count_params_analytic

    return count_params_analytic(cfg)
