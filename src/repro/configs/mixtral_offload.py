"""Paper-flagship deployment config: Mixtral-8x7B + mixed quantization +
LRU/speculative expert offloading (Eliseev & Mazur 2023, section 3.3).

Identical architecture to ``mixtral-8x7b``; the offload spec selects the
paper's 16GB-GPU operating point (k=4, 2 speculative loads, experts 2-bit,
attention 4-bit — the green Table-1 row with 17.54 GB model size).
"""
from repro.configs.base import OffloadSpec
from repro.configs.mixtral_8x7b import CONFIG as _MIXTRAL

CONFIG = _MIXTRAL.replace(
    name="mixtral-offload",
    offload=OffloadSpec(cache_size=4, num_speculative=2, lookahead=1,
                        expert_bits=2, attn_bits=4),
)
