"""Whisper-medium — encoder-decoder audio backbone.  [arXiv:2212.04356]

24 encoder + 24 decoder layers, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=51865, GeLU MLP, LayerNorm, learned/sinusoidal positions (we use RoPE
on decoder self-attn as the repo-standard positional scheme; noted in
DESIGN.md).  The mel-spectrogram + conv frontend is a STUB: ``input_specs``
provides 1500 precomputed frame embeddings per the carve-out.

long_500k is skipped for this arch (enc-dec, bounded decoder) — DESIGN.md §5.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,  # decoder layers; encoder_layers adds the encoder stack
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    block_pattern=("xattn+mlp",),
    encoder_layers=24,
    encoder_seq=1500,
    mlp_act="gelu",
    norm="layernorm",
    qkv_bias=True,
    citation="arXiv:2212.04356 (Whisper)",
)
