"""xLSTM-1.3B — sLSTM + mLSTM recurrent blocks.  [arXiv:2405.04517]

48L, d_model=2048, 4 heads (head_dim 512), d_ff=0 (xLSTM blocks carry their
own projections), vocab=50304.  Pattern: 5 mLSTM blocks then 1 sLSTM block
per period (8 periods), approximating the paper's sparse sLSTM placement.
Attention-free: sub-quadratic by construction (long_500k native).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm+none",) * 5 + ("slstm+none",),
    norm="rmsnorm",
    mlstm_chunk=256,
    citation="arXiv:2405.04517 (xLSTM)",
)
