"""RecurrentGemma-9B — Griffin hybrid: RG-LRU recurrent blocks + local
(sliding-window) attention in a 2:1 pattern.  [arXiv:2402.19427]

38L, d_model=4096, 16 heads (MQA kv=1), d_ff=12288 (GeGLU), vocab=256000,
local attention window 2048, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    n_layers=38,  # 12 full (rglru, rglru, swa) periods + 2 tail rglru layers
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru+mlp", "rglru+mlp", "swa+mlp"),
    sliding_window=2048,
    mlp_act="geglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    logit_softcap=30.0,
    citation="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)
