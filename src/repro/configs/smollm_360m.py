"""SmolLM-360M — llama-style small dense decoder.

[hf:HuggingFaceTB/SmolLM-360M (family card hf:HuggingFaceTB/SmolLM-135M)]
32L, d_model=960, 15 heads (GQA kv=5), d_ff=2560, vocab=49152, tied embeds.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    block_pattern=("attn+mlp",),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    citation="hf:HuggingFaceTB/SmolLM-135M",
)
