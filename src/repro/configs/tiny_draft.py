"""Tiny dense draft model for token-level speculative decoding.

Shares the tiny-moe tokenizer/vocab (byte-level, 512 entries) but is a
plain dense transformer at a fraction of the size: the draft proposes
greedy continuations that the expensive offloaded MoE target verifies in
one packed C=k chunk (DESIGN.md §11).  Dense on purpose — a draft with
its own expert streaming would compete with the target for the h2d bus,
which is exactly the resource speculation is trying to amortize.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tiny-draft",
    arch_type="dense",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,  # MUST match tiny-moe (draft/target share tokens)
    block_pattern=("attn+mlp",),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="float32",
    citation="in-repo draft proxy for arXiv:2312.17238 token speculation",
)
