"""Qwen1.5-4B — dense decoder with QKV bias, MHA.

[hf:Qwen/Qwen1.5-0.5B (family card; 4B dims per brief)]
40L, d_model=2560, 20 heads (kv=20), d_ff=6912, vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    block_pattern=("attn+mlp",),
    qkv_bias=True,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=5000000.0,
    citation="hf:Qwen/Qwen1.5-0.5B",
)
