"""Granite-3.0 1B-A400M — fine-grained MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base]
24L, d_model=1024, 16 heads (GQA kv=8), expert d_ff=512, vocab=49155,
MoE 32 experts top-8.  Full paper-technique target (offload spec attached).
"""
from repro.configs.base import ModelConfig, MoESpec, OffloadSpec

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    block_pattern=("attn+moe",),
    moe=MoESpec(num_experts=32, top_k=8),
    offload=OffloadSpec(cache_size=8, num_speculative=4, expert_bits=3),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
