"""Command R+ (104B) — large dense decoder, GQA, no biases.

[hf:CohereForAI/c4ai-command-r-v01 (family card; plus-size dims per brief)]
64L, d_model=12288, 96 heads (GQA kv=8), d_ff=33792, vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    arch_type="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    block_pattern=("attn+mlp",),
    mlp_act="swiglu",
    norm="layernorm",
    rope_theta=75000000.0,
    tie_embeddings=True,
    citation="hf:CohereForAI/c4ai-command-r-v01",
)
