"""Mixtral-8x7B — the paper's own model.  [arXiv:2401.04088]

32L, d_model=4096, 32 heads (GQA kv=8), expert d_ff=14336, vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096).

This is the flagship config for the reproduced offloading technique: the
attached ``OffloadSpec`` mirrors the paper's chosen deployment (k=4 LRU
slots on 16GB GPUs / k=2 on 12GB, 1-2 speculative loads, experts at 2-3
bit + attention at 4 bit HQQ).
"""
from repro.configs.base import ModelConfig, MoESpec, OffloadSpec

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("swa+moe",),
    sliding_window=4096,
    moe=MoESpec(num_experts=8, top_k=2),
    offload=OffloadSpec(cache_size=4, num_speculative=2, lookahead=1,
                        expert_bits=3, attn_bits=4),
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    citation="arXiv:2401.04088 (Mixtral of Experts)",
)
