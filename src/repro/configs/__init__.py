"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

Every assigned architecture (plus the paper-flagship ``mixtral-offload``)
is registered here and selectable via ``--arch <id>`` in the launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exports)
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoESpec,
    OffloadSpec,
    parse_block,
)

_MODULES = {
    "smollm-360m": "repro.configs.smollm_360m",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "whisper-medium": "repro.configs.whisper_medium",
    "phi-3-vision-4.2b": "repro.configs.phi_3_vision_4_2b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "mixtral-offload": "repro.configs.mixtral_offload",
    "tiny-moe": "repro.configs.tiny_moe",
    "tiny-draft": "repro.configs.tiny_draft",
}

ASSIGNED_ARCHS: List[str] = [
    "smollm-360m",
    "recurrentgemma-9b",
    "command-r-plus-104b",
    "granite-moe-1b-a400m",
    "stablelm-1.6b",
    "whisper-medium",
    "phi-3-vision-4.2b",
    "mixtral-8x7b",
    "xlstm-1.3b",
    "qwen1.5-4b",
]

_CACHE: Dict[str, ModelConfig] = {}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULES)}")
    if name not in _CACHE:
        mod = importlib.import_module(_MODULES[name])
        _CACHE[name] = mod.CONFIG
    return _CACHE[name]


def list_archs(assigned_only: bool = False) -> List[str]:
    return list(ASSIGNED_ARCHS) if assigned_only else sorted(_MODULES)


# (arch, shape) combinations that are skipped, with the reason recorded in
# DESIGN.md §5.  Everything else must lower+compile in the dry-run.
SKIPS = {
    ("whisper-medium", "long_500k"):
        "encoder-decoder with architecturally bounded decoder context; "
        "no sub-quadratic decoder variant exists for this family "
        "(DESIGN.md section 5).",
}

# Dense full-attention archs run long_500k via their sliding-window variant
# (sub-quadratic requirement; DESIGN.md section 5).
SWA_FOR_LONG = {
    "smollm-360m",
    "command-r-plus-104b",
    "stablelm-1.6b",
    "qwen1.5-4b",
    "phi-3-vision-4.2b",
}


def config_for_shape(arch: str, shape_name: str) -> ModelConfig:
    """Config actually used for a given input shape (applies SWA variant)."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch in SWA_FOR_LONG:
        cfg = cfg.with_sliding_window(4096)
    return cfg
