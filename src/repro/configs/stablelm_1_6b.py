"""StableLM-2 1.6B — dense decoder, MHA, partial rotary (25%), LayerNorm.

[hf:stabilityai/stablelm-2-1_6b]
24L, d_model=2048, 32 heads (kv=32), d_ff=5632, vocab=100352.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    block_pattern=("attn+mlp",),
    qkv_bias=False,
    mlp_act="swiglu",
    norm="layernorm",
    rope_theta=10000.0,
    rope_fraction=0.25,
    citation="hf:stabilityai/stablelm-2-1_6b",
)
