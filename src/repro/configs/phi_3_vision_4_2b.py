"""Phi-3-vision 4.2B — phi3-mini LM backbone + CLIP vision stub.

[hf:microsoft/Phi-3-vision-128k-instruct]
32L, d_model=3072, 32 heads (kv=32), d_ff=8192, vocab=32064.  The vision
tower (CLIP ViT-L + projector) is a STUB: ``input_specs`` provides
precomputed patch embeddings (num_image_tokens positions at sequence start).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    block_pattern=("attn+mlp",),
    num_image_tokens=576,  # one CLIP-L 336px tile worth of patches
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
)
