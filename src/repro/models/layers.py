"""Core transformer layers: norms, RoPE, GQA attention (global / sliding
window / cross), MLPs, embeddings.

Conventions
-----------
* Parameters are plain dicts of ``jnp`` arrays; every ``init_*`` has a
  matching ``*_train`` (full-sequence) and ``*_decode`` (single-step with
  cache) apply function.
* Attention weights keep explicit head axes — ``wq: (D, H, hd)`` — so the
  sharding rules in :mod:`repro.sharding.specs` can target head axes
  directly.
* Softmax / norms / rotary run in float32 regardless of param dtype.
* The training/prefill attention is **query-chunked** (exact, not an
  approximation): scores are materialised ``q_chunk`` query rows at a time
  inside a ``lax.scan``, bounding activation memory at
  ``B*H*q_chunk*S`` instead of ``B*H*S*S``.  This is what lets the 104B
  config's 32k prefill fit per-device HBM in the dry-run.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ragged_attention as RA
from repro.sharding.specs import constrain

DEFAULT_Q_CHUNK = 512
NEG_INF = -1e30


# ----------------------------------------------------------------------
# Norms
def init_norm(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), _pdt(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _pdt(cfg))
    return p


def apply_norm(p, cfg, x):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm" and "bias" in p:
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------------
# Rotary position embeddings (partial-fraction aware, stablelm-style)
def rope_frequencies(cfg):
    rot = int(cfg.head_dim * cfg.rope_fraction)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, cfg):
    """x: (..., S, H, hd) or (..., 1, H, hd); positions: (S,) int32 shared
    across the batch, or (B, S) per-row (padded / continuous batching)."""
    inv, rot = rope_frequencies(cfg)
    if rot == 0:
        return x
    ang = positions.astype(jnp.float32)[..., :, None] * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr, xp = x[..., :rot], x[..., rot:]
    xf = xr.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    # broadcast (..., S, rot/2) -> (..., S, 1, rot/2) over the head axis
    c = jnp.expand_dims(cos, -2)
    s = jnp.expand_dims(sin, -2)
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    yr = jnp.stack([y1, y2], axis=-1).reshape(xf.shape).astype(x.dtype)
    return jnp.concatenate([yr, xp], axis=-1) if rot < x.shape[-1] else yr


# ----------------------------------------------------------------------
# Attention
def init_attention(rng, cfg, *, cross=False):
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sc = 1.0 / math.sqrt(D)
    dt = _pdt(cfg)
    p = {
        "wq": (jax.random.normal(k1, (D, H, hd)) * sc).astype(dt),
        "wk": (jax.random.normal(k2, (D, Hkv, hd)) * sc).astype(dt),
        "wv": (jax.random.normal(k3, (D, Hkv, hd)) * sc).astype(dt),
        "wo": (jax.random.normal(k4, (H, hd, D)) * sc / math.sqrt(2 * cfg.n_layers)).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((Hkv, hd), dt)
        p["bv"] = jnp.zeros((Hkv, hd), dt)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((D,), dt)
    return p


def _project_q(p, cfg, x):
    # 2-D dot (Megatron layout): the (D, H, hd) einsum makes the SPMD
    # partitioner gather the weight over BOTH mesh axes (full
    # f32[12288,96,128] per chip per layer on the 104B config — §Perf
    # iteration 3); reshaping to (D, H*hd) keeps the head axis sharded.
    w = p["wq"]
    q = jnp.dot(x, w.reshape(w.shape[0], -1)).reshape(
        x.shape[:-1] + w.shape[1:])
    if "bq" in p:
        q = q + p["bq"]
    return q


def _project_kv(p, cfg, x):
    wk, wv = p["wk"], p["wv"]
    k = jnp.dot(x, wk.reshape(wk.shape[0], -1)).reshape(
        x.shape[:-1] + wk.shape[1:])
    v = jnp.dot(x, wv.reshape(wv.shape[0], -1)).reshape(
        x.shape[:-1] + wv.shape[1:])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def _out_proj(p, cfg, o):
    w = p["wo"]  # (H, hd, D)
    y = jnp.dot(o.reshape(o.shape[:-2] + (-1,)),
                w.reshape(-1, w.shape[-1]))
    if "bo" in p:
        y = y + p["bo"]
    return y


def attention_core(q, k, v, qpos, kpos, *, causal, window, q_chunk=DEFAULT_Q_CHUNK):
    """Exact query-chunked GQA attention.

    q: (B, Sq, H, hd)  k, v: (B, Skv, Hkv, hd)
    qpos: (Sq,) or (B, Sq) int32 absolute positions; kpos: (Skv,) or
    (B, Skv) int32 (−1 = invalid slot, used by the rolling decode cache
    and by padded / per-slot batches where rows sit at different
    positions).
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scale = 1.0 / math.sqrt(hd)
    qpos2 = jnp.broadcast_to(qpos, (B, Sq)) if qpos.ndim == 1 else qpos
    kpos2 = (jnp.broadcast_to(kpos, (B, k.shape[1]))
             if kpos.ndim == 1 else kpos)

    q_chunk = min(q_chunk, Sq)
    if Sq % q_chunk:
        q_chunk = Sq  # smoke shapes
    n_chunks = Sq // q_chunk

    def chunk_fn(carry, idx):
        start = idx * q_chunk
        qc = jax.lax.dynamic_slice_in_dim(qg, start, q_chunk, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(qpos2, start, q_chunk, axis=1)
        s = jnp.einsum("bqhgk,bthk->bhgqt", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        valid = (kpos2 >= 0)[:, None, :]  # (B, 1, Skv)
        if causal:
            valid = valid & (kpos2[:, None, :] <= qp[:, :, None])
        if window is not None:
            valid = valid & ((qp[:, :, None] - kpos2[:, None, :]) < window)
        s = jnp.where(valid[:, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)  # bf16 AV matmul
        oc = jnp.einsum("bhgqt,bthk->bqhgk", w, v)
        return carry, oc

    if n_chunks == 1:
        _, o = chunk_fn(None, jnp.int32(0))
    else:
        # flash-attention-style memory behaviour under autodiff: recompute
        # each chunk's scores in the backward instead of storing them all
        body = jax.checkpoint(chunk_fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
        _, o = jax.lax.scan(body, None, jnp.arange(n_chunks))
        o = jnp.moveaxis(o, 0, 1).reshape(B, Sq, Hkv, G, hd)
    return o.reshape(B, Sq, H, hd)


def attention_train(p, cfg, x, positions, *, window=None, causal=True,
                    kv_override=None, kv_positions=None, pad_mask=None):
    """Full-sequence attention.  ``kv_override`` (enc output) => cross-attn.

    ``pad_mask``: optional (B, S) bool, True at real tokens.  Pad
    positions are excluded from every key/value set (their own queries
    produce garbage that callers must ignore — pad rows never feed real
    outputs because their cache slots carry pos = −1).
    """
    q = _project_q(p, cfg, x)
    if kv_override is None:
        k, v = _project_kv(p, cfg, x)
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
        kpos = positions
        if pad_mask is not None:
            kpos = jnp.where(pad_mask,
                             jnp.broadcast_to(positions, pad_mask.shape), -1)
    else:
        k, v = _project_kv(p, cfg, kv_override)
        kpos = kv_positions
        causal = False
        window = None
    # Megatron-SP layout: full-seq, head-sharded QKV.  Without this, a
    # seq-sharded residual stream leaves q seq-sharded and the chunk loop
    # re-all-gathers it PER CHUNK (2x1.5TB/step on the 104B train config
    # — §Perf iteration 1).
    q = constrain(q, ("pod", "data"), None, "model", None)
    k = constrain(k, ("pod", "data"), None, "model", None)
    v = constrain(v, ("pod", "data"), None, "model", None)
    o = attention_core(q, k, v, positions, kpos, causal=causal, window=window)
    return _out_proj(p, cfg, o)


def init_attn_cache(cfg, batch, max_len, window=None):
    # ``pos`` is per-row so batch rows can sit at different absolute
    # positions (continuous batching / padded prefill); −1 = empty slot.
    W = min(max_len, window) if window else max_len
    dt = _pdt(cfg)
    return {
        "k": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim), dt),
        "pos": jnp.full((batch, W), -1, jnp.int32),
    }


def init_paged_attn_cache(cfg, n_pages, page_size):
    """Paged KV plane of one layer (DESIGN.md §9): a batch-free pool of
    ``n_pages`` fixed-size pages shared by every slot; which pages a row
    owns lives in the state-level page table (``state["pages"]``), not
    here.  ``ppos`` carries each written entry's absolute position
    (−1 = never written / scrubbed) — the same validity convention as
    the ring cache's ``pos``."""
    dt = _pdt(cfg)
    return {
        "kp": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                        dt),
        "vp": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, cfg.head_dim),
                        dt),
        "ppos": jnp.full((n_pages, page_size), -1, jnp.int32),
    }


def _attention_decode_paged(p, cfg, x_t, cache, cur_pos, pages, *,
                            window=None, active=None, layer=None):
    """Decode / chunk step against the paged KV plane (DESIGN.md §9).

    x_t: (B, C, D); pages: (B, T) page-table rows mapping position
    ``pos`` to page ``pages[b, pos // ps]`` offset ``pos % ps``.  Writes
    scatter into the shared pool; rows whose table slot is unallocated
    (or masked off by ``active`` — idle / mid-admission slots) write
    nowhere (``mode="drop"``), so a dummy decode can never corrupt a
    page another row owns or a chunked admission is mid-filling.
    Attention reads through :mod:`repro.kernels.ragged_attention` — the
    gathered view is bitwise the ring layout at matched width.

    ``layer``: with the layer-STACKED pool (kp (L, P, ps, Hkv, hd) —
    how the scanned decode step carries it), the layer index is folded
    into the scatter/gather indices so the pool is never sliced out of
    the scan carry: XLA keeps the (donated) pool in place and per-step
    cost tracks live pages, not pool capacity."""
    B, C = x_t.shape[0], x_t.shape[1]
    P, ps = cache["ppos"].shape[-2:]
    T = pages.shape[1]
    per_row = getattr(cur_pos, "ndim", 0) == 1
    pos_b = (cur_pos if per_row
             else jnp.broadcast_to(jnp.asarray(cur_pos, jnp.int32), (B,)))
    posq = pos_b[:, None] + jnp.arange(C, dtype=jnp.int32)  # (B, C)
    q = _project_q(p, cfg, x_t)
    k_new, v_new = _project_kv(p, cfg, x_t)
    q = apply_rope(q, posq, cfg)
    k_new = apply_rope(k_new, posq, cfg)
    ords = posq // ps
    off = posq % ps
    pid = jnp.take_along_axis(pages, jnp.clip(ords, 0, T - 1), axis=1)
    ok = (pid >= 0) & (ords < T)
    if active is not None:
        ok = ok & active[:, None]
    tgt = jnp.where(ok, pid, P)  # P is out of bounds -> write dropped
    if layer is None:
        cache = {
            "kp": cache["kp"].at[tgt, off].set(k_new, mode="drop"),
            "vp": cache["vp"].at[tgt, off].set(v_new, mode="drop"),
            "ppos": cache["ppos"].at[tgt, off].set(posq, mode="drop"),
        }
    else:
        cache = {
            "kp": cache["kp"].at[layer, tgt, off].set(k_new, mode="drop"),
            "vp": cache["vp"].at[layer, tgt, off].set(v_new, mode="drop"),
            "ppos": cache["ppos"].at[layer, tgt, off].set(posq,
                                                          mode="drop"),
        }
    o = RA.ragged_attention(q, cache["kp"], cache["vp"], cache["ppos"],
                            pages, posq, window=window, q_chunk=C,
                            layer=layer)
    return _out_proj(p, cfg, o), cache


def attention_decode(p, cfg, x_t, cache, cur_pos, *, window=None,
                     pages=None, active=None, layer=None):
    """Decode / chunked-prefill step with a (possibly rolling) KV cache.

    x_t: (B, C, D) — C = 1 is the classic one-token decode step; C > 1
    is a *prefill chunk*: the C tokens sit at consecutive positions
    ``cur_pos .. cur_pos+C-1``, their K/V are written into the ring at
    those slots (arbitrary offsets — the chunked-prefill KV protocol,
    DESIGN.md §8), and causal masking inside :func:`attention_core`
    keeps intra-chunk attention exact.  Requires C <= cache width.

    cur_pos: scalar int32 absolute start position (whole batch in
    lock-step) or (B,) int32 per-row positions (continuous batching).

    ``pages`` switches to the paged KV plane (DESIGN.md §9): ``cache``
    is then the pooled :func:`init_paged_attn_cache` layout and
    ``active`` (B,) bool gates which rows may write (idle slots write
    nowhere instead of into their own ring row).  Dense ring mode
    ignores ``active`` — a free slot's writes stay row-local there.
    """
    if pages is not None:
        return _attention_decode_paged(p, cfg, x_t, cache, cur_pos, pages,
                                       window=window, active=active,
                                       layer=layer)
    B, C = x_t.shape[0], x_t.shape[1]
    W = cache["k"].shape[1]
    assert C <= W, f"chunk of {C} tokens exceeds KV width {W}"
    per_row = getattr(cur_pos, "ndim", 0) == 1
    q = _project_q(p, cfg, x_t)
    k_new, v_new = _project_kv(p, cfg, x_t)
    if per_row:
        posq = cur_pos[:, None] + jnp.arange(C, dtype=jnp.int32)  # (B, C)
        q = apply_rope(q, posq, cfg)
        k_new = apply_rope(k_new, posq, cfg)
        if C == 1:
            slot = jnp.mod(cur_pos, W)
            bidx = jnp.arange(B)
            cache = {
                "k": cache["k"].at[bidx, slot].set(k_new[:, 0]),
                "v": cache["v"].at[bidx, slot].set(v_new[:, 0]),
                "pos": cache["pos"].at[bidx, slot].set(
                    cur_pos.astype(jnp.int32)),
            }
        else:
            slots = jnp.mod(posq, W)  # (B, C)
            bidx = jnp.arange(B)[:, None]
            cache = {
                "k": cache["k"].at[bidx, slots].set(k_new),
                "v": cache["v"].at[bidx, slots].set(v_new),
                "pos": cache["pos"].at[bidx, slots].set(
                    posq.astype(jnp.int32)),
            }
    else:
        posq = cur_pos + jnp.arange(C, dtype=jnp.int32)  # (C,)
        q = apply_rope(q, posq, cfg)
        k_new = apply_rope(k_new, posq, cfg)
        if C == 1:
            slot = jnp.mod(cur_pos, W)
            pos_col = jnp.broadcast_to(posq.astype(jnp.int32), (B, 1))
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k_new, slot, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v_new, slot, axis=1),
                "pos": jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], pos_col, slot, axis=1),
            }
        else:
            # per-slot scatter (mod W) instead of a contiguous dynamic
            # slice: chunk writes must wrap the ring like decode writes do
            slots = jnp.mod(posq, W)  # (C,)
            pos_row = jnp.broadcast_to(posq.astype(jnp.int32), (B, C))
            cache = {
                "k": cache["k"].at[:, slots].set(k_new),
                "v": cache["v"].at[:, slots].set(v_new),
                "pos": cache["pos"].at[:, slots].set(pos_row),
            }
    o = attention_core(q, cache["k"], cache["v"], posq, cache["pos"],
                       causal=True, window=window, q_chunk=C)
    return _out_proj(p, cfg, o), cache


def cross_attention_decode(p, cfg, x_t, enc_k, enc_v, enc_pos):
    q = _project_q(p, cfg, x_t)
    o = attention_core(q, enc_k, enc_v, jnp.zeros((1,), jnp.int32), enc_pos,
                       causal=False, window=None, q_chunk=1)
    return _out_proj(p, cfg, o)


def precompute_cross_kv(p, cfg, enc_out):
    return _project_kv(p, cfg, enc_out)


# ----------------------------------------------------------------------
# MLPs
def init_mlp(rng, cfg):
    D, F = cfg.d_model, cfg.d_ff
    dt = _pdt(cfg)
    sc_in = 1.0 / math.sqrt(D)
    sc_out = 1.0 / math.sqrt(F) / math.sqrt(2 * cfg.n_layers)
    if cfg.mlp_act in ("swiglu", "geglu"):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "w_gate": (jax.random.normal(k1, (D, F)) * sc_in).astype(dt),
            "w_up": (jax.random.normal(k2, (D, F)) * sc_in).astype(dt),
            "w_down": (jax.random.normal(k3, (F, D)) * sc_out).astype(dt),
        }
    k1, k2 = jax.random.split(rng, 2)
    return {
        "w_in": (jax.random.normal(k1, (D, F)) * sc_in).astype(dt),
        "w_out": (jax.random.normal(k2, (F, D)) * sc_out).astype(dt),
    }


def apply_mlp(p, cfg, x):
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
        h = constrain(h, ("pod", "data"), None, "model")
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, ("pod", "data"), None, "model")
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


# ----------------------------------------------------------------------
# Embeddings / unembedding
def init_embedding(rng, cfg):
    dt = _pdt(cfg)
    p = {"table": (jax.random.normal(rng, (cfg.padded_vocab, cfg.d_model))
                   * 1.0 / math.sqrt(cfg.d_model)).astype(dt)}
    return p


def embed(p, cfg, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(params, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"])
    logits = logits.astype(jnp.float32)
    # keep the (huge) vocab axis model-sharded; CE reduces it locally
    logits = constrain(logits, ("pod", "data"), None, "model")
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, NEG_INF, logits)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def init_lm_head(rng, cfg):
    dt = _pdt(cfg)
    return {"w": (jax.random.normal(rng, (cfg.d_model, cfg.padded_vocab))
                  * 1.0 / math.sqrt(cfg.d_model)).astype(dt)}
