"""Model assembly for every architecture family.

A model is a stack of *blocks*; each block = mixer (attention / SWA /
cross-attn / RG-LRU / mLSTM / sLSTM) + optional FFN (dense MLP or MoE),
pre-norm residual.  The stack is organised for ``lax.scan``:

* ``params["stack"][p]`` — parameters of pattern-position ``p``, stacked
  over the ``n_periods`` repetitions (leading axis), scanned at apply time;
* ``params["tail"]``     — remainder layers (n_layers % period), unscanned.

Apply modes
-----------
* :func:`forward_train`  — full-sequence teacher-forced logits (+MoE aux).
* :func:`prefill`        — same compute, additionally returns a filled
  decode state (KV caches / recurrent states).
* :func:`decode_step`    — one token with the decode state.

Decode-vs-train parity is the key invariant (tests/test_parity.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, parse_block
from repro.models import layers as L
from repro.models import moe as M
from repro.models import recurrent as R
from repro.sharding.specs import constrain

PyTree = Any


# ======================================================================
# Init
def _init_block(rng, cfg: ModelConfig, kind: str):
    mixer, ffn = parse_block(kind)
    ks = jax.random.split(rng, 4)
    p: Dict[str, PyTree] = {"norm1": L.init_norm(cfg)}
    if mixer in ("attn", "swa", "xattn", "encattn"):
        p["attn"] = L.init_attention(ks[0], cfg)
    elif mixer == "rglru":
        p["rglru"] = R.init_rglru(ks[0], cfg)
    elif mixer == "mlstm":
        p["mlstm"] = R.init_mlstm(ks[0], cfg)
    elif mixer == "slstm":
        p["slstm"] = R.init_slstm(ks[0], cfg)
    if mixer == "xattn":
        p["norm_x"] = L.init_norm(cfg)
        p["xattn"] = L.init_attention(ks[2], cfg, cross=True)
    if ffn == "mlp":
        p["norm2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif ffn == "moe":
        p["norm2"] = L.init_norm(cfg)
        p["moe"] = M.init_moe(ks[1], cfg)
    return p


def init_model(rng, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(rng, 8)
    params: Dict[str, PyTree] = {"embed": L.init_embedding(ks[0], cfg)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_lm_head(ks[1], cfg)
    params["final_norm"] = L.init_norm(cfg)

    period = cfg.pattern_period
    n_p = cfg.n_periods

    def stacked_init(kind, base_key):
        def one(k):
            return _init_block(k, cfg, kind)
        return jax.vmap(one)(jax.random.split(base_key, n_p))

    stack_keys = jax.random.split(ks[2], period)
    params["stack"] = [stacked_init(cfg.block_pattern[i], stack_keys[i])
                       for i in range(period)]
    tail_keys = jax.random.split(ks[3], max(1, cfg.n_tail_layers))
    params["tail"] = [_init_block(tail_keys[i], cfg, k)
                      for i, k in enumerate(cfg.tail_kinds())]

    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(block_pattern=("encattn+mlp",),
                              n_layers=cfg.encoder_layers)
        enc_keys = jax.random.split(ks[4], 1)[0]
        def enc_one(k):
            return _init_block(k, enc_cfg, "encattn+mlp")
        params["encoder"] = {
            "stack": [jax.vmap(enc_one)(jax.random.split(enc_keys, cfg.encoder_layers))],
            "final_norm": L.init_norm(cfg),
        }
    if cfg.num_image_tokens:
        params["img_proj"] = (jax.random.normal(ks[5], (cfg.d_model, cfg.d_model))
                              * (cfg.d_model ** -0.5)).astype(jnp.dtype(cfg.dtype))
    return params


def count_params_analytic(cfg: ModelConfig) -> int:
    import math as _math
    shapes = jax.eval_shape(lambda: init_model(jax.random.key(0), cfg))
    return sum(_math.prod(l.shape) for l in jax.tree.leaves(shapes))


# ======================================================================
# Block apply (train / prefill)
def _block_train(p, cfg: ModelConfig, kind: str, x, positions, *,
                 want_state: bool, enc_out=None, enc_pos=None,
                 batch_for_state: int = 0, max_len: int = 0, pad_mask=None,
                 moe_ffn_fn=None):
    """Returns (x, state_or_None, aux).

    ``positions`` is (S,) shared or (B, S) per-row; ``pad_mask`` (B, S)
    marks real tokens (attention mixers only — recurrent mixers process
    pads and callers must not left-pad recurrent archs).  ``moe_ffn_fn``
    overrides the MoE expert computation (packed-offload prefill streams
    experts from the host store this way — DESIGN.md §6).
    """
    mixer, ffn = parse_block(kind)
    aux = {}
    state = {}

    def seq_shard(t):
        # Megatron-SP: keep residual adds sequence-sharded so the backward
        # of TP output projections reduce-scatters instead of all-reducing
        # (§Perf iteration 2 on the 104B train config)
        if cfg.act_seq_shard:
            return constrain(t, ("pod", "data"), "model", None)
        return t

    h = L.apply_norm(p["norm1"], cfg, x)
    if mixer in ("attn", "swa", "encattn"):
        window = cfg.sliding_window if mixer == "swa" else None
        causal = mixer != "encattn"
        if want_state and causal:
            # compute and also fill the rolling KV cache for decode
            y, kvstate = _attn_train_with_cache(p["attn"], cfg, h, positions,
                                                window, max_len,
                                                pad_mask=pad_mask)
            state["kv"] = kvstate
        else:
            y = L.attention_train(p["attn"], cfg, h, positions,
                                  window=window, causal=causal,
                                  pad_mask=pad_mask)
    elif mixer == "xattn":
        window = None
        if want_state:
            y, kvstate = _attn_train_with_cache(p["attn"], cfg, h, positions,
                                                None, max_len,
                                                pad_mask=pad_mask)
            state["kv"] = kvstate
        else:
            y = L.attention_train(p["attn"], cfg, h, positions, window=None,
                                  pad_mask=pad_mask)
    elif mixer == "rglru":
        y, st = R.rglru_train(p["rglru"], cfg, h)
        if want_state:
            state["rec"] = st
    elif mixer == "mlstm":
        y, st = R.mlstm_train(p["mlstm"], cfg, h)
        if want_state:
            state["rec"] = st
    elif mixer == "slstm":
        y, st = R.slstm_train(p["slstm"], cfg, h)
        if want_state:
            state["rec"] = st
    x = x + seq_shard(y)
    if mixer == "xattn":
        hx = L.apply_norm(p["norm_x"], cfg, x)
        y = L.attention_train(p["xattn"], cfg, hx, positions,
                              kv_override=enc_out, kv_positions=enc_pos)
        x = x + seq_shard(y)
    if ffn != "none":
        h2 = L.apply_norm(p["norm2"], cfg, x)
        if ffn == "mlp":
            x = x + seq_shard(L.apply_mlp(p["mlp"], cfg, h2))
        else:
            B, S, D = h2.shape
            y2d, moe_aux = M.moe_apply_dispatch(
                p["moe"], cfg, h2.reshape(B * S, D),
                token_mask=(pad_mask.reshape(B * S)
                            if pad_mask is not None else None),
                expert_ffn_fn=moe_ffn_fn)
            aux.update(moe_aux)
            x = x + seq_shard(y2d.reshape(B, S, D))
    return x, (state if want_state else None), aux


def _attn_train_with_cache(p, cfg, h, positions, window, max_len,
                           pad_mask=None):
    """Full-seq attention that also produces the decode KV cache.

    With a left-pad ``pad_mask``, pad entries carry pos = −1 and land in
    ring slots that real entries never occupy (real logical positions of
    a row with R real tokens fill slots 0..min(R,W)−1; pads only appear
    in the written tail when R < W, so the pads' slot mod(−1, W) = W−1
    is free).  Decode then naturally skips them via the pos >= 0 mask.
    """
    B, S, _ = h.shape
    y = L.attention_train(p, cfg, h, positions, window=window,
                          pad_mask=pad_mask)
    cache = L.init_attn_cache(cfg, B, max_len, window)
    W = cache["k"].shape[1]
    k_full, v_full = L._project_kv(p, cfg, h)
    k_full = L.apply_rope(k_full, positions, cfg)
    n = min(W, S)
    pos2 = jnp.broadcast_to(positions, (B, S)) if positions.ndim == 1 \
        else positions
    tail_pos = pos2[:, -n:]
    if pad_mask is not None:
        tail_pos = jnp.where(pad_mask[:, -n:], tail_pos, -1)
    slots = jnp.mod(tail_pos, W)  # (B, n); pos −1 (pads) -> slot W−1
    bidx = jnp.arange(B)[:, None]
    cache = {
        "k": cache["k"].at[bidx, slots].set(k_full[:, -n:]),
        "v": cache["v"].at[bidx, slots].set(v_full[:, -n:]),
        "pos": cache["pos"].at[bidx, slots].set(tail_pos.astype(jnp.int32)),
    }
    return y, cache


# ======================================================================
# Block decode (single token)
def _mixer_decode(p, cfg: ModelConfig, kind: str, x_t, state, pos, *,
                  enc_kv=None, pages=None, active=None, layer=None):
    """Mixer half of one block's decode step (norm1 + mixer + residual,
    plus the cross-attention sub-block for enc-dec decoders).  Shared by
    the scanned :func:`decode_step` and the layerwise packed-offload
    driver (:func:`decode_block_packed`) so both run the exact same
    non-MoE computation.  ``pages``/``active`` select the paged KV plane
    (DESIGN.md §9) — ignored by the dense ring caches; ``layer`` marks a
    layer-stacked paged cache addressed in place (scan-carry path)."""
    mixer, _ = parse_block(kind)
    h = L.apply_norm(p["norm1"], cfg, x_t)
    if mixer in ("attn", "swa", "xattn"):
        window = cfg.sliding_window if mixer == "swa" else None
        y, kv = L.attention_decode(p["attn"], cfg, h, state["kv"], pos,
                                   window=window, pages=pages,
                                   active=active, layer=layer)
        state = dict(state, kv=kv)
    elif mixer == "rglru":
        fn = R.rglru_decode if x_t.shape[1] == 1 else R.rglru_chunk
        y, rec = fn(p["rglru"], cfg, h, state["rec"])
        state = dict(state, rec=rec)
    elif mixer == "mlstm":
        fn = R.mlstm_decode if x_t.shape[1] == 1 else R.mlstm_chunk
        y, rec = fn(p["mlstm"], cfg, h, state["rec"])
        state = dict(state, rec=rec)
    elif mixer == "slstm":
        fn = R.slstm_decode if x_t.shape[1] == 1 else R.slstm_chunk
        y, rec = fn(p["slstm"], cfg, h, state["rec"])
        state = dict(state, rec=rec)
    x_t = x_t + y
    if mixer == "xattn":
        hx = L.apply_norm(p["norm_x"], cfg, x_t)
        ek, ev, ep = enc_kv
        y = L.cross_attention_decode(p["xattn"], cfg, hx, ek, ev, ep)
        x_t = x_t + y
    return x_t, state


def _block_decode(p, cfg: ModelConfig, kind: str, x_t, state, pos, *,
                  enc_kv=None, moe_mode: str = "dispatch", offload_hook=None,
                  pages=None, active=None, layer=None):
    mixer, ffn = parse_block(kind)
    info = {}
    x_t, state = _mixer_decode(p, cfg, kind, x_t, state, pos, enc_kv=enc_kv,
                               pages=pages, active=active, layer=layer)
    if ffn != "none":
        h2 = L.apply_norm(p["norm2"], cfg, x_t)
        B, S, D = h2.shape
        h2d = h2.reshape(B * S, D)
        if ffn == "moe":
            if moe_mode == "gather" or offload_hook is not None:
                y2d, route = M.moe_apply_gather(p["moe"], cfg, h2d)
                info["route"] = route
                info["hidden_pre_moe"] = h2d
            else:
                y2d, _ = M.moe_apply_dispatch(p["moe"], cfg, h2d)
        else:
            y2d = L.apply_mlp(p["mlp"], cfg, h2).reshape(B * S, D)
        x_t = x_t + y2d.reshape(B, S, D)
    return x_t, state, info


def decode_block_packed(p, cfg: ModelConfig, kind: str, x_t, state, pos,
                        store, pstate, l_moe, routers, *, lookahead: int = 1,
                        n_spec: int = 0, fused: bool = True, active=None,
                        vectorized: bool = True, pages=None):
    """One block's decode step with MoE served from the packed expert
    buffer pool — ``moe_mode="packed"`` (DESIGN.md §6).  Identical mixer
    computation to :func:`_block_decode`; the MoE FFN reads HQQ-packed
    slots through :func:`repro.models.moe.moe_apply_packed` and threads
    the pool state through.  Returns (x_t, state, pstate, info).

    This is the *synchronous* one-dispatch-per-block form (staging, when
    ``n_spec > 0``, runs inside the same jitted program as the compute) —
    the pipelined driver instead splits mixer / MoE / staging into
    separate dispatches (:func:`decode_block_packed_mixer` /
    :func:`decode_block_packed_moe`, DESIGN.md §7)."""
    mixer, ffn = parse_block(kind)
    info = {}
    x_t, state = _mixer_decode(p, cfg, kind, x_t, state, pos, pages=pages,
                               active=active)
    if ffn != "none":
        h2 = L.apply_norm(p["norm2"], cfg, x_t)
        B, S, D = h2.shape
        h2d = h2.reshape(B * S, D)
        if ffn == "moe":
            # acquire masks per ROW of the (B*S, D) token matrix; expand
            # the per-slot mask across chunk positions (C=1 unchanged)
            act_tok = active if (active is None or S == 1) \
                else jnp.repeat(active, S)
            y2d, route, pstate = M.moe_apply_packed(
                p["moe"], cfg, h2d, store, pstate, l_moe, routers,
                lookahead=lookahead, n_spec=n_spec, fused=fused,
                active=act_tok, vectorized=vectorized)
            info["route"] = route
            info["hidden_pre_moe"] = h2d
        else:
            y2d = L.apply_mlp(p["mlp"], cfg, h2).reshape(B * S, D)
        x_t = x_t + y2d.reshape(B, S, D)
    return x_t, state, pstate, info


def decode_block_packed_mixer(p, cfg: ModelConfig, kind: str, x_t, state,
                              pos, pages=None, active=None):
    """Mixer half of a packed MoE block's decode step (pipelined driver,
    DESIGN.md §7): norm1 + mixer + residual plus the pre-MoE norm —
    everything that does NOT read the expert pool state, so this dispatch
    can execute while the previous layer's speculative staging transfer
    is still in flight.  Returns (x_t, state, h2 (B, S, D))."""
    x_t, state = _mixer_decode(p, cfg, kind, x_t, state, pos, pages=pages,
                               active=active)
    return x_t, state, L.apply_norm(p["norm2"], cfg, x_t)


def decode_block_packed_moe(p, cfg: ModelConfig, x_t, h2, store, pstate,
                            l_moe, *, fused: bool = True,
                            vectorized: bool = True, active=None):
    """MoE half of a packed block's decode step (pipelined driver): route
    + ``acquire`` + packed compute + residual.  The FIRST op that reads
    the pool state — the fence where the previous layer's asynchronously
    dispatched staging is consumed (DESIGN.md §7).  Staging itself is NOT
    performed here (``n_spec=0``); the driver dispatches it separately.
    Returns (x_t, pstate, info)."""
    B, S, D = h2.shape
    h2d = h2.reshape(B * S, D)
    act_tok = active if (active is None or S == 1) else jnp.repeat(active, S)
    y2d, route, pstate = M.moe_apply_packed(
        p["moe"], cfg, h2d, store, pstate, l_moe, None, n_spec=0,
        fused=fused, active=act_tok, vectorized=vectorized)
    x_t = x_t + y2d.reshape(B, S, D)
    return x_t, pstate, {"route": route, "hidden_pre_moe": h2d}


# ======================================================================
# Decode-state init
def _block_state(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 paged=None):
    mixer, _ = parse_block(kind)
    if mixer in ("attn", "xattn", "swa") and paged is not None:
        return {"kv": L.init_paged_attn_cache(cfg, *paged)}
    if mixer in ("attn", "xattn"):
        return {"kv": L.init_attn_cache(cfg, batch, max_len, None)}
    if mixer == "swa":
        return {"kv": L.init_attn_cache(cfg, batch, max_len, cfg.sliding_window)}
    if mixer == "rglru":
        return {"rec": R.init_rglru_state(cfg, batch)}
    if mixer == "mlstm":
        return {"rec": R.init_mlstm_state(cfg, batch)}
    if mixer == "slstm":
        return {"rec": R.init_slstm_state(cfg, batch)}
    return {}


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, *,
                      kv_pages: int = None, kv_page: int = None,
                      kv_max_pages: int = None) -> PyTree:
    """``kv_pages``/``kv_page``/``kv_max_pages`` switch the KV plane to
    block-paged storage (DESIGN.md §9): every attention layer holds a
    batch-free pool of ``kv_pages`` pages of ``kv_page`` positions and
    the state grows a per-row page table ``state["pages"]``
    ((batch, kv_max_pages), −1 = unallocated) shared by all layers —
    which is why the whole pool serves any batch size (a B=1 admission
    chunk writes the same pages the running batch reads)."""
    # Per-layer-kind state planes (DESIGN.md §12): only "kv" layers take
    # the paged layout — recurrent layers keep their fixed-size state
    # (the degenerate one-page-per-slot case) whether or not the config
    # is paged, and a pure-recurrent stack simply has an all-dense state
    # plus an (unused) page table.
    paged = (kv_pages, kv_page) if kv_page is not None else None

    def stacked(kind):
        one = _block_state(cfg, kind, batch, max_len, paged)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_periods,) + a.shape).copy(), one)

    state: Dict[str, PyTree] = {
        "stack": [stacked(k) for k in cfg.block_pattern],
        "tail": [_block_state(cfg, k, batch, max_len, paged)
                 for k in cfg.tail_kinds()],
        "pos": jnp.zeros((), jnp.int32),
    }
    if paged is not None:
        state["pages"] = jnp.full((batch, kv_max_pages), -1, jnp.int32)
    if cfg.is_encoder_decoder:
        dt = jnp.dtype(cfg.dtype)
        S_e = cfg.encoder_seq
        state["enc_kv"] = {
            "k": jnp.zeros((cfg.n_layers, batch, S_e, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jnp.zeros((cfg.n_layers, batch, S_e, cfg.n_kv_heads, cfg.head_dim), dt),
            "pos": jnp.broadcast_to(jnp.arange(S_e, dtype=jnp.int32),
                                    (cfg.n_layers, S_e)).copy(),
        }
    return state


# ======================================================================
# Embedding frontends
def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    x = L.embed(params["embed"], cfg, batch["tokens"])
    if cfg.num_image_tokens and "image_embeds" in batch:
        img = jnp.einsum("bnd,de->bne", batch["image_embeds"].astype(x.dtype),
                         params["img_proj"])
        n = img.shape[1]
        x = jnp.concatenate([img, x[:, n:]], axis=1)
    return x


def _run_encoder(params, cfg: ModelConfig, audio_embeds, remat=False):
    """Whisper-style encoder over stub frontend embeddings."""
    x = audio_embeds.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, pslice):
        h, _, _ = _block_train(pslice, cfg, "encattn+mlp", carry, pos,
                               want_state=False)
        return h, ()

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["encoder"]["stack"][0])
    return L.apply_norm(params["encoder"]["final_norm"], cfg, x), pos


# ======================================================================
# Forward (train) and prefill
def pad_positions(pad_mask, S: int):
    """Prefill position layout shared by every prefill driver (scanned
    ``forward_train`` and the packed layerwise prefill): with a left-pad
    mask, real token j of a row gets logical position j − n_pads (rows
    start at position 0 regardless of padding) and pads get −1, masking
    them out of every attention; without one, plain ``arange``.
    Returns (pad_mask as bool or None, positions)."""
    if pad_mask is None:
        return None, jnp.arange(S, dtype=jnp.int32)
    pad_mask = pad_mask.astype(bool)
    positions = jnp.cumsum(pad_mask.astype(jnp.int32), axis=1) - 1
    return pad_mask, jnp.where(pad_mask, positions, -1)


def forward_train(params, cfg: ModelConfig, batch, *, want_state=False,
                  max_len: int = 0, remat: bool = False):
    x = _embed_inputs(params, cfg, batch)
    B, S, _ = x.shape
    x = constrain(x, ("pod", "data"), None, None)
    pad_mask, positions = pad_positions(batch.get("pad_mask"), S)
    max_len = max_len or S

    enc_out = enc_pos = None
    if cfg.is_encoder_decoder:
        enc_out, enc_pos = _run_encoder(params, cfg, batch["audio_embeds"],
                                        remat=remat and not want_state)

    aux_acc = {"load_balance": jnp.zeros((), jnp.float32)}
    period = cfg.pattern_period
    states = {"stack": [], "tail": []}

    # scan over periods; inside the body apply each pattern position once
    def body(carry, pslices):
        x, aux_lb = carry
        if cfg.act_seq_shard:
            # sequence-parallel residual stream (shards the remat stack)
            x = constrain(x, ("pod", "data"), "model", None)
        st_out = []
        for i in range(period):
            kind = cfg.block_pattern[i]
            x, st, aux = _block_train(pslices[i], cfg, kind, x, positions,
                                      want_state=want_state, enc_out=enc_out,
                                      enc_pos=enc_pos, max_len=max_len,
                                      pad_mask=pad_mask)
            if "load_balance" in aux:
                aux_lb = aux_lb + aux["load_balance"]
            st_out.append(st if st is not None else {})
        return (x, aux_lb), tuple(st_out)

    if remat and not want_state:
        # activation checkpointing: save only the per-period residual
        # stream; everything inside a period is recomputed in the bwd pass
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    (x, lb), stacked_states = jax.lax.scan(
        body, (x, aux_acc["load_balance"]), tuple(params["stack"]))
    if want_state:
        states["stack"] = list(stacked_states)

    for i, kind in enumerate(cfg.tail_kinds()):
        x, st, aux = _block_train(params["tail"][i], cfg, kind, x, positions,
                                  want_state=want_state, enc_out=enc_out,
                                  enc_pos=enc_pos, max_len=max_len,
                                  pad_mask=pad_mask)
        if "load_balance" in aux:
            lb = lb + aux["load_balance"]
        if want_state:
            states["tail"].append(st)

    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.unembed(params, cfg, x)
    aux_acc["load_balance"] = lb
    if want_state:
        # per-row decode positions when rows have different true lengths
        states["pos"] = (pad_mask.sum(1).astype(jnp.int32)
                         if pad_mask is not None
                         else jnp.asarray(S, jnp.int32))
        if cfg.is_encoder_decoder:
            states["enc_kv"] = _collect_enc_kv(params, cfg, enc_out)
        return logits, aux_acc, states
    return logits, aux_acc


def _collect_enc_kv(params, cfg, enc_out):
    """Precompute per-decoder-layer cross-attn K/V from encoder output."""
    def per_layer(pslice):
        k, v = L.precompute_cross_kv(pslice["xattn"], cfg, enc_out)
        return k, v

    ks, vs = jax.vmap(per_layer)(params["stack"][0])
    S_e = enc_out.shape[1]
    return {"k": ks, "v": vs,
            "pos": jnp.broadcast_to(jnp.arange(S_e, dtype=jnp.int32),
                                    (cfg.n_layers, S_e)).copy()}


def encode_enc_kv(params, cfg: ModelConfig, audio_embeds):
    """Encoder pass + per-decoder-layer cross-attn K/V — the admission-
    time computation of the read-only shared encoder-KV plane
    (DESIGN.md §12): run ONCE per request when it is admitted, referenced
    by every decode step, never scattered to.  ``audio_embeds``:
    (B, encoder_seq, d_model)."""
    enc_out, _ = _run_encoder(params, cfg, audio_embeds)
    return _collect_enc_kv(params, cfg, enc_out)


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """``batch`` may carry ``pad_mask`` (B, S) for left-padded prompts of
    unequal length; the returned state then has per-row ``pos`` (B,)."""
    logits, aux, state = forward_train(params, cfg, batch, want_state=True,
                                       max_len=max_len)
    return logits, state


_ENGINE_JIT_CACHE: Dict[Any, Any] = {}
_ENGINE_JIT_STATS: Dict[str, int] = {"builds": 0, "hits": 0}


def cached_jit(key, make):
    """Process-wide cache of engine-level jitted callables.

    Engines (serving, offload, oracle decoders) are constructed per
    test / benchmark pass; per-instance ``jax.jit`` closures would
    recompile byte-identical programs every time (jax caches by function
    *object*).  Keying on the (hashable, frozen) config plus mode flags
    lets every instance share one executable — a large share of the
    tier-1 suite's runtime was exactly this recompilation (DESIGN.md §7).
    ``params``/state always ride as call arguments, so nothing model-
    specific is baked into the cache entry.
    """
    if key not in _ENGINE_JIT_CACHE:
        _ENGINE_JIT_STATS["builds"] += 1
        _ENGINE_JIT_CACHE[key] = make()
    else:
        _ENGINE_JIT_STATS["hits"] += 1
    return _ENGINE_JIT_CACHE[key]


def cached_jit_stats() -> Dict[str, Any]:
    """Introspection for the engine-executable cache (DESIGN.md §8):
    ``builds`` counts ``make()`` invocations (one per distinct program
    key per process — the compile-once invariant the runtime executor's
    tests assert), ``hits`` the cache reuses, ``entries``/``keys`` the
    live cache contents."""
    return {**_ENGINE_JIT_STATS,
            "entries": len(_ENGINE_JIT_CACHE),
            "keys": list(_ENGINE_JIT_CACHE.keys())}


def cached_jit_clear() -> None:
    """Drop every cached engine executable (and its stats).

    The explicit hook conftest uses after memory-heavy test modules:
    ``jax.clear_caches()`` invalidates the underlying XLA executables,
    but the jitted *wrappers* held here would pin their constants/params
    closures alive — clearing both releases the memory and resets the
    compile-once accounting for the next measurement."""
    _ENGINE_JIT_CACHE.clear()
    _ENGINE_JIT_STATS["builds"] = 0
    _ENGINE_JIT_STATS["hits"] = 0


def make_prefill(cfg: ModelConfig):
    """Jitted prefill with static ``max_len`` — the one wrapper every
    engine shares: ``fn(params, batch, max_len)``."""
    return cached_jit(
        ("prefill", cfg),
        lambda: jax.jit(lambda p, b, ml: prefill(p, cfg, b, ml),
                        static_argnums=2))


# ======================================================================
# Decode
def decode_step(params, cfg: ModelConfig, state, tokens, *,
                moe_mode: str = "dispatch", collect_info: bool = False,
                active=None, row=None):
    """tokens: (B, C) int32. Returns (logits (B,C,V), new_state[, infos]).

    C = 1 is the classic one-token decode step.  C > 1 is a *prefill
    chunk*: attention mixers write the chunk's K/V into the caches at
    positions ``pos .. pos+C-1``; recurrent mixers (rglru/mlstm/slstm)
    fold the chunk through their sequential chunk forms
    (``repro.models.recurrent.*_chunk`` — carry composition is exact, so
    chunk splits are bitwise-invariant); enc-dec decoders additionally
    read the shared ``state["enc_kv"]`` plane.  ``pos`` advances by C —
    the runtime executor drives chunked prefill through exactly this
    step (DESIGN.md §8/§12), so decode and chunked prefill share one
    block program for EVERY layer kind in the config zoo.

    ``state["pos"]`` may be a scalar (whole batch in lock-step) or (B,)
    per-row positions (continuous batching / padded prefill).

    ``moe_mode``: "dispatch" (scatter into capacity buffers), "gather"
    (per-token expert-weight gather — interactive decode / routing
    collection).  The third mode, "packed" (HQQ-packed experts served
    from the device buffer pool), runs through the layerwise driver
    (``repro.runtime.Executor`` packed planes ->
    :func:`decode_block_packed`) rather than this scanned step, because
    its slot state threads across layers; on this backend the layerwise
    loop is bitwise-identical to the scan (tests/test_offload.py).

    Paged-KV states (``"pages"`` in state, DESIGN.md §9) add two
    controls: ``active`` (B,) bool gates which rows write KV and advance
    ``pos`` (idle / mid-admission slots are frozen — their pages are
    either unallocated or being filled by chunk programs), and
    ``row`` (traced int32 scalar) runs the step as a **B=1 row chunk**
    against the shared page pools: tokens must be (1, C), the program
    slices that row's page-table row and position, writes the chunk's KV
    straight into the pool pages the row owns (no private accumulator
    state, no install copy), and advances only that row's ``pos``."""
    if moe_mode == "packed":
        raise ValueError(
            "moe_mode='packed' threads buffer-pool state across layers; "
            "drive it with a packed-plane repro.runtime.Executor "
            "(layerwise decode_block_packed), not the scanned decode_step")
    x = L.embed(params["embed"], cfg, tokens)
    pages = state.get("pages")
    if row is not None:
        assert pages is not None, "row chunks need a paged-KV state"
        row = jnp.asarray(row, jnp.int32)
        pages = jax.lax.dynamic_slice(pages, (row, 0),
                                      (1, pages.shape[1]))
        pos = jax.lax.dynamic_slice(state["pos"], (row,), (1,))
    else:
        pos = state["pos"]
    period = cfg.pattern_period
    infos = []

    enc_kv_stacked = state.get("enc_kv")

    def _enc_kv_for(li):
        """Per-layer cross-attn view of the shared encoder-KV plane —
        READ-ONLY (computed once at admission, never scattered to)."""
        ek, ev = enc_kv_stacked["k"][li], enc_kv_stacked["v"][li]
        if row is not None:
            ek = jax.lax.dynamic_slice_in_dim(ek, row, 1, axis=0)
            ev = jax.lax.dynamic_slice_in_dim(ev, row, 1, axis=0)
        return ek, ev, enc_kv_stacked["pos"][li]

    def _gate_rows(old, new):
        """Freeze inactive rows' fixed-size (rec) state: unlike the ring
        caches — where a frozen row's writes stay row-local and invisible
        behind its pos — a recurrent carry update would corrupt the row,
        so masked rows keep their pre-step state bit for bit."""
        return jax.tree.map(
            lambda o, n: jnp.where(
                active.reshape(active.shape + (1,) * (n.ndim - 1)), n, o),
            old, new)

    # The stacked decode state rides in the scan CARRY and is updated
    # in place with dynamic_update_index — passing it as xs/ys would make
    # XLA double-buffer the entire KV stack (2.5x cache memory at
    # decode_32k; caught by the dry-run).
    def scan_body(carry, xs):
        x, sstacks = carry
        pslices, lidx = xs
        new_stacks = list(sstacks)
        inf_out = []
        for i in range(period):
            kind = cfg.block_pattern[i]
            mixer = parse_block(kind)[0]
            enc_kv = None
            if mixer == "xattn" and enc_kv_stacked is not None:
                enc_kv = _enc_kv_for(lidx * period + i)
            if pages is not None and mixer in ("attn", "swa", "xattn"):
                # paged KV: the layer-stacked pool stays WHOLE in the
                # carry; the layer index rides in the scatter/gather
                # indices, so XLA updates the (donated) pool in place —
                # slicing it per layer would copy pool-capacity bytes
                # every step (DESIGN.md §9)
                x, st, info = _block_decode(pslices[i], cfg, kind, x,
                                            new_stacks[i], pos,
                                            enc_kv=enc_kv,
                                            moe_mode=moe_mode, pages=pages,
                                            active=active, layer=lidx)
                new_stacks[i] = st
            else:
                # dense rings and fixed-size recurrent state (the
                # DESIGN.md §12 "rec" plane — also taken by rec layers of
                # a paged hybrid: their state never pages)
                sslice = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, lidx, 0,
                                                           keepdims=False),
                    new_stacks[i])
                blk_in = sslice
                if row is not None:
                    blk_in = jax.tree.map(
                        lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1,
                                                               axis=0),
                        sslice)
                x, st, info = _block_decode(pslices[i], cfg, kind, x,
                                            blk_in, pos, enc_kv=enc_kv,
                                            moe_mode=moe_mode)
                if row is not None:
                    st = jax.tree.map(
                        lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                            full, r, row, axis=0),
                        sslice, st)
                elif active is not None:
                    st = _gate_rows(sslice, st)
                new_stacks[i] = jax.tree.map(
                    lambda a, b: jax.lax.dynamic_update_index_in_dim(
                        a, b, lidx, 0),
                    new_stacks[i], st)
            if collect_info:
                inf_out.append(info)
        return (x, tuple(new_stacks)), \
            (tuple(inf_out) if collect_info else ())

    lidx = jnp.arange(cfg.n_periods, dtype=jnp.int32)
    (x, new_stack), info_stack = jax.lax.scan(
        scan_body, (x, tuple(state["stack"])),
        (tuple(params["stack"]), lidx))

    new_tail = []
    for i, kind in enumerate(cfg.tail_kinds()):
        mixer = parse_block(kind)[0]
        enc_kv = None
        if mixer == "xattn" and enc_kv_stacked is not None:
            enc_kv = _enc_kv_for(cfg.n_periods * period + i)
        st_in = state["tail"][i]
        if pages is not None and mixer in ("attn", "swa", "xattn"):
            x, st, info = _block_decode(params["tail"][i], cfg, kind, x,
                                        st_in, pos, enc_kv=enc_kv,
                                        moe_mode=moe_mode,
                                        pages=pages, active=active)
        else:
            blk_in = st_in
            if row is not None:
                blk_in = jax.tree.map(
                    lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=0),
                    st_in)
            x, st, info = _block_decode(params["tail"][i], cfg, kind, x,
                                        blk_in, pos, enc_kv=enc_kv,
                                        moe_mode=moe_mode)
            if row is not None:
                st = jax.tree.map(
                    lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                        full, r, row, axis=0),
                    st_in, st)
            elif active is not None:
                st = _gate_rows(st_in, st)
        new_tail.append(st)
        if collect_info:
            infos.append(info)

    x = L.apply_norm(params["final_norm"], cfg, x)
    logits = L.unembed(params, cfg, x)
    C = tokens.shape[1]
    if row is not None:
        new_pos = jax.lax.dynamic_update_slice(state["pos"], pos + C, (row,))
    elif pages is not None and active is not None:
        # frozen rows (idle slots / mid-admission) must not advance: an
        # admission's next chunk writes at the position it left off
        new_pos = pos + jnp.where(active, C, 0).astype(pos.dtype)
    else:
        new_pos = pos + C
    new_state = dict(state, stack=list(new_stack), tail=new_tail,
                     pos=new_pos)
    if collect_info:
        return logits, new_state, (info_stack, infos)
    return logits, new_state


# ======================================================================
# Per-layer param/state access (used by the offload engine / tracing,
# which run an unscanned python loop over layers).
def layer_params(params, cfg: ModelConfig, layer_idx: int):
    period = cfg.pattern_period
    n_scanned = cfg.n_periods * period
    if layer_idx < n_scanned:
        pos = layer_idx % period
        per = layer_idx // period
        return jax.tree.map(lambda a: a[per], params["stack"][pos])
    return params["tail"][layer_idx - n_scanned]


def layer_kind(cfg: ModelConfig, layer_idx: int) -> str:
    return cfg.block_pattern[layer_idx % cfg.pattern_period]


def decode_state_layer(state, cfg: ModelConfig, layer_idx: int):
    """Slice one layer's decode state out of the stacked layout."""
    period = cfg.pattern_period
    n_scanned = cfg.n_periods * period
    if layer_idx < n_scanned:
        per = layer_idx // period
        return jax.tree.map(lambda a: a[per], state["stack"][layer_idx % period])
    return state["tail"][layer_idx - n_scanned]


def set_decode_state_layer(state, cfg: ModelConfig, layer_idx: int, new):
    """Write one layer's decode state back into the stacked layout
    (pure: returns an updated state dict)."""
    period = cfg.pattern_period
    n_scanned = cfg.n_periods * period
    out = dict(state)
    if layer_idx < n_scanned:
        per = layer_idx // period
        i = layer_idx % period
        out["stack"] = list(state["stack"])
        out["stack"][i] = jax.tree.map(lambda a, b: a.at[per].set(b),
                                       state["stack"][i], new)
    else:
        out["tail"] = list(state["tail"])
        out["tail"][layer_idx - n_scanned] = new
    return out


def embed_tokens(params, cfg: ModelConfig, tokens):
    """(B, S) int32 -> (B, S, D) embeddings (layerwise-driver frontend)."""
    return L.embed(params["embed"], cfg, tokens)


def apply_head(params, cfg: ModelConfig, x):
    """Final norm + unembed (layerwise-driver backend)."""
    return L.unembed(params, cfg, L.apply_norm(params["final_norm"], cfg, x))
