"""Sparse Mixture-of-Experts FFN: token-level top-k routing.

Four apply paths, all producing *identical* outputs (unit-tested):

* :func:`moe_apply_dense` — reference: every expert computed for every
  token, combined with the (sparse) routing weights.  O(E) compute; used
  as the test oracle and for tiny decode batches.
* :func:`moe_apply_dispatch` — production path: tokens are scattered into
  an ``(E, capacity, D)`` buffer (GShard-style, but via scatter indices
  rather than a one-hot dispatch einsum, which would be O(T*E*C) memory),
  expert FFNs run as one batched einsum, results gather back.  Under the
  production mesh the buffer's expert axis is sharded on ``"model"``
  (expert parallelism -> all-to-all) when E divides the axis.
* :func:`moe_apply_gather` — per-token expert-weight gather over a dense
  resident expert stack: only the (T, K) selected experts' weight slices
  are read.  The computational shape of offloaded decode, and the parity
  oracle for the packed path below.
* :func:`moe_apply_packed` — the real offloaded path (DESIGN.md §6):
  expert weights stay HQQ-packed in a host store; the selected experts
  are served from a per-layer device buffer pool (``core/expert_pool``)
  driven by the LRU/speculative state machine, and computed either by
  per-slot dequantization into the *same* einsums as the gather path
  (bitwise-equal by construction) or by the fused dequant-matmul kernel
  (``kernels/ops.dequant_matmul`` — Pallas when shapes/bits tile, jnp
  reference fallback for 3-bit and non-aligned shapes).

Capacity-overflow tokens in the dispatch path are dropped (standard GShard
semantics); with ``capacity_factor >= top_k * E`` no token can ever drop,
which the tests exploit to check dispatch == dense exactly.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import expert_pool as EP
from repro.core import speculative
from repro.quant import hqq
from repro.sharding.specs import constrain


def init_moe(rng, cfg, n_layers_hint: Optional[int] = None):
    spec = cfg.moe
    D, F, E = cfg.d_model, cfg.d_ff, spec.num_experts
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    sc_in = 1.0 / math.sqrt(D)
    sc_out = 1.0 / math.sqrt(F) / math.sqrt(2 * (n_layers_hint or cfg.n_layers))
    return {
        "router": (jax.random.normal(k1, (D, E)) * sc_in).astype(jnp.float32),
        "experts": {
            "w_gate": (jax.random.normal(k2, (E, D, F)) * sc_in).astype(dt),
            "w_up": (jax.random.normal(k3, (E, D, F)) * sc_in).astype(dt),
            "w_down": (jax.random.normal(k4, (E, F, D)) * sc_out).astype(dt),
        },
    }


def router_logits(p, x2d):
    """(T, E) router logits in float32 (paper keeps gates in 16/32-bit)."""
    return jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                      p["router"].astype(jnp.float32))


def route_topk(p, spec, x2d) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (weights (T,K) f32, ids (T,K) i32, probs (T,E) f32)."""
    logits = router_logits(p, x2d)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, spec.top_k)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)  # mixtral renorm
    return w, ids.astype(jnp.int32), probs


def expert_ffn(experts, cfg, xbuf):
    """xbuf: (E, C, D) -> (E, C, D), batched over experts."""
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", xbuf, experts["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xbuf, experts["w_up"])
    h = act(g.astype(jnp.float32)).astype(xbuf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, experts["w_down"])


def capacity(spec, T: int) -> int:
    c = int(math.ceil(spec.top_k * T * spec.capacity_factor / spec.num_experts))
    return max(4, c + (-c) % 4)


def aux_losses(spec, probs, ids, token_mask=None):
    """Switch-style load-balance loss + router z-ish entropy diagnostics.
    ``token_mask`` excludes pad tokens (their garbage routing must not
    bias the balance statistics)."""
    T, E = probs.shape
    assign = jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1)  # (T, E)
    if token_mask is None:
        frac_tokens = assign.mean(0) / spec.top_k
        frac_probs = probs.mean(0)
    else:
        w = token_mask.astype(jnp.float32)[:, None]  # (T, 1)
        n = jnp.maximum(w.sum(), 1.0)
        frac_tokens = (assign * w).sum(0) / (n * spec.top_k)
        frac_probs = (probs * w).sum(0) / n
    lb = E * jnp.sum(frac_tokens * frac_probs)
    return {"load_balance": lb}


# ----------------------------------------------------------------------
def moe_apply_dense(p, cfg, x2d):
    """Oracle: compute all experts densely, weight by routing."""
    spec = cfg.moe
    w, ids, probs = route_topk(p, spec, x2d)
    T, D = x2d.shape
    E = spec.num_experts
    # sparse weights as dense (T, E)
    wdense = jnp.zeros((T, E), jnp.float32)
    wdense = wdense.at[jnp.arange(T)[:, None], ids].add(w)
    xb = jnp.broadcast_to(x2d[None], (E, T, D))
    y_all = expert_ffn(p["experts"], cfg, xb)  # (E, T, D)
    y = jnp.einsum("etd,te->td", y_all.astype(jnp.float32), wdense)
    return y.astype(x2d.dtype), aux_losses(spec, probs, ids)


def moe_apply_dispatch(p, cfg, x2d, capacity_factor=None, groups=None,
                       token_mask=None, expert_ffn_fn=None):
    """Scatter-dispatch production path (train / large-batch decode).

    ``token_mask`` (T,) bool marks real tokens: masked-out tokens (pads in
    a left-padded serving batch) are dropped from dispatch so they never
    consume expert capacity that belongs to real tokens.

    ``groups`` splits tokens into independently-dispatched groups with
    per-group capacity (the real-EP-system semantics: capacity is per
    device group, and the scatter stays LOCAL to the group).  On the
    production mesh ``groups`` = number of batch shards, so the group axis
    shards on ("pod","data") and only the expert FFN crosses shards
    (all-to-all when experts are model-sharded).  Without grouping GSPMD
    replicates the global scatter (74GB/chip for granite train_4k —
    caught by the dry-run).

    ``expert_ffn_fn`` overrides the expert computation (``(E, C, D) ->
    (E, C, D)``); the packed-offload prefill streams experts one at a
    time from the host store this way (:func:`packed_expert_ffn`) instead
    of reading a dense resident stack.
    """
    spec = cfg.moe
    if capacity_factor is not None:
        spec = spec.__class__(**{**spec.__dict__, "capacity_factor": capacity_factor})
    g = groups or getattr(cfg, "moe_dispatch_groups", 1) or 1
    T, D = x2d.shape
    if T % g:
        g = 1
    w, ids, probs = route_topk(p, spec, x2d)
    Tg = T // g
    E, K = spec.num_experts, spec.top_k
    C = capacity(spec, Tg)

    def dispatch_one(xg, idsg, wg, mg):
        flat_e = idsg.reshape(Tg * K)  # slot -> expert, token-major priority
        flat_valid = jnp.repeat(mg, K)
        # masked tokens point at a virtual expert E so they never claim a
        # capacity position of a real expert
        flat_e = jnp.where(flat_valid, flat_e, E)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (Tg*K, E)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_in_e, jnp.minimum(flat_e, E - 1)[:, None],
                                  axis=1)[:, 0]
        keep = (pos < C) & flat_valid
        flat_e = jnp.minimum(flat_e, E - 1)  # safe index; dropped via keep
        pos_c = jnp.where(keep, pos, C)  # C = out-of-range -> dropped
        tok_idx = jnp.repeat(jnp.arange(Tg), K)
        xslot = jnp.take(xg, tok_idx, axis=0)  # (Tg*K, D)
        buf = jnp.zeros((E, C, D), xg.dtype)
        buf = buf.at[flat_e, pos_c].add(
            jnp.where(keep[:, None], xslot, 0), mode="drop")
        # slot-level reverse maps so the combine can scatter straight from
        # the (expert-sharded) ybuf into per-token outputs: the cross-shard
        # traffic is then (Tg, D) instead of (Tg*K, D) — top_k x less
        # (§Perf hillclimb 3 on granite's top-8 routing)
        tok_map = jnp.full((E, C), Tg, jnp.int32)  # Tg = dropped sentinel
        tok_map = tok_map.at[flat_e, pos_c].set(
            jnp.where(keep, tok_idx, Tg), mode="drop")
        w_map = jnp.zeros((E, C), jnp.float32)
        w_map = w_map.at[flat_e, pos_c].set(
            jnp.where(keep, wg.reshape(Tg * K), 0.0), mode="drop")
        return buf, (tok_map, w_map)

    def combine_one(ybuf, meta, wg):
        tok_map, w_map = meta
        contrib = ybuf * w_map[..., None].astype(ybuf.dtype)  # (E, C, D)
        y = jnp.zeros((Tg, D), x2d.dtype)
        return y.at[tok_map.reshape(E * C)].add(
            contrib.reshape(E * C, D).astype(x2d.dtype), mode="drop")

    xg = x2d.reshape(g, Tg, D)
    idsg = ids.reshape(g, Tg, K)
    wg = w.reshape(g, Tg, K)
    mg = (jnp.ones((g, Tg), bool) if token_mask is None
          else token_mask.reshape(g, Tg).astype(bool))
    buf, meta = jax.vmap(dispatch_one)(xg, idsg, wg, mg)  # (g, E, C, D)
    # group axis -> batch shards (local dispatch); expert axis -> "model"
    # (expert parallel) when divisible.  The expert FFN below is the only
    # cross-group op -> all-to-all.
    buf = constrain(buf, ("pod", "data"), "model", None, None)
    ffn = expert_ffn_fn or (lambda b: expert_ffn(p["experts"], cfg, b))
    ybuf = jax.vmap(ffn)(buf)
    ybuf = constrain(ybuf, ("pod", "data"), "model", None, None)
    y = jax.vmap(combine_one)(ybuf, meta, wg)  # (g, Tg, D)
    return (y.reshape(T, D).astype(x2d.dtype),
            aux_losses(spec, probs, ids, token_mask=token_mask))


def moe_apply_gather(p, cfg, x2d, experts_override=None):
    """Per-token expert-weight gather — the offloaded-inference shape.

    Only the (T, K) selected experts' weight slices are read.  With the
    offload engine, ``experts_override`` supplies (possibly dequantized)
    weights gathered from the cache/host pools; here we gather from the
    resident stacked experts.  T is expected tiny (interactive decode).
    """
    spec = cfg.moe
    w, ids, probs = route_topk(p, spec, x2d)
    ex = experts_override or p["experts"]
    wg = jnp.take(ex["w_gate"], ids, axis=0)  # (T, K, D, F)
    wu = jnp.take(ex["w_up"], ids, axis=0)
    wd = jnp.take(ex["w_down"], ids, axis=0)  # (T, K, F, D)
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
    g = jnp.einsum("td,tkdf->tkf", x2d, wg)
    u = jnp.einsum("td,tkdf->tkf", x2d, wu)
    h = act(g.astype(jnp.float32)).astype(x2d.dtype) * u
    yk = jnp.einsum("tkf,tkfd->tkd", h, wd)  # (T, K, D)
    y = jnp.einsum("tkd,tk->td", yk.astype(jnp.float32), w)
    return y.astype(x2d.dtype), {"ids": ids, "weights": w, "probs": probs}


# ----------------------------------------------------------------------
def _packed_compute(cfg, x2d, served, w, *, fused: bool = True):
    """The vectorized packed-MoE data plane shared by decode
    (:func:`moe_apply_packed`) and chunked prefill
    (:func:`moe_apply_packed_stream`): compute every (token, k) expert
    matmul straight from the served packed slots ``(T*K, ...)`` leading.

    ``fused=True`` runs the whole batch as one fused dequant-matmul
    dispatch per matrix (``kernels/ops.dequant_matmul_batched``);
    ``fused=False`` dequantizes per slot into exactly
    :func:`moe_apply_gather`'s einsums.  Both bitwise-equal on this
    backend (tested) — which is what makes decode and chunked prefill
    interchangeable bitwise (DESIGN.md §8).
    """
    from repro.kernels import ops  # local import: keep kernels optional

    T, K = w.shape
    dt = x2d.dtype
    ddt = jnp.dtype(cfg.dtype)
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
    if fused:
        xk = jnp.repeat(x2d, K, axis=0)[:, None, :]      # (T*K, 1, D)
        g = ops.dequant_matmul_batched(xk, served.w_gate).astype(dt)
        u = ops.dequant_matmul_batched(xk, served.w_up).astype(dt)
        h = act(g.astype(jnp.float32)).astype(dt) * u
        yk = ops.dequant_matmul_batched(h, served.w_down)  # (T*K, 1, D)
        y = jnp.einsum("tkd,tk->td", yk.reshape(T, K, -1), w)
    else:
        dq = lambda qt: hqq.dequantize(qt, ddt).reshape(
            (T, K) + tuple(qt.shape[1:]))
        wg = dq(served.w_gate)   # (T, K, D, F)
        wu = dq(served.w_up)
        wd = dq(served.w_down)   # (T, K, F, D)
        g = jnp.einsum("td,tkdf->tkf", x2d, wg)
        u = jnp.einsum("td,tkdf->tkf", x2d, wu)
        h = act(g.astype(jnp.float32)).astype(dt) * u
        yk = jnp.einsum("tkf,tkfd->tkd", h, wd)
        y = jnp.einsum("tkd,tk->td", yk.astype(jnp.float32), w)
    return y.astype(dt)


def moe_apply_packed_stream(p, cfg, x2d, store, l, *, fused: bool = True):
    """Chunked-prefill MoE over the packed host store (DESIGN.md §8).

    Routes the chunk's tokens, gathers the routed experts' packed bytes
    straight from the host store in ONE batched ``pe_gather`` (the same
    batch-plan gather :func:`~repro.core.expert_pool.acquire` uses for
    pool misses), and computes with the shared :func:`_packed_compute`
    plane.  No pool state is read or written and no transfer is counted:
    prefill is the encode phase the paper's cache does not manage, so
    chunked prefill leaves the LRU/staging tiers and the h2d counters
    exactly as whole-prompt prefill does — untouched.

    Bitwise-identical to :func:`moe_apply_gather` over the dequantized
    expert stack (per-slot dequant commutes with stacking; same einsums).
    Returns ``(y2d, route_info)``.
    """
    w, ids, probs = route_topk(p, cfg.moe, x2d)
    T, K = ids.shape
    served = EP.pe_gather(store, l, ids.reshape(T * K))
    y = _packed_compute(cfg, x2d, served, w, fused=fused)
    return y, {"ids": ids, "weights": w, "probs": probs}


def moe_apply_packed(p, cfg, x2d, store, pstate, l, routers=None, *,
                     lookahead: int = 1, n_spec: int = 0, fused: bool = True,
                     active=None, vectorized: bool = True):
    """Offloaded-decode MoE over HQQ-packed weights (DESIGN.md §6/§7).

    The routed experts of layer ``l`` are served from the per-layer device
    buffer pool (``core/expert_pool.acquire`` performs the LRU slot swaps
    and host-store gathers the state machine decides), then computed
    straight from the packed slot contents:

    * ``fused=True`` — the whole batch of (token, k) expert matmuls runs
      as ONE fused dequant-matmul dispatch
      (``kernels/ops.dequant_matmul_batched``: Pallas kernel when
      shapes/bits tile, batched jnp reference otherwise).
    * ``fused=False`` — batched dequantization assembled into exactly
      :func:`moe_apply_gather`'s einsums (bitwise-equal by construction).

    ``vectorized=False`` replays the PR-2 data plane — per-(token, k)
    sequential slot swaps and T*K separate matmul calls — kept only as
    the measured baseline of ``benchmarks/offload_bench.py``.

    After serving layer ``l``, the lookahead layer's likely experts are
    predicted from the *current* hidden state (paper §3.2) and staged into
    its staging buffers — batch-1 interactive decode only, matching the
    paper's setting (batched continuous decode disables speculation).
    The pipelined executor (``repro.runtime.Executor``) passes
    ``n_spec=0`` and instead dispatches staging asynchronously *outside*
    this jitted block (DESIGN.md §7).

    ``p`` only needs the router (packed mode strips dense expert stacks
    from the executable params).  Returns ``(y2d, route_info, pstate')``.
    """
    from repro.kernels import ops  # local import: keep kernels optional

    spec_moe = cfg.moe
    w, ids, probs = route_topk(p, spec_moe, x2d)
    pstate, served = EP.acquire(store, pstate, l, ids, active,
                                vectorized=vectorized)
    T, K = ids.shape
    dt = x2d.dtype
    ddt = jnp.dtype(cfg.dtype)
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
    if vectorized:
        y = _packed_compute(cfg, x2d, served, w, fused=fused)
    elif fused:
        yk_rows = []
        for t in range(T):
            xt = x2d[t:t + 1]
            for k in range(K):
                sl = served.slice(t * K + k)
                g = ops.dequant_matmul(xt, sl.w_gate).astype(dt)
                u = ops.dequant_matmul(xt, sl.w_up).astype(dt)
                h = act(g.astype(jnp.float32)).astype(dt) * u
                yk_rows.append(ops.dequant_matmul(h, sl.w_down))
        yk = jnp.stack(yk_rows).reshape(T, K, -1)  # (T, K, D) f32
        y = jnp.einsum("tkd,tk->td", yk.astype(jnp.float32), w)
    else:
        dq = lambda qt: jnp.stack(
            [hqq.dequantize(hqq.slice_leading(qt, i), ddt)
             for i in range(T * K)]).reshape((T, K) + qt.shape[1:])
        wg = dq(served.w_gate)   # (T, K, D, F)
        wu = dq(served.w_up)
        wd = dq(served.w_down)   # (T, K, F, D)
        g = jnp.einsum("td,tkdf->tkf", x2d, wg)
        u = jnp.einsum("td,tkdf->tkf", x2d, wu)
        h = act(g.astype(jnp.float32)).astype(dt) * u
        yk = jnp.einsum("tkf,tkfd->tkd", h, wd)
        y = jnp.einsum("tkd,tk->td", yk.astype(jnp.float32), w)
    if T == 1 and n_spec > 0 and routers is not None:
        tgt = l + lookahead
        L = store.n_layers
        pred = speculative.predict_experts(
            routers[jnp.clip(tgt, 0, L - 1)], x2d, n_spec)[0]
        pstate = EP.stage(store, pstate, tgt, pred, tgt < L,
                          vectorized=vectorized)
    return (y.astype(dt), {"ids": ids, "weights": w, "probs": probs},
            pstate)


def packed_expert_ffn(store, l, cfg) -> Callable:
    """Expert FFN over the packed host store for the *prefill* phase:
    experts stream through one at a time (per-slot dequantization, no
    dense (E, ...) weight stack), computing per-expert slices of exactly
    :func:`expert_ffn`'s einsums — bitwise-equal on this backend (the
    encode phase "works relatively well with existing algorithms", so no
    cache accounting here).  Use as ``moe_apply_dispatch(...,
    expert_ffn_fn=packed_expert_ffn(store, l, cfg))``.
    """
    act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
    ddt = jnp.dtype(cfg.dtype)

    def ffn(xbuf):  # (E, C, D) -> (E, C, D)
        outs = []
        for e in range(store.n_slots):
            sl = store.slice(l, e)
            wg = hqq.dequantize(sl.w_gate, ddt)
            wu = hqq.dequantize(sl.w_up, ddt)
            wd = hqq.dequantize(sl.w_down, ddt)
            g = jnp.einsum("cd,df->cf", xbuf[e], wg)
            u = jnp.einsum("cd,df->cf", xbuf[e], wu)
            h = act(g.astype(jnp.float32)).astype(xbuf.dtype) * u
            outs.append(jnp.einsum("cf,fd->cd", h, wd))
        return jnp.stack(outs)

    return ffn
