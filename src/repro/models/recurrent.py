"""Recurrent sequence mixers: RG-LRU (Griffin/RecurrentGemma), mLSTM and
sLSTM (xLSTM).

All three expose the same triple of apply functions used by
``models/transformer.py``:

* ``*_train(params, cfg, x)``            -> ``(y, final_state)``
* ``*_decode(params, cfg, x_t, state)``  -> ``(y_t, new_state)``
* ``init_*_state(cfg, batch)``           -> zero state pytree

Numerical notes
---------------
* The mLSTM training path is **chunkwise-parallel** (TPU-friendly: big
  matmuls within a chunk, a short scan across chunks) and is provably
  identical to the stabilized recurrent form — ``mlstm_recurrent_ref`` is
  the oracle and ``tests/test_recurrent.py`` asserts allclose.  All
  stabilizer exponents are <= 0 by construction (log-space cummax), so the
  chunkwise form is overflow-free in bf16/f32.
* RG-LRU training uses ``jax.lax.associative_scan`` over the linear
  recurrence h_t = a_t * h_{t-1} + b_t.
* sLSTM has true hidden-to-hidden recurrence (block-diagonal per head) and
  therefore scans sequentially over time — that *is* the architecture; the
  xLSTM paper accepts this for a minority of blocks.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _pdt(cfg):
    return jnp.dtype(cfg.dtype)


# ======================================================================
# RG-LRU block (Griffin recurrent block: gated branch * conv->RG-LRU branch)
RGLRU_C = 8.0


def init_rglru(rng, cfg):
    D = cfg.d_model
    R = D  # rnn width = d_model (RecurrentGemma-9B uses 4096 = d_model)
    dt = _pdt(cfg)
    ks = jax.random.split(rng, 7)
    sc = 1.0 / math.sqrt(D)
    scr = 1.0 / math.sqrt(R)
    # Lambda init so that a = exp(-c*softplus(L)) in (0.9, 0.999)
    lam = jax.random.uniform(ks[0], (R,), minval=-9.0, maxval=-4.3)
    return {
        "w_x": (jax.random.normal(ks[1], (D, R)) * sc).astype(dt),
        "w_gate_br": (jax.random.normal(ks[2], (D, R)) * sc).astype(dt),
        "conv_w": (jax.random.normal(ks[3], (cfg.rglru_conv_width, R)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((R,), dt),
        "w_rec_gate": (jax.random.normal(ks[4], (R, R)) * scr).astype(dt),
        "w_in_gate": (jax.random.normal(ks[5], (R, R)) * scr).astype(dt),
        "lam": lam.astype(F32),
        "w_out_r": (jax.random.normal(ks[6], (R, D)) * scr
                    / math.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def init_rglru_state(cfg, batch):
    R = cfg.d_model
    return {
        "h": jnp.zeros((batch, R), F32),
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, R), _pdt(cfg)),
    }


def _causal_conv(p, x, prefix):
    """Depthwise causal conv, width cw.  x: (B,S,R); prefix: (B,cw-1,R)."""
    cw = p["conv_w"].shape[0]
    xp = jnp.concatenate([prefix, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * p["conv_w"][cw - 1 - i]
            for i in range(cw))
    return y + p["conv_b"], xp[:, -(cw - 1):]


def _rglru_gates(p, xi):
    r = jax.nn.sigmoid(jnp.einsum("...r,rq->...q", xi, p["w_rec_gate"]).astype(F32))
    i = jax.nn.sigmoid(jnp.einsum("...r,rq->...q", xi, p["w_in_gate"]).astype(F32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably
    b_scale = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = b_scale * (i * xi.astype(F32))
    return a, b


def rglru_train(p, cfg, x) -> Tuple[jnp.ndarray, dict]:
    from repro.sharding.specs import constrain

    B, S, D = x.shape
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_br"]).astype(F32))
    xi0 = jnp.einsum("bsd,dr->bsr", x, p["w_x"])
    prefix = jnp.zeros((B, cfg.rglru_conv_width - 1, xi0.shape[-1]), x.dtype)
    xi, conv_state = _causal_conv(p, xi0, prefix)
    a, b = _rglru_gates(p, xi)
    # the recurrence is elementwise over R: shard R on "model" so the
    # associative scan's O(log S) saved intermediates shard too
    a = constrain(a, ("pod", "data"), None, "model")
    b = constrain(b, ("pod", "data"), None, "model")

    def comb(first, second):
        a1, b1 = first
        a2, b2 = second
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(comb, (a, b), axis=1)
    h = Bc  # h0 = 0 so h_t = cumulative b
    y = jnp.einsum("bsr,rd->bsd", (gate * h).astype(x.dtype), p["w_out_r"])
    state = {"h": h[:, -1], "conv": conv_state}
    return y, state


def rglru_decode(p, cfg, x_t, state):
    """x_t: (B, 1, D)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x_t, p["w_gate_br"]).astype(F32))
    xi0 = jnp.einsum("bsd,dr->bsr", x_t, p["w_x"])
    xi, conv_state = _causal_conv(p, xi0, state["conv"])
    a, b = _rglru_gates(p, xi)  # (B,1,R)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = jnp.einsum("bsr,rd->bsd", (gate * h[:, None]).astype(x_t.dtype), p["w_out_r"])
    return y, {"h": h, "conv": conv_state}


def rglru_chunk(p, cfg, x, state):
    """Chunked decode/prefill step: x (B, C, D), state carried across calls.

    Projections, conv taps and gates are computed for the whole chunk in
    parallel (each is per-position with a fixed reduction order, so they
    are chunk-boundary invariant); the h recurrence runs as a sequential
    ``lax.scan`` whose step is exactly :func:`rglru_decode`'s update
    ``h = a_t * h + b_t``.  Splitting a sequence into chunks therefore
    composes BITWISE with feeding it whole — unlike the
    ``associative_scan`` training form, which regroups the products and
    is only allclose (tests/test_recurrent.py).  C = 1 reproduces
    ``rglru_decode`` bit for bit.
    """
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["w_gate_br"]).astype(F32))
    xi0 = jnp.einsum("bsd,dr->bsr", x, p["w_x"])
    xi, conv_state = _causal_conv(p, xi0, state["conv"])
    a, b = _rglru_gates(p, xi)  # (B,C,R)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h_last, hs = jax.lax.scan(
        step, state["h"], (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    h = jnp.moveaxis(hs, 0, 1)  # (B,C,R)
    y = jnp.einsum("bsr,rd->bsd", (gate * h).astype(x.dtype), p["w_out_r"])
    return y, {"h": h_last, "conv": conv_state}


# ======================================================================
# mLSTM (xLSTM matrix-memory cell), chunkwise-parallel training form.
def init_mlstm(rng, cfg):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    dt = _pdt(cfg)
    ks = jax.random.split(rng, 7)
    scd = 1.0 / math.sqrt(D)
    return {
        "wq": (jax.random.normal(ks[0], (D, H, dh)) * scd).astype(dt),
        "wk": (jax.random.normal(ks[1], (D, H, dh)) * scd).astype(dt),
        "wv": (jax.random.normal(ks[2], (D, H, dh)) * scd).astype(dt),
        "wf": (jax.random.normal(ks[3], (D, H)) * scd).astype(F32),
        "bf": jnp.linspace(3.0, 6.0, H).astype(F32),  # forget bias init
        "wi": (jax.random.normal(ks[4], (D, H)) * scd).astype(F32),
        "bi": jnp.full((H,), -3.0, F32),
        "w_ogate": (jax.random.normal(ks[5], (D, D)) * scd).astype(dt),
        "headnorm": jnp.ones((H, dh), F32),
        "out_proj": (jax.random.normal(ks[6], (D, D)) * scd
                     / math.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def init_mlstm_state(cfg, batch):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), F32),
        "n": jnp.zeros((batch, H, dh), F32),
        "m": jnp.zeros((batch, H), F32),
    }


def _mlstm_qkv_gates(p, cfg, xn):
    B, S, D = xn.shape
    H = cfg.n_heads
    dh = D // H
    q = jnp.einsum("bsd,dhj->bshj", xn, p["wq"]).astype(F32)
    k = jnp.einsum("bsd,dhj->bshj", xn, p["wk"]).astype(F32) / math.sqrt(dh)
    v = jnp.einsum("bsd,dhj->bshj", xn, p["wv"]).astype(F32)
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", xn.astype(F32), p["wf"]) + p["bf"])
    li = jnp.einsum("bsd,dh->bsh", xn.astype(F32), p["wi"]) + p["bi"]
    return q, k, v, lf, li


def mlstm_scan_core(q, k, v, lf, li, state, chunk):
    """Chunkwise-parallel stabilized mLSTM.  All inputs f32.

    q,k,v: (B,S,H,dh); lf,li: (B,S,H).  Returns (h (B,S,H,dh), state).
    Exactly equivalent to ``mlstm_recurrent_ref`` (same stabilizers).
    """
    B, S, H, dh = q.shape
    L = min(chunk, S)
    if S % L:
        L = S
    Nc = S // L

    def to_chunks(x):
        return x.reshape(B, Nc, L, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, lfc, lic = map(to_chunks, (q, k, v, lf, li))

    def chunk_step(carry, inp):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        q, k, v, lf, li = inp  # (B,L,H,*)
        b = jnp.cumsum(lf, axis=1)  # inclusive log-decay within chunk
        u = li - b
        g = jnp.maximum(m[:, None], jax.lax.cummax(u, axis=1))  # (B,L,H)
        m_j = b + g
        # inter-chunk numerator
        inter = jnp.einsum("blhk,bhkv->blhv", q, C) * jnp.exp(m[:, None] - g)[..., None]
        # intra-chunk: D_js = exp(u_s - g_j) for s<=j else 0
        scores = jnp.einsum("blhk,bshk->bhls", q, k)
        us = u.transpose(0, 2, 1)  # (B,H,L) over s
        gj = g.transpose(0, 2, 1)  # (B,H,L) over j
        Dmat = jnp.exp(us[:, :, None, :] - gj[:, :, :, None])  # (B,H,Lj,Ls)
        mask = jnp.tril(jnp.ones((L, L), bool))
        Dmat = jnp.where(mask[None, None], Dmat, 0.0)
        intra = jnp.einsum("bhls,bshv->blhv", scores * Dmat, v)
        num = inter + intra
        n_j = (n[:, None] * jnp.exp(m[:, None] - g)[..., None]
               + jnp.einsum("bhls,bshk->blhk", Dmat, k))
        qn = jnp.einsum("blhk,blhk->blh", q, n_j)
        den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_j))
        h = num / den[..., None]
        # chunk-final state (j = L-1 row of the same quantities)
        gL = g[:, -1]  # (B,H)
        scale_prev = jnp.exp(m - gL)
        w_s = jnp.exp(u - gL[:, None])  # (B,L,H); every s feeds the final state
        C_new = (C * scale_prev[..., None, None]
                 + jnp.einsum("blh,blhk,blhv->bhkv", w_s, k, v))
        n_new = n * scale_prev[..., None] + jnp.einsum("blh,blhk->bhk", w_s, k)
        m_new = b[:, -1] + gL
        return (C_new, n_new, m_new), h

    carry = (state["C"], state["n"], state["m"])
    carry, hs = jax.lax.scan(chunk_step, carry, (qc, kc, vc, lfc, lic))
    h = hs.swapaxes(0, 1).reshape(B, S, H, dh)
    C, n, m = carry
    return h, {"C": C, "n": n, "m": m}


def mlstm_recurrent_ref(q, k, v, lf, li, state):
    """Stabilized recurrent oracle (step-by-step).  f32 inputs."""
    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, lft, lit = inp  # (B,H,dh) / (B,H)
        m_t = jnp.maximum(lft + m, lit)
        fs = jnp.exp(lft + m - m_t)
        is_ = jnp.exp(lit - m_t)
        C = fs[..., None, None] * C + is_[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = fs[..., None] * n + is_[..., None] * kt
        num = jnp.einsum("bhk,bhkv->bhv", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n)),
                          jnp.exp(-m_t))
        return (C, n, m_t), num / den[..., None]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, lf, li))
    carry, hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    C, n, m = carry
    return jnp.moveaxis(hs, 0, 1), {"C": C, "n": n, "m": m}


def _mlstm_out(p, cfg, xn, h):
    B, S, D = xn.shape
    H = cfg.n_heads
    dh = D // H
    hf = h.astype(F32)
    ms = (hf * hf).mean(-1, keepdims=True)
    hn = hf * jax.lax.rsqrt(ms + 1e-6) * p["headnorm"]
    o = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xn, p["w_ogate"]).astype(F32))
    y = (hn.reshape(B, S, D) * o).astype(xn.dtype)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mlstm_train(p, cfg, x):
    q, k, v, lf, li = _mlstm_qkv_gates(p, cfg, x)
    state = init_mlstm_state(cfg, x.shape[0])
    h, state = mlstm_scan_core(q, k, v, lf, li, state, cfg.mlstm_chunk)
    return _mlstm_out(p, cfg, x, h), state


def mlstm_decode(p, cfg, x_t, state):
    q, k, v, lf, li = _mlstm_qkv_gates(p, cfg, x_t)
    h, state = mlstm_recurrent_ref(q, k, v, lf, li, state)
    return _mlstm_out(p, cfg, x_t, h), state


def mlstm_chunk(p, cfg, x, state):
    """Chunked decode/prefill step: x (B, C, D), state carried across calls.

    QKV/gate projections are chunk-parallel; the cell update runs through
    :func:`mlstm_recurrent_ref` — the stabilized sequential oracle — whose
    per-step carry composes exactly, so chunk boundaries never move a bit
    (the chunkwise-parallel ``mlstm_scan_core`` regroups the stabilizer
    maxima per L-block and is only allclose).  ``mlstm_decode`` already
    scans over S, so this is the same program; the alias exists so the
    per-mixer chunk entry points are uniform.
    """
    return mlstm_decode(p, cfg, x, state)


# ======================================================================
# sLSTM (xLSTM scalar-memory cell with hidden-to-hidden recurrence)
N_SGATES = 4  # z, i, f, o


def init_slstm(rng, cfg):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    dt = _pdt(cfg)
    ks = jax.random.split(rng, 4)
    scd = 1.0 / math.sqrt(D)
    sch = 1.0 / math.sqrt(dh)
    bias = jnp.zeros((N_SGATES, H, dh), F32)
    bias = bias.at[2].set(jnp.linspace(3.0, 6.0, H)[:, None])  # forget bias
    return {
        "w_gates_in": (jax.random.normal(ks[0], (D, N_SGATES, H, dh)) * scd).astype(dt),
        "r_gates": (jax.random.normal(ks[1], (N_SGATES, H, dh, dh)) * sch).astype(dt),
        "b_gates": bias,
        "headnorm": jnp.ones((H, dh), F32),
        "out_proj": (jax.random.normal(ks[2], (D, D)) * scd
                     / math.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def init_slstm_state(cfg, batch):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), F32)
    return {"h": z, "c": z, "n": z, "m": z}


def _slstm_step(p, carry, pre_in):
    """pre_in: (B, 4, H, dh) input contribution for one timestep."""
    h, c, n, m = carry
    rec = jnp.einsum("ghij,bhj->bghi",
                     p["r_gates"].astype(F32), h)
    pre = pre_in + rec + p["b_gates"][None]
    z = jnp.tanh(pre[:, 0])
    li = pre[:, 1]
    lf = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_t = jnp.maximum(lf + m, li)
    fs = jnp.exp(lf + m - m_t)
    is_ = jnp.exp(li - m_t)
    c_t = fs * c + is_ * z
    n_t = fs * n + is_
    h_t = o * c_t / jnp.maximum(n_t, jnp.exp(-m_t) + 1e-9)
    return (h_t, c_t, n_t, m_t), h_t


def slstm_train(p, cfg, x):
    B, S, D = x.shape
    pre = jnp.einsum("bsd,dghj->bsghj", x.astype(F32),
                     p["w_gates_in"].astype(F32))
    state0 = init_slstm_state(cfg, B)
    carry = (state0["h"], state0["c"], state0["n"], state0["m"])
    carry, hs = jax.lax.scan(lambda c, i: _slstm_step(p, c, i),
                             carry, jnp.moveaxis(pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)  # (B,S,H,dh)
    y = _slstm_out(p, cfg, x, h)
    hf, cf, nf, mf = carry
    return y, {"h": hf, "c": cf, "n": nf, "m": mf}


def slstm_decode(p, cfg, x_t, state):
    pre = jnp.einsum("bsd,dghj->bsghj", x_t.astype(F32),
                     p["w_gates_in"].astype(F32))[:, 0]
    carry = (state["h"], state["c"], state["n"], state["m"])
    carry, h_t = _slstm_step(p, carry, pre)
    y = _slstm_out(p, cfg, x_t, h_t[:, None])
    hf, cf, nf, mf = carry
    return y, {"h": hf, "c": cf, "n": nf, "m": mf}


def slstm_chunk(p, cfg, x, state):
    """Chunked decode/prefill step: x (B, C, D), state carried across calls.

    Gate pre-activations are chunk-parallel; the hidden-to-hidden
    recurrence scans :func:`_slstm_step` from the carried state (that IS
    the training scan, just seeded) — sequential composition makes chunk
    splits bitwise-invariant, and C = 1 reproduces ``slstm_decode``.
    """
    pre = jnp.einsum("bsd,dghj->bsghj", x.astype(F32),
                     p["w_gates_in"].astype(F32))
    carry = (state["h"], state["c"], state["n"], state["m"])
    carry, hs = jax.lax.scan(lambda c, i: _slstm_step(p, c, i),
                             carry, jnp.moveaxis(pre, 1, 0))
    y = _slstm_out(p, cfg, x, jnp.moveaxis(hs, 0, 1))
    hf, cf, nf, mf = carry
    return y, {"h": hf, "c": cf, "n": nf, "m": mf}


def _slstm_out(p, cfg, x, h):
    B, S, D = x.shape
    hf = h.astype(F32)
    ms = (hf * hf).mean(-1, keepdims=True)
    hn = (hf * jax.lax.rsqrt(ms + 1e-6) * p["headnorm"]).reshape(B, S, D)
    return jnp.einsum("bsd,de->bse", hn.astype(x.dtype), p["out_proj"])
