"""Half-Quadratic Quantization (HQQ, Badri & Shaji 2023) in JAX.

Data-free group-wise affine quantization with half-quadratic (proximal)
optimization of the zero-point under an l_p (p<1) residual norm — the
scheme the paper uses for mixed MoE quantization (section 3.3 / Table 1):

* experts at 2-3 bit, attention/shared layers at 4 bit;
* group sizes per the paper: 4-bit g=64 (scale group 256),
  3-bit g=64 (scale group 128), 2-bit g=16 (scale group 128);
* quantized storage also carries per-group scale/zero, themselves
  meta-quantized to 8-bit over ``scale_group``-sized groups — this is why
  the paper's "2-bit" scheme really costs ~2.6-3 bits/param, which
  :func:`bits_per_param` reports exactly.

Layout: weights are grouped along the **contraction axis** K of a
``(..., K, N)`` matrix — ``(..., G, g, N)`` with scale/zero ``(..., G, 1, N)``
— matching the Pallas ``dequant_matmul`` kernel's expectations (scales vary
along the K loop, MXU-friendly N stays dense).  Sub-byte codes pack along
``g``: 4-bit 2/byte, 2-bit 4/byte, 3-bit 8 codes in 3 bytes.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# paper's group-size table (section 4.2)
PAPER_SCHEMES = {
    16: dict(bits=16, group_size=None, scale_group=None),
    8: dict(bits=8, group_size=64, scale_group=256),
    4: dict(bits=4, group_size=64, scale_group=256),
    3: dict(bits=3, group_size=64, scale_group=128),
    2: dict(bits=2, group_size=16, scale_group=128),
}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QTensor:
    """Packed quantized tensor. ``packed``: uint8 (..., G, g*bits//8, N)."""

    packed: jnp.ndarray
    scale: jnp.ndarray  # (..., G, 1, N) float16 (or meta-quantized uint8)
    zero: jnp.ndarray
    meta: Optional[dict]  # scale/zero meta-quant params or None
    bits: int
    group_size: int
    shape: Tuple[int, ...]  # original (..., K, N)

    def tree_flatten(self):
        children = (self.packed, self.scale, self.zero, self.meta)
        aux = (self.bits, self.group_size, self.shape)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale, zero, meta = children
        bits, group_size, shape = aux
        return cls(packed, scale, zero, meta, bits, group_size, shape)


# ----------------------------------------------------------------------
# bit packing along axis -2 (the ``g`` axis of (..., G, g, N))
def pack_codes(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    q = q.astype(jnp.uint8)
    if bits == 8:
        return q
    if bits == 4:
        return q[..., 0::2, :] | (q[..., 1::2, :] << 4)
    if bits == 2:
        return (q[..., 0::4, :] | (q[..., 1::4, :] << 2)
                | (q[..., 2::4, :] << 4) | (q[..., 3::4, :] << 6))
    if bits == 3:
        g = q.shape[-2]
        assert g % 8 == 0, "3-bit packing needs g % 8 == 0"
        qi = q.astype(jnp.uint32)
        octets = [qi[..., i::8, :] for i in range(8)]
        word = sum(o << (3 * i) for i, o in enumerate(octets))  # 24 bits
        b0 = (word & 0xFF).astype(jnp.uint8)
        b1 = ((word >> 8) & 0xFF).astype(jnp.uint8)
        b2 = ((word >> 16) & 0xFF).astype(jnp.uint8)
        return jnp.concatenate([b0, b1, b2], axis=-2)
    raise ValueError(f"unsupported bits={bits}")


def unpack_codes(p: jnp.ndarray, bits: int, g: int) -> jnp.ndarray:
    if bits == 8:
        return p
    if bits == 4:
        lo = p & 0x0F
        hi = p >> 4
        return _interleave([lo, hi], g)
    if bits == 2:
        parts = [(p >> (2 * i)) & 0x03 for i in range(4)]
        return _interleave(parts, g)
    if bits == 3:
        n8 = g // 8
        b0 = p[..., :n8, :].astype(jnp.uint32)
        b1 = p[..., n8: 2 * n8, :].astype(jnp.uint32)
        b2 = p[..., 2 * n8:, :].astype(jnp.uint32)
        word = b0 | (b1 << 8) | (b2 << 16)
        parts = [((word >> (3 * i)) & 0x7).astype(jnp.uint8) for i in range(8)]
        return _interleave(parts, g)
    raise ValueError(f"unsupported bits={bits}")


def _interleave(parts, g):
    # parts[i] holds codes at positions i::len(parts) along axis -2;
    # original index j = c*P + i, so (c, i) merges c-major.
    stacked = jnp.stack(parts, axis=-2)  # (..., C, P, N)
    sh = stacked.shape
    return stacked.reshape(sh[:-3] + (g,) + sh[-1:])


# ----------------------------------------------------------------------
def _shrink_lp(x, beta, p):
    """Generalized soft-threshold (HQQ proximal operator for l_p, p<1)."""
    return jnp.sign(x) * jax.nn.relu(
        jnp.abs(x) - (1.0 / beta) * jnp.power(jnp.abs(x) + 1e-8, p - 1.0))


@partial(jax.jit, static_argnames=("bits", "group_size", "iters"))
def _quantize_groups(wg, bits, group_size, iters, lp=0.7, beta0=10.0,
                     kappa=1.01):
    """wg: (..., G, g, N) f32 -> (codes u8, scale, zero) with HQQ zero opt."""
    maxv = 2.0 ** bits - 1.0
    wmin = wg.min(axis=-2, keepdims=True)
    wmax = wg.max(axis=-2, keepdims=True)
    scale = (wmax - wmin) / maxv
    scale = jnp.where(scale <= 1e-8, 1.0, scale)
    zero = -wmin / scale  # code-space zero point

    def body(carry, i):
        zero, beta = carry
        q = jnp.clip(jnp.round(wg / scale + zero), 0, maxv)
        wr = (q - zero) * scale
        we = _shrink_lp(wg - wr, beta, lp)
        zero = jnp.mean(q - (wg - we) / scale, axis=-2, keepdims=True)
        return (zero, beta * kappa), ()

    (zero, _), _ = jax.lax.scan(body, (zero, beta0), jnp.arange(iters))
    q = jnp.clip(jnp.round(wg / scale + zero), 0, maxv).astype(jnp.uint8)
    return q, scale.astype(jnp.float32), zero.astype(jnp.float32)


def quantize(w: jnp.ndarray, bits: int, group_size: Optional[int] = None,
             scale_group: Optional[int] = None, iters: int = 20) -> QTensor:
    """Quantize ``w (..., K, N)`` grouped along K.  bits in {2,3,4,8}."""
    scheme = PAPER_SCHEMES[bits]
    group_size = group_size or scheme["group_size"]
    scale_group = scale_group if scale_group is not None else scheme["scale_group"]
    *lead, K, N = w.shape
    assert K % group_size == 0, (K, group_size)
    G = K // group_size
    wg = w.reshape(*lead, G, group_size, N).astype(jnp.float32)
    q, scale, zero = _quantize_groups(wg, bits, group_size, iters)
    packed = pack_codes(q, bits)
    meta = None
    if scale_group:
        scale, zero, meta = _meta_quantize(scale, zero, scale_group)
    else:
        scale = scale.astype(jnp.float16)
        zero = zero.astype(jnp.float16)
    return QTensor(packed, scale, zero, meta, bits, group_size, tuple(w.shape))


def _meta_quantize(scale, zero, scale_group):
    """8-bit meta-quantization of the per-group scale/zero (paper's
    'scale group size'). Groups along the G axis."""
    def mq(a):
        *lead, G, one, N = a.shape
        sg = min(scale_group, G)
        while G % sg:
            sg //= 2
        M = G // sg
        ar = a.reshape(*lead, M, sg, one, N)
        mn = ar.min(axis=-3, keepdims=True)
        mx = ar.max(axis=-3, keepdims=True)
        s = jnp.where(mx - mn <= 1e-12, 1.0, (mx - mn) / 255.0)
        q = jnp.clip(jnp.round((ar - mn) / s), 0, 255).astype(jnp.uint8)
        return q, s.astype(jnp.float16), mn.astype(jnp.float16)

    sq, ss, sm = mq(scale)
    zq, zs, zm = mq(zero)
    meta = {"s_scale": ss, "s_min": sm, "z_scale": zs, "z_min": zm}
    return sq, zq, meta


def _meta_dequantize(qt: QTensor):
    if qt.meta is None:
        return qt.scale.astype(jnp.float32), qt.zero.astype(jnp.float32)

    def dq(q, s, m):
        a = q.astype(jnp.float32) * s.astype(jnp.float32) + m.astype(jnp.float32)
        sh = q.shape
        return a.reshape(*sh[:-4], sh[-4] * sh[-3], sh[-2], sh[-1])

    scale = dq(qt.scale, qt.meta["s_scale"], qt.meta["s_min"])
    zero = dq(qt.zero, qt.meta["z_scale"], qt.meta["z_min"])
    return scale, zero


def dequantize(qt: QTensor, dtype=jnp.float32) -> jnp.ndarray:
    scale, zero = _meta_dequantize(qt)
    g = qt.group_size
    q = unpack_codes(qt.packed, qt.bits, g).astype(jnp.float32)
    w = (q - zero) * scale
    return w.reshape(qt.shape).astype(dtype)


def slice_leading(qt: QTensor, idx) -> QTensor:
    """Index a stacked :class:`QTensor` along its leading (batch) axes.

    ``quantize`` of a ``(..., K, N)`` weight keeps every leading axis on
    all its leaves, so a stack of homogeneous weights (e.g. the packed
    expert store's ``(L, E, K, N)``) is itself one QTensor; this returns
    the sub-QTensor at ``idx`` (an int/scalar or tuple of them — traced
    scalars are fine, making per-slot gathers jittable).  All quantization
    math is elementwise per leading slice, so slicing commutes bitwise
    with pack/dequant.
    """
    if not isinstance(idx, tuple):
        idx = (idx,)
    nd = len(idx)
    assert nd < len(qt.shape) - 1, (idx, qt.shape)
    meta = None if qt.meta is None else {k: v[idx] for k, v in qt.meta.items()}
    return QTensor(qt.packed[idx], qt.scale[idx], qt.zero[idx], meta,
                   qt.bits, qt.group_size, tuple(qt.shape[nd:]))


# ----------------------------------------------------------------------
# size accounting (Table 1)
def nbytes(qt: QTensor) -> int:
    n = qt.packed.size  # uint8
    for a in (qt.scale, qt.zero):
        n += a.size * a.dtype.itemsize
    if qt.meta:
        for a in qt.meta.values():
            n += a.size * a.dtype.itemsize
    return int(n)


def bits_per_param(qt: QTensor) -> float:
    return 8.0 * nbytes(qt) / math.prod(qt.shape)


def quant_error(w, qt) -> dict:
    wd = dequantize(qt)
    err = jnp.abs(wd - w.astype(jnp.float32))
    rel = jnp.linalg.norm(wd - w) / (jnp.linalg.norm(w) + 1e-9)
    return {"max_abs": float(err.max()), "rel_fro": float(rel),
            "bits_per_param": bits_per_param(qt)}


# ----------------------------------------------------------------------
# model-level helpers
def dense_nbytes(tree, bytes_per_el=2) -> int:
    return sum(l.size * bytes_per_el for l in jax.tree.leaves(tree))


def quantize_tree(tree, bits, **kw):
    """Quantize every >=2D leaf of a param subtree (K = axis -2)."""
    def q(leaf):
        if leaf.ndim >= 2 and leaf.shape[-2] % (kw.get("group_size")
                                                or PAPER_SCHEMES[bits]["group_size"]) == 0:
            return quantize(leaf, bits, **kw)
        return leaf  # small/odd leaves stay fp16

    return jax.tree.map(q, tree)


def dequantize_tree(tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda l: dequantize(l, dtype) if isinstance(l, QTensor) else l,
        tree, is_leaf=lambda l: isinstance(l, QTensor))


def tree_nbytes(tree) -> int:
    total = 0
    for l in jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, QTensor)):
        if isinstance(l, QTensor):
            total += nbytes(l)
        else:
            total += l.size * 2  # fp16 storage for unquantized leaves
    return total
