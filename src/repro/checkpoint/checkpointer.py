"""Flat-npz checkpointing with path-keyed leaves.

Restores into an arbitrary target structure (``jax.eval_shape`` template),
casting and device-putting with the target's sharding when given — enough
to restore a CPU-trained model onto a production mesh.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def save(path: str, params, meta: Optional[dict] = None) -> None:
    flat = _flatten(params)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    np.savez(tmp, __meta__=json.dumps(meta or {}), **flat)
    written = tmp if tmp.endswith(".npz") else tmp + ".npz"
    os.replace(written, path)
    if os.path.exists(tmp):
        os.remove(tmp)


def load_meta(path: str) -> dict:
    with np.load(path, allow_pickle=False) as z:
        return json.loads(str(z["__meta__"]))


def restore(path: str, template) -> Any:
    """template: pytree of arrays or ShapeDtypeStructs (eval_shape)."""
    with np.load(path, allow_pickle=False) as z:
        flat = {k: z[k] for k in z.files if k != "__meta__"}

    def rebuild(tmpl, prefix=""):
        if isinstance(tmpl, dict):
            return {k: rebuild(v, f"{prefix}/{k}") for k, v in tmpl.items()}
        if isinstance(tmpl, (list, tuple)):
            return type(tmpl)(rebuild(v, f"{prefix}/{i}")
                              for i, v in enumerate(tmpl))
        arr = flat[prefix]
        if arr.shape != tuple(tmpl.shape):
            raise ValueError(f"{prefix}: checkpoint {arr.shape} != "
                             f"template {tmpl.shape}")
        out = jnp.asarray(arr, dtype=tmpl.dtype)
        shard = getattr(tmpl, "sharding", None)
        if shard is not None and not isinstance(
                shard, jax.sharding.SingleDeviceSharding):
            out = jax.device_put(out, shard)
        return out

    return rebuild(template)
