"""Hand-rolled AdamW with cosine schedule and global-norm clipping
(optax is not installed offline; this mirrors its semantics and is
unit-tested against closed-form expectations)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    # bf16 moments halve optimizer HBM — used for the 104B dry-run config
    # (precision note in EXPERIMENTS.md); math still runs in f32.
    moment_dtype: str = "float32"


def schedule(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_opt_state(params, cfg: "OptimizerConfig" = None) -> dict:
    dt = jnp.dtype(cfg.moment_dtype) if cfg else jnp.float32
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros_like(a, dt), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt_state, cfg: OptimizerConfig
                  ) -> Tuple[Any, dict, dict]:
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return newp.astype(p.dtype), mu.astype(mdt), nu.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
