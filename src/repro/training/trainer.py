"""Training loop: loss, train_step (jit/pjit-able), and a simple driver.

``train_step`` is the same function the multi-pod dry-run lowers at full
scale, so the training path exercised here on CPU is exactly the one that
would run on the production mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.training import optimizer as O


def loss_fn(params, cfg: ModelConfig, batch, remat: bool = False):
    logits, aux = T.forward_train(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce
    metrics = {"ce": ce}
    if cfg.moe is not None:
        n_moe = max(1, cfg.moe_layer_count)
        lb = aux["load_balance"] / n_moe
        loss = loss + cfg.moe.aux_loss_weight * lb
        metrics["load_balance"] = lb
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: O.OptimizerConfig,
                    microbatches: int = 1, remat: bool = False):
    """Standard step, with optional gradient accumulation over
    ``microbatches`` (scan) + per-period activation checkpointing — the
    memory knobs that let the 104B config fit 16GB/chip in the dry-run."""

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch, remat)
        else:
            def split(a):
                B = a.shape[0]
                assert B % microbatches == 0
                return a.reshape(microbatches, B // microbatches, *a.shape[1:])

            micro = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(params, cfg, mb, remat)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), ()

            g0 = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
            m0 = {"ce": jnp.zeros((), jnp.float32),
                  "loss": jnp.zeros((), jnp.float32)}
            if cfg.moe is not None:
                m0["load_balance"] = jnp.zeros((), jnp.float32)
            (grads, metrics), _ = jax.lax.scan(accum, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        params, opt_state, opt_metrics = O.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, cfg, batch)
        return metrics

    return eval_step


@dataclass
class TrainerConfig:
    steps: int = 200
    log_every: int = 10
    eval_every: int = 100
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0


def train(params, cfg: ModelConfig, opt_cfg: O.OptimizerConfig,
          batches: Iterable[Dict[str, np.ndarray]],
          tcfg: TrainerConfig,
          eval_batches: Optional[Callable[[], Iterable]] = None,
          log: Callable[[str], None] = print):
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    eval_fn = jax.jit(make_eval_step(cfg))
    opt_state = O.init_opt_state(params)
    history = []
    t0 = time.perf_counter()
    it = iter(batches)
    for step in range(tcfg.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            log(f"step {step:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f} "
                f"({m['wall_s']:.0f}s)")
        if (eval_batches is not None and tcfg.eval_every
                and step and step % tcfg.eval_every == 0):
            evs = [float(eval_fn(params, {k: jnp.asarray(v)
                                          for k, v in b.items()})["ce"])
                   for b in eval_batches()]
            log(f"  eval ce {np.mean(evs):.4f}")
        if (tcfg.checkpoint_path and tcfg.checkpoint_every
                and step and step % tcfg.checkpoint_every == 0):
            from repro.checkpoint.checkpointer import save
            save(tcfg.checkpoint_path, params,
                 meta={"step": step, "config": cfg.name})
    return params, opt_state, history


def eval_ce(params, cfg: ModelConfig, batches) -> float:
    eval_fn = jax.jit(make_eval_step(cfg))
    vals = [float(eval_fn(params, {k: jnp.asarray(v) for k, v in b.items()})["ce"])
            for b in batches]
    return float(np.mean(vals))
