"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig2_lru,...]

Prints ``name,us_per_call,derived`` CSV; JSON artifacts land in
experiments/bench/.  First run trains the tiny-moe artifact (~minutes);
subsequent runs hit the cache.
"""
from __future__ import annotations

import argparse
import sys
import time

SUITES = ["fig2_lru", "fig2_spec", "table1_quant", "table2_speed",
          "kernels", "serve"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes/grids for CI")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of suites")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig2_lru, fig2_spec, kernels_bench, serve_bench,
                            table1_quant, table2_speed)

    mods = {"fig2_lru": fig2_lru, "fig2_spec": fig2_spec,
            "table1_quant": table1_quant, "table2_speed": table2_speed,
            "kernels": kernels_bench, "serve": serve_bench}
    print("name,us_per_call,derived")
    failures = []
    for name in SUITES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mods[name].run(quick=args.quick)
            print(f"# [{name}] done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception as e:  # keep the harness going
            failures.append((name, repr(e)))
            print(f"# [{name}] FAILED: {e!r}", file=sys.stderr)
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
