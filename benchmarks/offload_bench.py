"""Packed offloaded decode: vectorized overlap-pipelined stream vs the
PR-2 synchronous per-(token, k) data plane (DESIGN.md §7).

Three engine variants decode the same prompt over the same HQQ-packed
store, all bitwise-identical to the dequantized-model oracle (asserted):

* ``pr2_sync``   — the PR-2 baseline: unrolled per-(token, k) slot swaps
  + T*K separate dequant-matmul calls, staging serialized inside the
  per-block jitted program (``pipelined=False, vectorized=False``).
* ``vectorized`` — batched gather/scatter slot plans + one batched
  dequant-matmul dispatch per matrix, staging still synchronous
  (``pipelined=False``).
* ``pipelined``  — the default engine: vectorized plane + speculative
  staging dispatched asynchronously outside the jitted block, fencing
  only at the lookahead layer's ``acquire``.

Reported per variant: compile (first-generate) seconds, steady-state
decode tokens/s with p50/p95 per-token latency, and measured h2d
bytes/token + hit ratio.  The traffic counters must agree across
variants — the data-plane refactor changes *how* bytes move, never how
many.

A fourth scenario, ``speculative`` (DESIGN.md §11), reruns the
pipelined plane at a high cache hit ratio (cache 6/8 experts, ~0.9)
with token-level draft-and-verify decoding against a replay draft at
acceptance 1.0: one C = k+1 verify chunk emits k+1 tokens, so per-token
dispatch overhead and expert traffic amortize.  Output stays bitwise
the oracle's (asserted), generation h2d must not exceed the
non-speculative baseline's (asserted), and the full run asserts the
>= 1.3x decode-throughput acceptance bar.

Results persist to ``experiments/bench/offload_bench.json`` AND the
repo-root ``BENCH_offload.json`` so the perf trajectory is trackable
across PRs.

    PYTHONPATH=src python -m benchmarks.offload_bench [--smoke] [--trained]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from benchmarks.common import emit

from repro.configs import get_config
from repro.core.offload_engine import (OffloadEngine, generate_plain,
                                       quantize_for_offload)
from repro.models import transformer as T

ROOT = Path(__file__).resolve().parents[1]

VARIANTS = {
    "pr2_sync": dict(pipelined=False, vectorized=False),
    "vectorized": dict(pipelined=False, vectorized=True),
    "pipelined": dict(pipelined=True, vectorized=True),
}


def run(smoke=False, trained=False, max_new=None, seed=0):
    cfg = get_config("tiny-moe")
    if trained:
        from benchmarks.common import get_trained_tiny_moe
        params, cfg = get_trained_tiny_moe()
    else:
        params = T.init_model(jax.random.key(seed), cfg)
    spec = cfg.offload
    max_new = max_new or (8 if smoke else 48)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab_size, (1, 12)).astype(np.int32)

    qdeq, _ = quantize_for_offload(params, cfg, spec)
    oracle = generate_plain(qdeq, cfg, prompt, max_new)

    # pre-warm the executables ALL variants share through the cfg-keyed
    # jit cache (embed/head, layerwise packed prefill): a distinct mode
    # compiles its own block programs but leaves the shared ones hot, so
    # each variant's first-generate time below reflects only its own
    # data-plane programs, not cache-warmup ordering
    warm = OffloadEngine(params, cfg, spec, quantized=True,
                         pipelined=False, vectorized=True, fused=False)
    warm.generate(prompt, max_new)

    results = []
    traffic = {}
    for name, kw in VARIANTS.items():
        import jax.numpy as jnp

        eng = OffloadEngine(params, cfg, spec, quantized=True, **kw)
        t0 = time.perf_counter()
        out, stats = eng.generate(prompt, max_new)  # compiles the variant
        t_compile = time.perf_counter() - t0
        assert (out == oracle).all(), f"{name}: diverged from oracle"
        traffic[name] = (stats.hits, stats.spec_hits, stats.demand_loads,
                         stats.spec_loads)
        # row fields come from the telemetry registry (the same snapshot
        # --metrics-json writes); the returned OffloadStats must agree
        # exactly — a drift here means the collector and the engine's own
        # accounting diverged (DESIGN.md §10)
        om = eng.metrics()["offload"]
        assert (om["hits"], om["spec_hits"], om["demand_loads"],
                om["spec_loads"]) == traffic[name], \
            f"{name}: registry drifted from OffloadStats: {om}"
        assert om["bytes_h2d"] == stats.bytes_h2d
        bpt = om["bytes_per_token"]
        # steady-state decode: time the jitted token loop alone (prefill
        # and pool-state init are identical across variants)
        dec = eng._decoder  # the packed-plane runtime Executor
        ps = dec.init_pool_state()
        logits, state, _ = dec.prefill(jnp.asarray(prompt),
                                       prompt.shape[1] + max_new + 4)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        for _ in range(2):  # warm donation buffers
            logits, state, ps, _ = dec.decode(state, tok, ps)
        jax.block_until_ready(logits)
        lat_ms = []
        t0 = time.perf_counter()
        for _ in range(max_new):
            t1 = time.perf_counter()
            logits, state, ps, _ = dec.decode(state, tok, ps)
            jax.block_until_ready(logits)
            lat_ms.append((time.perf_counter() - t1) * 1e3)
        t_gen = time.perf_counter() - t0
        results.append({
            "name": "offload_bench", "variant": name,
            "max_new": max_new,
            "first_gen_s": round(t_compile, 3),  # variant's jit + 1 gen
            "decode_ms_per_token": round(t_gen / max_new * 1e3, 2),
            "p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
            "p95_ms": round(float(np.percentile(lat_ms, 95)), 2),
            "tok_s": round(max_new / t_gen, 2),
            "bytes_per_token": round(bpt, 1),
            "hit_ratio": round(stats.hit_ratio, 4),
        })
        print(f"[offload_bench] {name:10s}: {max_new / t_gen:8.2f} tok/s "
              f"decode ({t_gen / max_new * 1e3:6.1f} ms/token, "
              f"p50/p95 {np.percentile(lat_ms, 50):.1f}/"
              f"{np.percentile(lat_ms, 95):.1f}ms, first gen "
              f"{t_compile:6.1f}s, {bpt / 1e3:.1f}KB/token h2d, "
              f"hit_ratio={stats.hit_ratio:.3f})")
    assert len(set(traffic.values())) == 1, \
        f"variants disagree on transfer counters: {traffic}"
    base = next(r for r in results if r["variant"] == "pr2_sync")
    pipe = next(r for r in results if r["variant"] == "pipelined")
    speedup = pipe["tok_s"] / base["tok_s"]
    compile_speedup = base["first_gen_s"] / max(1e-9, pipe["first_gen_s"])
    print(f"[offload_bench] decode speedup (pipelined vs pr2_sync): "
          f"{speedup:.2f}x; first-generate (compile) {compile_speedup:.2f}x "
          f"faster")
    results.append({"name": "offload_bench", "variant": "summary",
                    "speedup": round(speedup, 3),
                    "compile_speedup": round(compile_speedup, 3)})

    # ------------------------------------------------------------------
    # speculative scenario (DESIGN.md §11): pipelined plane, cache 6/8
    # experts (hit_ratio ~0.9), replay draft at acceptance 1.0
    import dataclasses

    from repro.core.draft import ReplayDraft

    k = 4
    spec_hi = dataclasses.replace(spec, cache_size=6)
    ref = np.concatenate([prompt[0], oracle[0]])  # same packed weights:
    # expert/attn bits are unchanged, so the dequantized oracle is too
    eng = OffloadEngine(params, cfg, spec_hi, quantized=True)

    def timed_gen(**kw):
        out, stats = eng.generate(prompt, max_new, **kw)  # compile pass
        assert (out == oracle).all(), "speculative scenario: diverged"
        t0 = time.perf_counter()
        out, stats = eng.generate(prompt, max_new, **kw)
        t = time.perf_counter() - t0
        assert (out == oracle).all(), "speculative scenario: diverged"
        return t, stats

    t_base, s_base = timed_gen()
    mk = lambda: ReplayDraft(ref, vocab_size=cfg.vocab_size)  # noqa: E731
    t_spec, s_spec = timed_gen(draft=mk(), num_draft_tokens=k)
    assert s_spec.bytes_h2d <= s_base.bytes_h2d, \
        f"speculation increased generation h2d at acceptance 1.0: " \
        f"{s_spec.bytes_h2d} > {s_base.bytes_h2d}"
    sm = eng.obs.snapshot()["spec"]
    spec_speedup = t_base / t_spec
    for variant, t, stats in (("spec_baseline", t_base, s_base),
                              ("speculative", t_spec, s_spec)):
        results.append({
            "name": "offload_bench", "variant": variant,
            "max_new": max_new, "num_draft_tokens": 0 if t is t_base else k,
            "decode_ms_per_token": round(t / max_new * 1e3, 2),
            "tok_s": round(max_new / t, 2),
            "bytes_per_token": round(stats.bytes_h2d / max(1, stats.n_tokens), 1),
            "hit_ratio": round(stats.hit_ratio, 4),
        })
        print(f"[offload_bench] {variant:13s}: {max_new / t:8.2f} tok/s "
              f"decode ({t / max_new * 1e3:6.1f} ms/token, "
              f"hit_ratio={stats.hit_ratio:.3f}, "
              f"h2d={stats.bytes_h2d / 1e6:.2f}MB)")
    print(f"[offload_bench] speculative speedup (k={k}, acceptance "
          f"{sm['acceptance_rate']:.2f}): {spec_speedup:.2f}x over "
          f"non-speculative pipelined at hit_ratio="
          f"{s_base.hit_ratio:.3f}")
    results.append({"name": "offload_bench", "variant": "spec_summary",
                    "num_draft_tokens": k,
                    "acceptance_rate": round(sm["acceptance_rate"], 3),
                    "hit_ratio": round(s_base.hit_ratio, 4),
                    "spec_speedup": round(spec_speedup, 3)})
    if not smoke:
        assert spec_speedup >= 1.3, \
            f"speculative decode speedup {spec_speedup:.2f}x below the " \
            f"1.3x acceptance bar"

    # ------------------------------------------------------------------
    # router top-k ablation (serve --top-k-override): routing each token
    # to 1 expert instead of the arch default shrinks the per-token
    # expert working set, so the LRU misses less and the offloaded
    # decode streams fewer bytes — the traffic drop the CLI flag buys
    from repro.launch.serve import resolve_top_k

    assert cfg.moe.top_k > 1, "top-k ablation needs a multi-expert router"
    eng_k1 = OffloadEngine(params, resolve_top_k(cfg, 1), spec,
                           quantized=True)
    _, s_k1 = eng_k1.generate(prompt, max_new)
    bpt_k1 = s_k1.bytes_h2d / max(1, s_k1.n_tokens)
    bpt_base = pipe["bytes_per_token"]  # same engine class/spec/prompt
    assert bpt_k1 < bpt_base, \
        f"k=1 routing must cut h2d traffic: {bpt_k1:.0f} >= {bpt_base:.0f}"
    results.append({
        "name": "offload_bench", "variant": "top_k_override",
        "top_k": 1, "arch_top_k": cfg.moe.top_k, "max_new": max_new,
        "bytes_per_token": round(bpt_k1, 1),
        "baseline_bytes_per_token": round(bpt_base, 1),
        "h2d_savings_ratio": round(bpt_base / max(1e-9, bpt_k1), 3),
        "hit_ratio": round(s_k1.hit_ratio, 4),
    })
    print(f"[offload_bench] top_k_override: k=1 h2d "
          f"{bpt_k1 / 1e3:.1f}KB/token vs k={cfg.moe.top_k} "
          f"{bpt_base / 1e3:.1f}KB/token "
          f"({bpt_base / max(1e-9, bpt_k1):.2f}x less traffic)")

    emit(results, "offload_bench")
    (ROOT / "BENCH_offload.json").write_text(json.dumps(results, indent=1))
    print("[offload_bench] wrote BENCH_offload.json")
    if smoke:
        # smoke asserts structure, not margins (CI machines are noisy) —
        # but the vectorized plane must at least not be slower than the
        # unrolled one by more than jitter
        assert speedup > 0.5, "smoke: pipelined path unreasonably slow"
        assert spec_speedup > 0.5, "smoke: speculative path unreasonably slow"
        print("[offload_bench] smoke OK")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run for CI (asserts parity + sanity)")
    ap.add_argument("--trained", action="store_true",
                    help="use the trained tiny-moe artifact (realistic "
                         "routing locality; trains + caches on first use)")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, trained=args.trained, max_new=args.max_new,
        seed=args.seed)


if __name__ == "__main__":
    main()
