"""Paper Table 1: mixed quantization grid — (attn bits x expert bits) ->
quality + model size.

Quality here is held-out byte cross-entropy of the trained tiny-moe with
the HQQ-quantized weights (WikiText2/C4/MMLU are not available offline;
the *structure* — quality monotone in bits, experts cheaper to quantize
than attention — is the reproduced claim).  Sizes are reported both at
tiny scale (measured packed bytes) and projected to Mixtral-8x7B dims
(the paper's 86.99 -> 17.3 GB column)."""
from __future__ import annotations

import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core.offload_engine import quantize_for_offload
from repro.core.cost_model import EFFECTIVE_BITS
from repro.data.pipeline import DataConfig, PackedDataset
from repro.training.trainer import eval_ce

from benchmarks.common import emit, get_trained_tiny_moe


def mixtral_size_gb(attn_bits, expert_bits):
    """Project the scheme to Mixtral-8x7B parameter counts (Table 1)."""
    cfg = get_config("mixtral-8x7b")
    from repro.models.transformer import count_params_analytic

    total = count_params_analytic(cfg)
    experts = cfg.moe_layer_count * cfg.moe.num_experts * 3 * cfg.d_model * cfg.d_ff
    emb = cfg.vocab_size * cfg.d_model  # embeddings stay fp16 (tied)
    attn = total - experts - emb
    gb = (experts * EFFECTIVE_BITS[expert_bits] / 8
          + attn * EFFECTIVE_BITS[attn_bits] / 8 + emb * 2) / 1e9
    return gb


def run(quick=False):
    params, cfg = get_trained_tiny_moe()
    ds = PackedDataset(DataConfig(seq_len=128, batch_size=8,
                                  max_bytes=2_000_000))
    eval_b = list(ds.eval_batches(2 if quick else 4))
    rows = []
    grid_attn = [16, 4] if quick else [16, 4, 3, 2]
    grid_exp = [16, 4, 2] if quick else [16, 4, 3, 2]
    base_ce = eval_ce(params, cfg, eval_b)
    for ab in grid_attn:
        for eb in grid_exp:
            if ab == 16 and eb == 16:
                ce, sizes = base_ce, None
            else:
                spec = OffloadSpec(expert_bits=eb if eb != 16 else 8,
                                   attn_bits=ab if ab != 16 else 8)
                # 16 means "skip quantizing" — emulate by very high bits
                qp, sizes = quantize_for_offload(params, cfg, spec)
                if eb == 16:
                    qp = _restore_subtree(qp, params, "experts")
                if ab == 16:
                    qp = _restore_attn(qp, params)
                ce = eval_ce(qp, cfg, eval_b)
            gb = mixtral_size_gb(ab, eb)
            rows.append({
                "name": f"table1_attn{ab}_exp{eb}",
                "us_per_call": "",
                "derived": f"ce={ce:.4f};mixtral_gb={gb:.2f}",
                "attn_bits": ab, "expert_bits": eb,
                "eval_ce": ce, "mixtral_proj_gb": gb,
                "delta_ce_vs_fp": ce - base_ce,
            })
            print(f"[table1] attn={ab} exp={eb}: ce {ce:.4f} "
                  f"(+{ce-base_ce:.4f}) mixtral {gb:.1f}GB")
    # structural claims from the paper's Table 1
    get = lambda ab, eb: next(r for r in rows if r["attn_bits"] == ab
                              and r["expert_bits"] == eb)
    checks = []
    if not quick:
        # quality monotone in expert bits at fixed attn bits
        checks.append(("table1_exp_bits_monotone",
                       get(4, 2)["eval_ce"] >= get(4, 4)["eval_ce"] - 1e-3))
        # expert quantization cheaper than attention quantization:
        # (attn4,exp16) should cost less quality than (attn16,exp4) costs
        # RELATIVE to bytes saved — report the two deltas for the writeup
        checks.append(("table1_attn4exp16_delta",
                       round(get(4, 16)["delta_ce_vs_fp"], 4)))
        checks.append(("table1_attn16exp4_delta",
                       round(get(16, 4)["delta_ce_vs_fp"], 4)))
    for nm, val in checks:
        rows.append({"name": nm, "derived": str(val)})
    emit(rows, "table1_quant")
    return rows


def _restore_subtree(qtree, orig, key):
    def walk(a, b, path):
        if isinstance(a, dict):
            return {k: walk(a[k], b[k], path + (k,)) for k in a}
        if isinstance(a, (list, tuple)):
            return type(a)(walk(x, y, path + (str(i),))
                           for i, (x, y) in enumerate(zip(a, b)))
        return b if key in path else a
    return walk(qtree, orig, ())


def _restore_attn(qtree, orig):
    names = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")

    def walk(a, b, path):
        if isinstance(a, dict):
            return {k: walk(a[k], b[k], path + (k,)) for k in a}
        if isinstance(a, (list, tuple)):
            return type(a)(walk(x, y, path + (str(i),))
                           for i, (x, y) in enumerate(zip(a, b)))
        if path[-1] in names and "experts" not in path:
            return b
        return a
    return walk(qtree, orig, ())


if __name__ == "__main__":
    run()
