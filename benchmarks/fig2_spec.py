"""Paper Fig. 2 (right): speculative-loading recall vs #experts fetched,
for gate lookahead of 1, 2 and 10 layers (paper's three settings).

recall@n = fraction of layer-(l+j) active experts covered when applying
layer-(l+j)'s gate to layer-l's hidden state and fetching top-n."""
from __future__ import annotations

from repro.core.speculative import recall_curve

from benchmarks.common import emit, get_trace


def run(quick=False):
    tr = get_trace(128 if quick else None)
    n_layers = tr["ids"].shape[1]
    lookaheads = [j for j in (1, 2, min(5, n_layers - 1)) if j < n_layers]
    n_fetch = [1, 2, 3, 4, 6, 8]
    rec = recall_curve(tr["hiddens"], tr["routers"], tr["ids"],
                       lookaheads, n_fetch)
    rows = []
    for j in lookaheads:
        for n in n_fetch:
            rows.append({
                "name": f"fig2_spec_recall_ahead{j}_fetch{n}",
                "us_per_call": "",
                "derived": f"{rec[(j, n)]:.4f}",
                "lookahead": j, "n_fetch": n, "recall": rec[(j, n)],
            })
    # paper claims: recall grows with n; nearer lookahead is better
    r1 = [rec[(1, n)] for n in n_fetch]
    rows.append({"name": "fig2_spec_monotone_in_n",
                 "derived": str(all(b >= a - 1e-9
                                    for a, b in zip(r1, r1[1:])))})
    if len(lookaheads) >= 2:
        j2 = lookaheads[1]
        rows.append({
            "name": "fig2_spec_nearer_lookahead_better",
            "derived": str(rec[(1, 2)] >= rec[(j2, 2)] - 0.02),
        })
    emit(rows, "fig2_spec")
    return rows


if __name__ == "__main__":
    run()
