"""Kernel micro-benchmarks: Pallas (interpret-mode, correctness-bound on
CPU) and the jnp reference paths (the actual CPU compute numbers).

On real TPU hardware the pallas_call timings replace the interpret
numbers; here `us_per_call` for *_interp rows measures the Python
interpreter loop and is reported for completeness only (derived column
carries the analytic FLOPs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.quant import hqq

from benchmarks.common import emit, timeit


def run(quick=False):
    rows = []
    # --- dequant matmul ---
    M, K, N = (32, 256, 128) if quick else (64, 1024, 512)
    w = jax.random.normal(jax.random.key(0), (K, N)) * 0.05
    x = jax.random.normal(jax.random.key(1), (M, K))
    for bits in (2, 4, 8):
        qt = hqq.quantize(w, bits, group_size=64, scale_group=None)
        scale, zero = hqq._meta_dequantize(qt)
        flops = 2 * M * K * N

        jref = jax.jit(lambda xx, p=qt.packed, s=scale, z=zero:
                       ref.dequant_matmul_ref(xx, p, s, z, bits=bits,
                                              group_size=64))
        us, _ = timeit(jref, x)
        rows.append({"name": f"dequant_matmul_ref_{bits}bit_jit",
                     "us_per_call": f"{us:.1f}",
                     "derived": f"gflops={flops/us/1e3:.2f}"})
        if not quick:
            us_k, _ = timeit(
                lambda xx: ops.dequant_matmul(xx, qt, interpret=True), x,
                warmup=1, iters=1)
            rows.append({"name": f"dequant_matmul_pallas_{bits}bit_interp",
                         "us_per_call": f"{us_k:.0f}",
                         "derived": "interpret-mode (CPU emulation)"})

    # --- flash attention ---
    BH, BKV, S, d = (4, 2, 256, 64) if quick else (8, 2, 1024, 64)
    q = jax.random.normal(jax.random.key(2), (BH, S, d))
    k = jax.random.normal(jax.random.key(3), (BKV, S, d))
    v = jax.random.normal(jax.random.key(4), (BKV, S, d))
    flops = 4 * BH * S * S * d
    jref = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c,
                                                           causal=True))
    us, _ = timeit(jref, q, k, v)
    rows.append({"name": "flash_attention_ref_jit",
                 "us_per_call": f"{us:.1f}",
                 "derived": f"gflops={flops/us/1e3:.2f}"})
    if not quick:
        us_k, _ = timeit(
            lambda a, b, c: ops.flash_attention(a, b, c, causal=True),
            q, k, v, warmup=1, iters=1)
        rows.append({"name": "flash_attention_pallas_interp",
                     "us_per_call": f"{us_k:.0f}",
                     "derived": "interpret-mode (CPU emulation)"})

    # --- model-level chunked attention (production jnp path) ---
    from repro.models.layers import attention_core
    B, S2, Hkv, G, hd = 2, 512, 2, 2, 64
    qq = jax.random.normal(jax.random.key(5), (B, S2, Hkv * G, hd))
    kk = jax.random.normal(jax.random.key(6), (B, S2, Hkv, hd))
    vv = jax.random.normal(jax.random.key(7), (B, S2, Hkv, hd))
    pos = jnp.arange(S2, dtype=jnp.int32)
    f = jax.jit(lambda a, b, c: attention_core(a, b, c, pos, pos,
                                               causal=True, window=None))
    us, _ = timeit(f, qq, kk, vv)
    rows.append({"name": "model_chunked_attention_jit",
                 "us_per_call": f"{us:.1f}",
                 "derived": f"B{B}xS{S2}xH{Hkv*G}"})
    emit(rows, "kernels")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", action="store_true",
                    dest="smoke",
                    help="reduced shapes, jnp reference paths only "
                         "(CI smoke)")
    run(quick=ap.parse_args().smoke)
