"""Continuous batching vs static batched serving throughput.

Workload: N requests with mixed prompt lengths and mixed output budgets,
all backlogged at t=0 (the heavy-traffic regime the ROADMAP targets).
The static baseline is the seed's serving shape — FCFS groups of
``max_slots`` requests through ``ServeEngine.serve_batch``, every group
holding all its slots until the longest member finishes.  The continuous
engine releases a slot the step its request finishes and admits the next
request immediately, so short requests stop serialising behind long ones.

Both paths run the same jitted ``decode_step``; one warmup pass absorbs
compilation, then a timed pass reports tokens/s.  Expected on the mixed
workload: >= 1.5x tokens/s for continuous batching.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--trained]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import ContinuousEngine, Request, ServeEngine
from repro.serving.sampler import SamplerConfig
from repro.serving.scheduler import ExpertOverlapPolicy


def make_workload(cfg, n_requests, seed=0, smoke=False):
    """Interactive-traffic mix: mostly short replies, a tail of long
    generations (what makes static batching serialise short requests
    behind long ones).  Prompt lengths come from a small discrete set so
    per-length prefill compilation stays bounded."""
    rng = np.random.default_rng(seed)
    lengths = (4, 8) if smoke else (8, 16, 24, 32)
    short, long_ = ((2, 8), (8, 12)) if smoke else ((4, 16), (48, 64))
    reqs = []
    for _ in range(n_requests):
        s = int(rng.choice(lengths))
        prompt = rng.integers(1, cfg.vocab_size, s).astype(np.int32)
        lo, hi = short if rng.random() < 0.75 else long_
        reqs.append((prompt, int(rng.integers(lo, hi + 1))))
    return reqs


def run_static(params, cfg, workload, max_slots):
    """FCFS groups of ``max_slots`` through the static engine."""
    eng = ServeEngine(params, cfg, SamplerConfig(kind="greedy"))
    toks = 0
    for i in range(0, len(workload), max_slots):
        group = [Request(p, m) for p, m in workload[i: i + max_slots]]
        for r in eng.serve_batch(group):
            toks += len(r.completed)
    return toks


def run_continuous(params, cfg, workload, max_slots, slot_len, policy=None):
    # same EOS semantics as ServeEngine.serve_batch (which stops rows at
    # EOS), so both paths generate the same workload
    eng = ContinuousEngine(params, cfg, max_slots=max_slots,
                           slot_len=slot_len, policy=policy)
    for p, m in workload:
        eng.submit(p, m)
    done = eng.run(max_steps=100_000)
    assert len(done) == len(workload), "continuous engine dropped requests"
    return eng.stats()["tokens"], eng


def run(quick=False, trained=False, n_requests=None, max_slots=4,
        slot_len=None, seed=0, overlap=False):
    cfg = get_config("tiny-moe")
    if trained:
        from benchmarks.common import get_trained_tiny_moe
        params, cfg = get_trained_tiny_moe()
    else:
        params = T.init_model(jax.random.key(0), cfg)

    n = n_requests or (6 if quick else 24)
    slot_len = slot_len or (64 if quick else 128)
    workload = make_workload(cfg, n, seed=seed, smoke=quick)
    # FCFS for the throughput headline: expert-overlap admission pays a
    # per-step routing-collection cost that only pays off when expert
    # loads are expensive (the offloaded regime, priced by the cost
    # model) — pass overlap=True to measure that variant's wall-clock
    policy = ExpertOverlapPolicy(params, cfg) if overlap else None

    # warmup (compilation) + timed pass, for each serving mode
    run_static(params, cfg, workload, max_slots)
    t0 = time.perf_counter()
    static_toks = run_static(params, cfg, workload, max_slots)
    t_static = time.perf_counter() - t0

    run_continuous(params, cfg, workload, max_slots, slot_len, policy)
    t0 = time.perf_counter()
    cont_toks, eng = run_continuous(params, cfg, workload, max_slots,
                                    slot_len, policy)
    t_cont = time.perf_counter() - t0

    # per-request greedy sequences are engine-dependent only through EOS
    # stops (static stops at EOS, and its joint prefill shifts MoE
    # capacity contention), so counts may differ by a few tokens
    drift = abs(cont_toks - static_toks) / max(1, cont_toks)
    assert drift < 0.25, \
        f"token accounting drift too large: {cont_toks} vs {static_toks}"
    tps_static = static_toks / t_static
    tps_cont = cont_toks / t_cont
    speedup = tps_cont / tps_static
    s = eng.stats()
    result = {
        "name": "serve_bench",
        "n_requests": n, "max_slots": max_slots, "slot_len": slot_len,
        "static_tokens": static_toks, "continuous_tokens": cont_toks,
        "static_s": round(t_static, 3), "static_tok_s": round(tps_static, 2),
        "continuous_s": round(t_cont, 3),
        "continuous_tok_s": round(tps_cont, 2),
        "policy": "overlap" if overlap else "fcfs",
        "speedup": round(speedup, 3),
        "decode_steps": s["steps"], "tokens_per_step": round(
            s["tokens_per_step"], 3),
    }
    emit([result], "serve_bench")
    print(f"[serve_bench] static  : {tps_static:8.1f} tok/s "
          f"({t_static:.2f}s for {static_toks} tokens)")
    print(f"[serve_bench] contin. : {tps_cont:8.1f} tok/s "
          f"({t_cont:.2f}s, {s['steps']} steps, "
          f"{s['tokens_per_step']:.2f} tok/step)")
    print(f"[serve_bench] speedup : {speedup:.2f}x")
    if quick:
        assert speedup > 0.2, "smoke: continuous path unreasonably slow"
        print("[serve_bench] smoke OK")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI (seconds, asserts only)")
    ap.add_argument("--trained", action="store_true",
                    help="use the trained tiny-moe artifact instead of "
                         "random init (slower first run)")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--slot-len", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--overlap", action="store_true",
                    help="use the expert-overlap admission policy")
    args = ap.parse_args()
    run(quick=args.smoke, trained=args.trained, n_requests=args.n_requests,
        max_slots=args.max_slots, slot_len=args.slot_len, seed=args.seed,
        overlap=args.overlap)


if __name__ == "__main__":
    main()
