"""Shared benchmark infrastructure: the trained tiny-MoE artifact + trace.

The paper's Fig-2/Table-2 numbers are *measured behaviours of a trained
MoE router*; random routers have no locality, so every benchmark first
ensures a trained ``tiny-moe`` checkpoint exists (same block structure as
Mixtral: SWA attention + top-2-of-8 experts), trained on the byte corpus.
Cached under experiments/artifacts/ so the suite re-runs fast.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax
import numpy as np

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "experiments" / "artifacts"
BENCH_OUT = ROOT / "experiments" / "bench"

TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_TRAIN_STEPS", "300"))
TRACE_TOKENS = int(os.environ.get("REPRO_BENCH_TRACE_TOKENS", "384"))


def get_trained_tiny_moe(steps: int = None):
    """Returns (params, cfg), training + caching on first call."""
    from repro.checkpoint import checkpointer as C
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, PackedDataset
    from repro.models import transformer as T
    from repro.training import optimizer as O
    from repro.training import trainer

    steps = steps or TRAIN_STEPS
    cfg = get_config("tiny-moe")
    path = ART / f"tiny_moe_{steps}.npz"
    tmpl = jax.eval_shape(lambda: T.init_model(jax.random.key(0), cfg))
    if path.exists():
        return C.restore(str(path), tmpl), cfg
    print(f"[bench] training tiny-moe for {steps} steps (cached after)...")
    ds = PackedDataset(DataConfig(seq_len=128, batch_size=8,
                                  max_bytes=2_000_000))
    params = T.init_model(jax.random.key(0), cfg)
    opt = O.OptimizerConfig(lr=1e-3, warmup_steps=30, total_steps=steps)
    params, _, hist = trainer.train(
        params, cfg, opt, ds.batches(),
        trainer.TrainerConfig(steps=steps, log_every=max(20, steps // 10)))
    ART.mkdir(parents=True, exist_ok=True)
    C.save(str(path), params, meta={"steps": steps,
                                    "final_loss": hist[-1]["loss"]})
    return params, cfg


def get_trace(n_tokens: int = None):
    """Expert-activation trace of the trained model over held-out text."""
    from repro.core import trace as TR
    from repro.data.pipeline import DataConfig, PackedDataset

    n_tokens = n_tokens or TRACE_TOKENS
    path = ART / f"trace_{TRAIN_STEPS}_{n_tokens}.npz"
    if path.exists():
        z = np.load(path)
        return {k: z[k] for k in z.files}
    params, cfg = get_trained_tiny_moe()
    ds = PackedDataset(DataConfig(seq_len=n_tokens, batch_size=1,
                                  max_bytes=2_000_000))
    batch = next(ds.eval_batches(1))
    print(f"[bench] collecting routing trace over {n_tokens} tokens...")
    tr = TR.collect_trace(params, cfg, batch["tokens"][:1])
    ART.mkdir(parents=True, exist_ok=True)
    np.savez(path, **tr)
    return tr


def emit(rows, name: str):
    """Print ``name,us_per_call,derived`` CSV rows + persist JSON."""
    BENCH_OUT.mkdir(parents=True, exist_ok=True)
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
    (BENCH_OUT / f"{name}.json").write_text(json.dumps(rows, indent=1))


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out  # us
