"""Paper Fig. 2 (left): LRU cache hit ratio vs cache size k.

Measured by replaying the trained MoE's real routing trace through the
LRU cache at each k (the paper runs Mixtral over OpenAssistant; we run
tiny-moe — same 8-expert top-2 routing — over held-out corpus text)."""
from __future__ import annotations

from repro.core.lru_cache import lru_hit_curve

from benchmarks.common import emit, get_trace


def run(quick=False):
    tr = get_trace(128 if quick else None)
    ks = [1, 2, 3, 4, 6, 8]
    curve = lru_hit_curve(tr["ids"], ks)
    rows = []
    for k in ks:
        rows.append({
            "name": f"fig2_lru_hit_ratio_k{k}",
            "us_per_call": "",
            "derived": f"{curve[k]:.4f}",
            "k": k,
            "hit_ratio": curve[k],
        })
    # paper-claim check: hit ratio rises steeply then saturates; k=E is ~1
    rows.append({
        "name": "fig2_lru_monotone",
        "derived": str(all(curve[a] <= curve[b] + 1e-9
                           for a, b in zip(ks, ks[1:]))),
    })
    # beyond-paper: how much headroom does LRU leave vs LFU-decay and the
    # clairvoyant Belady bound? (paper section 3.1 names this open)
    from repro.core.lru_cache import policy_comparison

    comp = policy_comparison(tr["ids"], [2, 4])
    for (pol, k), v in sorted(comp.items()):
        rows.append({"name": f"fig2ext_{pol}_k{k}", "us_per_call": "",
                     "derived": f"{v:.4f}", "policy": pol, "k": k,
                     "hit_ratio": v})
    emit(rows, "fig2_lru")
    return rows


if __name__ == "__main__":
    run()
