"""Decode-attention benchmark: dense ring vs ragged paged (DESIGN.md §9).

The dense slotted plane pays O(n_slots x slot_len) attention every decode
step no matter how much context is actually live; the paged plane gathers
only the live page horizon, so its cost follows live tokens.  This bench
measures exactly that:

* ``decode_scaling`` rows — one batched decode-step attention at a fixed
  slot width, with the batch's live context swept from 1/8 of the slot to
  full: the dense time stays flat (it cannot see liveness), the ragged
  time scales down with the live fraction.
* ``worklist`` rows — the ragged Pallas kernel's grid size (work-list
  length) for mixed per-row lengths, with and without a sliding window:
  O(total live pages), not O(batch x table width) — including the pages
  the window lets the kernel skip outright.

Results print to stdout and persist machine-readable to
``experiments/bench/attention_bench.json`` AND the repo-root
``BENCH_attention.json`` (the perf-trajectory snapshot CI prints).

    PYTHONPATH=src python -m benchmarks.attention_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

from repro.kernels import ragged_attention as RA
from repro.models.layers import attention_core

ROOT = Path(__file__).resolve().parents[1]


def _bucket(n):
    w = 1
    while w < n:
        w *= 2
    return w


def _make_layouts(rng, lens, W, ps, Hkv, hd):
    """Dense ring + paged pool carrying the same live KV entries."""
    B = len(lens)
    T = W // ps
    kd = np.zeros((B, W, Hkv, hd), np.float32)
    vd = np.zeros((B, W, Hkv, hd), np.float32)
    posd = np.full((B, W), -1, np.int32)
    kp = np.zeros((B * T, ps, Hkv, hd), np.float32)
    vp = np.zeros((B * T, ps, Hkv, hd), np.float32)
    ppos = np.full((B * T, ps), -1, np.int32)
    pages = np.full((B, T), -1, np.int32)
    nxt = 0
    for b, n in enumerate(lens):
        n_pages = -(-int(n) // ps)
        for o in range(n_pages):
            pages[b, o] = nxt
            nxt += 1
        k = rng.standard_normal((int(n), Hkv, hd)).astype(np.float32)
        v = rng.standard_normal((int(n), Hkv, hd)).astype(np.float32)
        kd[b, :n], vd[b, :n], posd[b, :n] = k, v, np.arange(n)
        for p_ in range(int(n)):
            pid = pages[b, p_ // ps]
            kp[pid, p_ % ps], vp[pid, p_ % ps] = k[p_], v[p_]
            ppos[pid, p_ % ps] = p_
    return (jnp.asarray(kd), jnp.asarray(vd), jnp.asarray(posd)), \
        (jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(ppos),
         jnp.asarray(pages))


def _time(fn, *args, iters=30):
    fn(*args).block_until_ready()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(smoke=False, seed=0):
    rng = np.random.default_rng(seed)
    if smoke:
        B, W, ps, Hkv, G, hd, iters = 2, 128, 16, 2, 2, 32, 5
    else:
        B, W, ps, Hkv, G, hd, iters = 4, 512, 32, 4, 2, 64, 30
    H = Hkv * G
    window = None

    dense_fn = jax.jit(lambda q, k, v, qp, kp_: attention_core(
        q, k, v, qp, kp_, causal=True, window=window, q_chunk=1))
    ragged_fn = jax.jit(lambda q, kp, vp, pp, pg, qp:
                        RA.ragged_attention_reference(
                            q, kp, vp, pp, pg, qp, window=window, q_chunk=1))

    results = []
    print(f"[attention_bench] decode-step attention, {B} slots x "
          f"slot_len {W} (page {ps}):")
    for frac in (0.125, 0.25, 0.5, 1.0):
        lens = np.full((B,), max(1, int(W * frac)), np.int64)
        (kd, vd, posd), (kp, vp, ppos, pages) = _make_layouts(
            rng, lens, W, ps, Hkv, hd)
        q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
        qpos = jnp.asarray(lens[:, None].astype(np.int32))  # next position
        t_dense = _time(dense_fn, q, kd, vd, qpos, posd, iters=iters)
        width = min(_bucket(-(-int(lens.max()) // ps)), W // ps)
        t_ragged = _time(ragged_fn, q, kp, vp, ppos,
                         pages[:, :width], qpos, iters=iters)
        parity = np.array_equal(
            np.asarray(dense_fn(q, kd, vd, qpos, posd)),
            np.asarray(ragged_fn(q, kp, vp, ppos, pages, qpos)))
        assert parity, "paged attention diverged from the dense ring"
        row = {"name": "attention_bench", "scenario": "decode_scaling",
               "slots": B, "slot_len": W, "page": ps,
               "live_frac": frac, "live_tokens": int(lens.sum()),
               "table_width_pages": width,
               "dense_ms": round(t_dense, 3),
               "ragged_ms": round(t_ragged, 3),
               "speedup": round(t_dense / max(1e-9, t_ragged), 3),
               "bitwise_parity_full_width": True}
        results.append(row)
        print(f"  live {frac:5.3f} ({int(lens[0]):4d} tok/row): dense "
              f"{t_dense:7.3f}ms  ragged {t_ragged:7.3f}ms "
              f"({row['speedup']:.2f}x, width {width}p)")

    # ragged kernel grid scaling: mixed lengths, with / without a window
    lens = rng.integers(1, W, B).astype(np.int64)
    _, (kp, vp, ppos, pages) = _make_layouts(rng, lens, W, ps, Hkv, hd)
    q_lo = q_hi = (lens - 1).astype(np.int32)
    for win in (None, max(ps, W // 8)):
        wrow, _, wflags = RA.build_page_worklist(
            np.asarray(pages), lens, q_lo, q_hi, ps, window=win)
        n_live = int(wflags[:, 2].sum())
        results.append({"name": "attention_bench", "scenario": "worklist",
                        "window": win, "live_tokens": int(lens.sum()),
                        "live_pages": n_live,
                        "dense_grid_pages": B * (W // ps)})
        print(f"[attention_bench] kernel grid (window={win}): {n_live} "
              f"pages visited vs {B * (W // ps)} dense "
              f"({int(lens.sum())} live tokens)")
        assert n_live <= sum(-(-int(n) // ps) for n in lens)

    # smoke also exercises the Pallas kernel itself at a tiny shape
    if smoke:
        wl = RA.build_page_worklist(np.asarray(pages), lens, q_lo, q_hi, ps)
        qk = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
        qp = jnp.asarray(lens[:, None].astype(np.int32) - 1)
        out = RA.ragged_attention(qk, kp, vp, ppos, pages, qp, worklist=wl)
        ref = RA.ragged_attention_reference(qk, kp, vp, ppos, pages, qp,
                                            q_chunk=1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("[attention_bench] smoke OK (pallas kernel parity)")

    emit(results, "attention_bench")
    (ROOT / "BENCH_attention.json").write_text(json.dumps(results, indent=1))
    print(f"[attention_bench] wrote BENCH_attention.json "
          f"({len(results)} rows)")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, asserts parity)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, seed=args.seed)


if __name__ == "__main__":
    main()
