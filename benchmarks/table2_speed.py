"""Paper Table 2: tokens/s on {A100, 3080M, 3060, T4} x {2-bit, 3-bit
experts} x {full algorithm, w/o pre-loading, w/o LRU & pre-loading,
naive offloading}.

Cache/speculation statistics are MEASURED (trace replay of the trained
router through the actual policies, k=4/n_spec=2 per the paper's 16GB
operating point); wall-clock is the calibrated analytic cost model at
Mixtral-8x7B parameter sizes (no GPU on this host — see DESIGN.md §2).
The reproduced claims are the orderings and ratios of Table 2."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import cost_model as C

from benchmarks.common import emit, get_trace

POLICY_LABELS = {
    "full": "Full algorithm",
    "no_spec": "W/o expert pre-loading",
    "no_lru_no_spec": "W/o LRU cache & pre-loading",
    "naive": "Naive offloading (per-layer streaming)",
}

PAPER_TABLE2 = {  # tokens/s from the paper, for side-by-side reporting
    (2, "full"): {"a100": 3.061, "3080m": 2.655, "3060": 2.278, "t4": 2.092},
    (2, "no_spec"): {"a100": 2.918, "3080m": 2.227, "3060": 2.051, "t4": 1.567},
    (2, "no_lru_no_spec"): {"a100": 2.265, "3080m": 1.758, "3060": 1.547, "t4": 1.168},
    (2, "naive"): {"a100": 1.392, "3080m": 1.059, "3060": 0.919, "t4": 0.661},
    (3, "full"): {"a100": 2.845, "3080m": 2.475, "3060": 2.038, "t4": 1.603},
    (3, "no_spec"): {"a100": 2.683, "3080m": 2.024, "3060": 1.857, "t4": 1.365},
    (3, "no_lru_no_spec"): {"a100": 2.055, "3080m": 1.595, "3060": 1.346, "t4": 1.061},
    (3, "naive"): {"a100": 1.246, "3080m": 0.914, "3060": 0.580, "t4": 0.580},
}


def run(quick=False):
    tr = get_trace(128 if quick else None)
    mixtral = get_config("mixtral-8x7b")
    stats = C.replay_policies(tr["ids"], tr["hiddens"], tr["routers"],
                              k=4, n_spec=2, lookahead=1)
    # tiny-moe has 6 MoE layers; project per-token transfer counts to
    # Mixtral's 32 MoE layers (per-layer rates are what the trace measures)
    layer_scale = mixtral.moe_layer_count / tr["ids"].shape[1]
    stats = {pol: C.TokenStats(*(v * layer_scale for v in
                                 (ts.demand_loads, ts.spec_loads,
                                  ts.hits, ts.spec_hits)))
             for pol, ts in stats.items()}
    rows = []
    ours = {}
    for bits in (2, 3):
        for pol, ts in stats.items():
            for hw_name, hw in C.HARDWARE.items():
                tps = C.tokens_per_second(mixtral, hw, ts, bits,
                                          naive=(pol == "naive"))
                ours[(bits, pol, hw_name)] = tps
                paper = PAPER_TABLE2.get((bits, pol), {}).get(hw_name)
                rows.append({
                    "name": f"table2_{bits}bit_{pol}_{hw_name}",
                    "us_per_call": f"{1e6 / tps:.0f}",
                    "derived": f"tok/s={tps:.3f};paper={paper}",
                    "bits": bits, "policy": pol, "hw": hw_name,
                    "tokens_per_s": round(tps, 3), "paper_tokens_per_s": paper,
                })
    # reproduced structural claims
    claims = {
        # every policy level strictly improves throughput (per hw, 2-bit)
        "table2_policy_ordering": all(
            ours[(2, "full", h)] > ours[(2, "no_spec", h)]
            > ours[(2, "no_lru_no_spec", h)] > ours[(2, "naive", h)]
            for h in C.HARDWARE),
        # full algorithm lands in the paper's 2-4 tok/s interactive band
        "table2_interactive_band": all(
            1.5 < ours[(b, "full", h)] < 5.0
            for b in (2, 3) for h in C.HARDWARE),
        # hw ordering follows bandwidth: a100 > 3080m > 3060 > t4
        "table2_hw_ordering": all(
            ours[(b, "full", "a100")] > ours[(b, "full", "3080m")]
            > ours[(b, "full", "3060")] > ours[(b, "full", "t4")]
            for b in (2, 3)),
    }
    for nm, ok in claims.items():
        rows.append({"name": nm, "derived": str(ok)})
        print(f"[table2] {nm}: {ok}")
    # stats summary for the writeup
    ts = stats["full"]
    rows.append({
        "name": "table2_measured_stats_full",
        "derived": (f"demand/tok={ts.demand_loads:.2f};"
                    f"spec_hits/tok={ts.spec_hits:.2f};"
                    f"hits/tok={ts.hits:.2f};spec_loads/tok={ts.spec_loads:.2f}"),
    })
    emit(rows, "table2_speed")
    return rows


if __name__ == "__main__":
    run()
