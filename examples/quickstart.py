"""Quickstart: train a tiny Mixtral-family MoE on real bytes, generate
text, then run the SAME model through the paper's offloading engine and
confirm generation is bit-identical while counting transfers.

    PYTHONPATH=src python examples/quickstart.py [--steps 120]
"""
import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.core.offload_engine import OffloadEngine, generate_plain
from repro.data.pipeline import DataConfig, PackedDataset, decode_bytes, encode_text
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = get_config("tiny-moe")
    print(f"== 1. train {cfg.name} "
          f"({T.count_params_analytic(cfg)/1e6:.1f}M params) ==")
    ds = PackedDataset(DataConfig(seq_len=128, batch_size=8,
                                  max_bytes=1_500_000))
    params = T.init_model(jax.random.key(0), cfg)
    opt = O.OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    params, _, hist = trainer.train(
        params, cfg, opt, ds.batches(),
        trainer.TrainerConfig(steps=args.steps, log_every=20))
    assert hist[-1]["loss"] < hist[0]["loss"], "training must reduce loss"

    print("\n== 2. generate (plain decode) ==")
    prompt = encode_text("def ")[None]
    out = generate_plain(params, cfg, prompt, 48)
    print("generated:", repr(decode_bytes(out[0])))

    print("\n== 3. same model through the offload engine ==")
    eng = OffloadEngine(params, cfg)  # LRU k=2, 2 speculative (config)
    out_off, stats = eng.generate(prompt, 48)
    assert (out == out_off).all(), "offloading must not change outputs!"
    print(f"bit-identical: True | hit_ratio={stats.hit_ratio:.2f} "
          f"demand_loads={stats.demand_loads} spec_hits={stats.spec_hits}")
    print(f"host->device traffic: {stats.bytes_h2d/1e6:.1f} MB "
          f"(vs naive {stats.n_tokens * cfg.moe_layer_count * cfg.moe.num_experts * stats.expert_bytes/1e6:.1f} MB)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
