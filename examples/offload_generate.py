"""The paper's headline demo: interactive generation of an MoE model whose
experts do NOT fit in accelerator memory — mixed HQQ quantization (experts
3-bit / attention 4-bit) + LRU cache + speculative prefetch — with the
cost-model projection to the paper's four GPUs at Mixtral-8x7B scale.

    PYTHONPATH=src python examples/offload_generate.py
"""
import dataclasses

import numpy as np

from benchmarks.common import get_trained_tiny_moe
from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core import cost_model as C
from repro.core.offload_engine import OffloadEngine
from repro.data.pipeline import decode_bytes, encode_text


def main():
    params, cfg = get_trained_tiny_moe()
    prompt = encode_text("import ")[None]

    print("=" * 64)
    print("ablation sweep (paper Table 2 policies), 64 tokens each")
    print("=" * 64)
    results = {}
    for label, spec in [
        ("full algorithm", OffloadSpec(cache_size=4, num_speculative=2)),
        ("w/o pre-loading", OffloadSpec(cache_size=4, num_speculative=0)),
        ("w/o LRU & pre-loading", OffloadSpec(cache_size=1,
                                              num_speculative=0)),
    ]:
        eng = OffloadEngine(params, cfg, spec)
        out, stats = eng.generate(prompt, 64)
        results[label] = stats
        print(f"{label:26s} hit_ratio={stats.hit_ratio:.3f} "
              f"demand/tok={stats.demand_loads/stats.n_tokens:.2f} "
              f"text={decode_bytes(out[0])[:40]!r}")

    print("\nprojected tokens/s at Mixtral-8x7B scale (3-bit experts):")
    mixtral = get_config("mixtral-8x7b")
    hdr = f"{'policy':28s}" + "".join(f"{h:>9s}" for h in C.HARDWARE)
    print(hdr)
    for label, stats in results.items():
        row = f"{label:28s}"
        for hw_name, hw in C.HARDWARE.items():
            tps = C.tokens_per_second(mixtral, hw, stats.per_token(), 3)
            row += f"{tps:9.2f}"
        print(row)
    naive_row = f"{'naive offloading':28s}"
    for hw_name, hw in C.HARDWARE.items():
        naive_row += (
            f"{C.tokens_per_second(mixtral, hw, C.TokenStats(0,0,0,0), 3, naive=True):9.2f}")
    print(naive_row)

    print("\nmixed quantization (3-bit experts / 4-bit attention):")
    engq = OffloadEngine(params, cfg, quantized=True)
    out, stats = engq.generate(prompt, 64)
    print(f"quantized generation: {decode_bytes(out[0])[:48]!r}")
    print("sizes:", {k: f"{v/1e6:.2f}MB" for k, v in engq.size_report.items()})


if __name__ == "__main__":
    main()
