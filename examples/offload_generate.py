"""The paper's headline demo: interactive generation of an MoE model whose
experts do NOT fit in accelerator memory — mixed HQQ quantization (experts
3-bit / attention 4-bit) + LRU cache + speculative prefetch — with the
cost-model projection to the paper's four GPUs at Mixtral-8x7B scale.

    PYTHONPATH=src python examples/offload_generate.py
"""
import dataclasses
import sys
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks.*

from benchmarks.common import get_trained_tiny_moe
from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core import cost_model as C
from repro.core.offload_engine import OffloadEngine
from repro.data.pipeline import decode_bytes, encode_text


def main():
    params, cfg = get_trained_tiny_moe()
    prompt = encode_text("import ")[None]

    print("=" * 64)
    print("ablation sweep (paper Table 2 policies), 64 tokens each")
    print("=" * 64)
    results = {}
    for label, spec in [
        ("full algorithm", OffloadSpec(cache_size=4, num_speculative=2)),
        ("w/o pre-loading", OffloadSpec(cache_size=4, num_speculative=0)),
        ("w/o LRU & pre-loading", OffloadSpec(cache_size=1,
                                              num_speculative=0)),
    ]:
        eng = OffloadEngine(params, cfg, spec)
        out, stats = eng.generate(prompt, 64)
        results[label] = stats
        print(f"{label:26s} hit_ratio={stats.hit_ratio:.3f} "
              f"demand/tok={stats.demand_loads/stats.n_tokens:.2f} "
              f"text={decode_bytes(out[0])[:40]!r}")

    print("\nprojected tokens/s at Mixtral-8x7B scale (3-bit experts):")
    mixtral = get_config("mixtral-8x7b")
    hdr = f"{'policy':28s}" + "".join(f"{h:>9s}" for h in C.HARDWARE)
    print(hdr)
    for label, stats in results.items():
        row = f"{label:28s}"
        for hw_name, hw in C.HARDWARE.items():
            tps = C.tokens_per_second(mixtral, hw, stats.per_token(), 3)
            row += f"{tps:9.2f}"
        print(row)
    naive_row = f"{'naive offloading':28s}"
    for hw_name, hw in C.HARDWARE.items():
        naive_row += (
            f"{C.tokens_per_second(mixtral, hw, C.TokenStats(0,0,0,0), 3, naive=True):9.2f}")
    print(naive_row)

    print("\nmixed quantization (3-bit experts / 4-bit attention), "
          "REAL packed execution:")
    engq = OffloadEngine(params, cfg, quantized=True)
    out, stats = engq.generate(prompt, 64)
    print(f"quantized generation: {decode_bytes(out[0])[:48]!r}")
    print(f"measured traffic: {stats.demand_loads} demand + "
          f"{stats.spec_loads} speculative loads x "
          f"{stats.expert_bytes/1e3:.1f}KB/expert = "
          f"{stats.bytes_h2d/1e6:.2f}MB host->device")
    print("sizes:", {k: f"{v/1e6:.2f}MB" for k, v in engq.size_report.items()})

    # Table-1 framing: where the bytes actually live under packed
    # offloading vs keeping the dense model resident
    dense_experts = sum(
        leaf.size * 2 for p in range(cfg.pattern_period)
        for leaf in jax.tree.leaves(params["stack"][p].get("moe", {})
                                    .get("experts", {})))
    ps = engq._last_pool_state
    pool_b = ps.pool.nbytes() + ps.staging.nbytes()
    store_b = engq.store.nbytes()
    other_b = engq.size_report["attn"] + engq.size_report["fp16"]
    print("\nmemory footprint (measured, tiny-moe scale):")
    print(f"  dense fp16 experts, all resident : {dense_experts/1e6:8.2f}MB")
    print(f"  packed host store (off-device)   : {store_b/1e6:8.2f}MB")
    print(f"  device expert buffer pool        : {pool_b/1e6:8.2f}MB "
          f"({cfg.moe_layer_count} layers x "
          f"({engq.spec.cache_size} LRU + {engq.spec.num_speculative} "
          f"staging) slots)")
    print(f"  non-expert device weights        : {other_b/1e6:8.2f}MB")
    print(f"  => device-resident total {(pool_b+other_b)/1e6:.2f}MB vs "
          f"{(dense_experts+other_b)/1e6:.2f}MB dense-resident "
          f"({(dense_experts+other_b)/(pool_b+other_b):.1f}x)")


if __name__ == "__main__":
    main()
