"""End-to-end driver (deliverable b): train a ~100M-parameter MoE language
model for a few hundred steps on the byte corpus, with eval, checkpointing
and generation at the end.

    PYTHONPATH=src python examples/train_moe_100m.py [--steps 300]

The config is a granite-style fine-grained MoE (8 experts top-2) sized to
~100M total parameters; on this CPU host a step takes a few seconds —
budget ~15-30 min for the default 300 steps.
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import save
from repro.configs.base import ModelConfig, MoESpec
from repro.data.pipeline import (DataConfig, PackedDataset, decode_bytes,
                                 encode_text)
from repro.models import transformer as T
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampler import SamplerConfig
from repro.training import optimizer as O
from repro.training import trainer

CFG_100M = ModelConfig(
    name="moe-100m",
    arch_type="moe",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab_size=49152,
    block_pattern=("swa+moe",),
    sliding_window=256,
    moe=MoESpec(num_experts=8, top_k=2, aux_loss_weight=0.02),
    tie_embeddings=True,
    dtype="float32",
    citation="in-repo 100M-scale driver (granite/mixtral family)",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--out", default="experiments/artifacts/moe_100m.npz")
    args = ap.parse_args()

    cfg = CFG_100M
    n = T.count_params_analytic(cfg)
    active = n - cfg.moe_layer_count * (cfg.moe.num_experts - cfg.moe.top_k) \
        * 3 * cfg.d_model * cfg.d_ff
    print(f"[100m] {cfg.name}: {n/1e6:.1f}M total / {active/1e6:.1f}M active")

    ds = PackedDataset(DataConfig(seq_len=args.seq_len,
                                  batch_size=args.batch_size,
                                  max_bytes=8_000_000))
    params = T.init_model(jax.random.key(0), cfg)
    opt = O.OptimizerConfig(lr=6e-4, warmup_steps=40, total_steps=args.steps)
    t0 = time.time()
    params, _, hist = trainer.train(
        params, cfg, opt, ds.batches(),
        trainer.TrainerConfig(steps=args.steps, log_every=10,
                              eval_every=100),
        eval_batches=lambda: ds.eval_batches(4))
    print(f"[100m] {args.steps} steps in {time.time()-t0:.0f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    save(args.out, params, meta={"arch": cfg.name, "steps": args.steps,
                                 "final_loss": hist[-1]["loss"]})

    eng = ServeEngine(params, cfg, SamplerConfig(kind="greedy"))
    reqs = [Request(encode_text("def "), 48),
            Request(encode_text("class "), 48),
            Request(encode_text("import "), 48)]
    for r in eng.serve_batch(reqs):
        print("sample:", repr(decode_bytes(np.array(r.completed))))


if __name__ == "__main__":
    main()
