"""Batched serving example: multiple concurrent requests through the
(fits-in-memory) serving engine, with sampling per the paper's evaluation
protocol ("sample proportionally to the predicted probabilities").

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys
from pathlib import Path

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))  # benchmarks.*

from benchmarks.common import get_trained_tiny_moe
from repro.data.pipeline import decode_bytes, encode_text
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampler import SamplerConfig


def main():
    params, cfg = get_trained_tiny_moe()
    eng = ServeEngine(params, cfg, SamplerConfig(kind="categorical",
                                                 temperature=0.8))
    prompts = ["def ", "import ", "class F", "return ", "for i in "]
    reqs = [Request(encode_text(p), max_new_tokens=40) for p in prompts]
    out = eng.serve_batch(reqs, seed=7)
    for p, r in zip(prompts, out):
        print(f"{p!r:14s} -> {decode_bytes(np.array(r.completed))!r}")


if __name__ == "__main__":
    main()
