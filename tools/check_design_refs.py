#!/usr/bin/env python
"""Docs-consistency check: every ``DESIGN.md §N`` reference in the code
base must resolve to an existing ``## §N`` section of DESIGN.md.

Run from the repo root (CI does):

    python tools/check_design_refs.py

Exits non-zero listing every dangling reference.  Also fails if DESIGN.md
or the references vanish entirely (the check silently passing on an empty
set would hide a rename of the file itself).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
SCAN_FILES = ("README.md", "ROADMAP.md", "CHANGES.md")
REF = re.compile(r"DESIGN\.md\s+§(\d+)")
SECTION = re.compile(r"^##\s+§(\d+)\b", re.MULTILINE)


def main() -> int:
    design = ROOT / "DESIGN.md"
    if not design.exists():
        print("check_design_refs: DESIGN.md missing", file=sys.stderr)
        return 1
    sections = {int(n) for n in SECTION.findall(design.read_text())}
    if not sections:
        print("check_design_refs: DESIGN.md has no '## §N' sections",
              file=sys.stderr)
        return 1

    paths = [ROOT / f for f in SCAN_FILES if (ROOT / f).exists()]
    for d in SCAN_DIRS:
        paths += sorted((ROOT / d).rglob("*.py"))
    n_refs = 0
    bad = []
    for path in paths:
        text = path.read_text(errors="replace")
        for m in REF.finditer(text):
            n_refs += 1
            sec = int(m.group(1))
            if sec not in sections:
                line = text[: m.start()].count("\n") + 1
                bad.append(f"{path.relative_to(ROOT)}:{line}: "
                           f"DESIGN.md §{sec} does not exist "
                           f"(sections: {sorted(sections)})")
    if not n_refs:
        print("check_design_refs: no DESIGN.md § references found — "
              "did the convention change?", file=sys.stderr)
        return 1
    if bad:
        print("\n".join(bad), file=sys.stderr)
        return 1
    print(f"check_design_refs: {n_refs} references OK against sections "
          f"{sorted(sections)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
