#!/usr/bin/env python
"""CI validator for ``--metrics-json`` / ``--trace`` output files
(DESIGN.md §10).

Checks a metrics snapshot written by ``launch/serve.py --metrics-json``
against the schema of record (``repro.obs.schema``): the schema version,
the ``mode`` descriptor, the exact namespace set for that
engine/plane/KV-layout combination, the exact key set inside every
namespace, and the field layout of every histogram.  Optionally also
checks a Chrome ``trace_event`` file from ``--trace`` for structural
sanity and the request-lifecycle span vocabulary.

    python tools/check_metrics_schema.py METRICS.json [--trace TRACE.json]

Exits non-zero listing every violation.  The same ``expected_namespaces``
function backs the snapshot tests in ``tests/test_obs.py`` — this tool
exists so CI catches drift in the *serialized* artifact (sanitization,
mode plumbing, file layout), not just the in-process snapshot.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.obs.schema import (EXEC_KEYS_BY_PLANE, HISTOGRAM_FIELDS,  # noqa: E402
                              JIT_KEYS, OFFLOAD_KEYS, REQUEST_KEYS,
                              ROOFLINE_KEYS, SCHEMA_VERSION, SPEC_KEYS,
                              expected_namespaces)

# histograms serialize as nested dicts; everything else is scalar-ish
HISTOGRAM_METRICS = {("step", "wall_ms"), ("request", "queue_wait_steps"),
                     ("request", "gen_tokens"), ("spec", "proposed"),
                     ("spec", "accepted")}

# span/instant names every traced continuous-serve run must carry
REQUIRED_TRACE_NAMES = {"submit", "queue_wait", "decode", "finish"}


def expected_for_mode(mode):
    """``mode`` descriptor (the dict serve.py embeds) -> exact
    ``{namespace: key set}`` the file's metrics section must carry."""
    engine = mode.get("engine")
    timing = bool(mode.get("timing", False))
    plane = mode.get("plane", "plain")
    roofline = bool(mode.get("roofline", timing))
    # read via .get: files from before the speculation / prefix-caching
    # PRs carry none of these fields and must keep validating
    speculative = bool(mode.get("speculative", False))
    prefix_cache = bool(mode.get("prefix_cache", False))
    kv_host = bool(mode.get("kv_host", False))
    if engine == "continuous":
        # defaults True: every ContinuousEngine registers the faults
        # collector, so files predating the mode field still validate
        return expected_namespaces(
            kv_layout=mode.get("kv_layout", "dense"),
            offloaded=bool(mode.get("offloaded", False)),
            timing=timing, plane=plane, roofline=roofline,
            speculative=speculative, prefix_cache=prefix_cache,
            kv_host=kv_host, faults=bool(mode.get("faults", True)))
    if engine == "offload":
        # the batch OffloadEngine has no scheduler/KV-slot plane or step
        # loop — it carries traffic + jit always, request/exec/roofline
        # when timing is on, spec when draft-and-verify decoding ran
        # (no faults namespace: the fault-injection plane lives in the
        # continuous engine's request lifecycle, DESIGN.md §14)
        out = {"offload": OFFLOAD_KEYS, "jit": JIT_KEYS}
        if speculative:
            out["spec"] = SPEC_KEYS
        if timing:
            out["request"] = REQUEST_KEYS
            out["exec"] = EXEC_KEYS_BY_PLANE[plane]
            if roofline:
                out["roofline"] = ROOFLINE_KEYS
        return out
    raise ValueError(f"unknown mode.engine {engine!r}")


def check_metrics(path: Path):
    errors = []
    doc = json.loads(path.read_text())
    for field in ("schema_version", "mode", "metrics"):
        if field not in doc:
            errors.append(f"{path}: missing top-level field {field!r}")
    if errors:
        return errors
    if doc["schema_version"] != SCHEMA_VERSION:
        errors.append(f"{path}: schema_version {doc['schema_version']} != "
                      f"{SCHEMA_VERSION}")
    mode = doc["mode"]
    for field in ("engine", "arch", "offloaded", "timing", "plane",
                  "roofline"):
        if field not in mode:
            errors.append(f"{path}: mode missing {field!r} (got "
                          f"{sorted(mode)})")
    if errors:
        return errors
    if mode["engine"] == "continuous" and "kv_layout" not in mode:
        return [f"{path}: continuous mode missing 'kv_layout'"]

    expected = expected_for_mode(mode)
    metrics = doc["metrics"]
    if set(metrics) != set(expected):
        errors.append(f"{path}: namespaces {sorted(metrics)} != expected "
                      f"{sorted(expected)} for mode {mode}")
    for ns in sorted(set(metrics) & set(expected)):
        got, want = set(metrics[ns]), set(expected[ns])
        if got != want:
            missing, extra = sorted(want - got), sorted(got - want)
            errors.append(f"{path}: namespace {ns!r}: missing={missing} "
                          f"extra={extra}")
    for ns, key in sorted(HISTOGRAM_METRICS):
        val = metrics.get(ns, {}).get(key)
        if val is None:
            continue  # namespace absent is already reported above
        if not isinstance(val, dict) or set(val) != HISTOGRAM_FIELDS:
            errors.append(f"{path}: {ns}.{key} should be a histogram with "
                          f"fields {sorted(HISTOGRAM_FIELDS)}, got {val!r}")
    # timed runs must have actually measured something
    if mode["timing"] and mode["engine"] == "continuous":
        step = metrics.get("step", {})
        if not step.get("timed"):
            errors.append(f"{path}: timing mode but step.timed == "
                          f"{step.get('timed')!r} (no steps measured)")
    return errors


def check_trace(path: Path):
    errors = []
    doc = json.loads(path.read_text())
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: traceEvents missing or empty"]
    names_by_ph = {}
    for i, ev in enumerate(events):
        for field in ("ph", "name", "pid", "tid"):
            if field not in ev:
                errors.append(f"{path}: event {i} missing {field!r}: {ev}")
                break
        else:
            names_by_ph.setdefault(ev["ph"], set()).add(ev["name"])
            if ev["ph"] in ("X", "i") and "ts" not in ev:
                errors.append(f"{path}: event {i} ({ev['name']}) has no ts")
            if ev["ph"] == "X" and ev.get("dur", -1.0) < 0.0:
                errors.append(f"{path}: event {i} ({ev['name']}) has "
                              f"negative/missing dur")
    meta = names_by_ph.get("M", set())
    if not {"process_name", "thread_name"} <= meta:
        errors.append(f"{path}: missing process/thread metadata events "
                      f"(got {sorted(meta)})")
    seen = names_by_ph.get("X", set()) | names_by_ph.get("i", set())
    missing = REQUIRED_TRACE_NAMES - seen
    if missing:
        errors.append(f"{path}: request lifecycle spans missing: "
                      f"{sorted(missing)}")
    if not any(n.startswith("prefill[") for n in names_by_ph.get("X", ())):
        errors.append(f"{path}: no prefill[lo:hi) chunk spans recorded")
    if not any(n.startswith("step ") for n in names_by_ph.get("X", ())):
        errors.append(f"{path}: no per-step spans recorded")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("metrics", type=Path,
                    help="a --metrics-json file from launch/serve.py")
    ap.add_argument("--trace", type=Path, default=None,
                    help="optionally also validate a --trace file")
    args = ap.parse_args()

    errors = check_metrics(args.metrics)
    n_checked = 1
    if args.trace is not None:
        errors += check_trace(args.trace)
        n_checked += 1
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"check_metrics_schema: {n_checked} file(s) OK "
          f"(schema v{SCHEMA_VERSION})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
