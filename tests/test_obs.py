"""Telemetry plane (DESIGN.md §10): registry/histogram/tracer unit
behavior, legacy ``stats()`` projection, snapshot schema exactness for
every engine/plane/KV-layout combination, and the hot-path contract —
tokens are bitwise identical with telemetry fully on (timing + tracing)
or fully off, on every decode plane."""
import importlib.util
import json
from pathlib import Path

import numpy as np
import pytest

from repro.configs.base import OffloadSpec
from repro.core.offload_engine import OffloadEngine
from repro.obs import Telemetry, flatten_legacy
from repro.obs.registry import Histogram, MetricsRegistry
from repro.obs.schema import (EXEC_KEYS_BY_PLANE, HISTOGRAM_FIELDS,
                              JIT_KEYS, OFFLOAD_KEYS, REQUEST_KEYS,
                              ROOFLINE_KEYS, SPEC_KEYS,
                              expected_namespaces)
from repro.obs.tracing import PID_REQUESTS, Tracer
from repro.serving.engine import ContinuousEngine
from repro.serving.sampler import SamplerConfig

ROOT = Path(__file__).resolve().parents[1]


def _prompts(cfg, n, seed=0, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _offload_spec():
    return OffloadSpec(cache_size=4, num_speculative=2, expert_bits=3,
                       attn_bits=4)


def _run_serving(cfg, params, telemetry, *, kv_page=None, offload=None,
                 sampler=None, seed=0, **kw):
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=48,
                           eos_id=None, kv_page=kv_page, offload=offload,
                           sampler=sampler, seed=seed, telemetry=telemetry,
                           **kw)
    reqs = [eng.submit(p, m) for p, m in
            zip(_prompts(cfg, 4, seed=5), [4, 7, 3, 6])]
    eng.run(max_steps=300)
    assert all(r.state == "finished" for r in reqs)
    return eng, [r.generated for r in reqs]


# ----------------------------------------------------------------------
# registry / histogram / tracer / flatten units
def test_histogram_log_buckets_and_quantiles():
    h = Histogram()
    for v in (1.0, 2.0, 4.0, 4.5, 100.0):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 5 and s["sum"] == pytest.approx(111.5)
    assert s["min"] == 1.0 and s["max"] == 100.0
    # each bucket spans one power of two -> estimates within the sample
    # range and monotone across quantiles
    assert s["min"] <= s["p50"] <= s["p95"] <= s["max"]
    # 4.0 and 4.5 land in bucket 3 ([4, 8)); 1.0 in bucket 1; 2.0 in 2
    assert s["buckets"] == {"1": 1, "2": 1, "3": 2, "7": 1}


def test_histogram_empty_snapshot_is_total():
    s = Histogram().snapshot()
    assert set(s) == HISTOGRAM_FIELDS
    assert s["count"] == 0 and s["p95"] == 0.0


def test_registry_kind_conflict_rejected():
    r = MetricsRegistry()
    r.counter("ns", "x")
    r.counter("ns", "x")  # re-declare same kind is idempotent
    with pytest.raises(ValueError):
        r.gauge("ns", "x")


def test_registry_collector_overlap_asserts():
    r = MetricsRegistry()
    r.counter("engine", "steps")
    r.register_collector("engine", lambda: {"steps": 3})
    with pytest.raises(AssertionError):
        r.snapshot()


def test_flatten_legacy_prefixes_and_collisions():
    flat = flatten_legacy({"engine": {"steps": 3}, "kv": {"slots_free": 1},
                           "offload": {"hits": 2}, "step": {"timed": 4}})
    assert flat == {"steps": 3, "kv_slots_free": 1, "offload_hits": 2,
                    "step_timed": 4}
    with pytest.raises(AssertionError):
        flatten_legacy({"kv": {"x": 1}, "engine": {"kv_x": 2}})


def test_tracer_chrome_format_and_metadata_dedup():
    clock = iter(range(0, 10_000_000, 1_000_000))
    tr = Tracer(clock_ns=lambda: next(clock))
    assert tr.request_track(7) == 7
    tr.request_track(7)  # second call must not duplicate the thread meta
    tr.complete("decode", PID_REQUESTS, 7, 10.0, 25.0, args={"tokens": 3})
    tr.instant("finish", PID_REQUESTS, 7)
    doc = tr.to_chrome()
    assert set(doc) == {"traceEvents", "displayTimeUnit"}
    names = [(e["ph"], e["name"]) for e in doc["traceEvents"]]
    assert names.count(("M", "thread_name")) == 3  # steps, exec, req 7
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert spans == [{"ph": "X", "name": "decode", "pid": PID_REQUESTS,
                      "tid": 7, "ts": 10.0, "dur": 25.0,
                      "args": {"tokens": 3}}]


# ----------------------------------------------------------------------
# snapshot schema exactness: engines emit EXACTLY the documented key set
def _assert_schema(snapshot, **combo):
    want = expected_namespaces(**combo)
    assert set(snapshot) == set(want), \
        f"namespaces {sorted(snapshot)} != {sorted(want)}"
    for ns in want:
        assert set(snapshot[ns]) == set(want[ns]), \
            f"{ns}: {sorted(set(snapshot[ns]) ^ set(want[ns]))} drifted"


@pytest.mark.parametrize("kv_page", [None, 16], ids=["dense", "paged"])
def test_continuous_snapshot_schema(tiny_moe_cfg, tiny_moe_params, kv_page):
    eng, _ = _run_serving(tiny_moe_cfg, tiny_moe_params,
                          Telemetry(timing=True, trace=True),
                          kv_page=kv_page)
    _assert_schema(eng.metrics(),
                   kv_layout="paged" if kv_page else "dense",
                   timing=True, plane="plain", roofline=True)
    assert set(eng.metrics()["step"]["wall_ms"]) == HISTOGRAM_FIELDS


def test_continuous_snapshot_schema_telemetry_off(tiny_moe_cfg,
                                                  tiny_moe_params):
    eng, _ = _run_serving(tiny_moe_cfg, tiny_moe_params, None)
    _assert_schema(eng.metrics(), kv_layout="dense", timing=False)
    # the legacy flat stats() shim still carries its historical keys
    s = eng.stats()
    for key in ("steps", "tokens", "tokens_per_step", "finished",
                "kv_slots_in_use", "kv_slots_free", "jit_hits"):
        assert key in s, f"legacy stats() lost {key!r}"


def test_offloaded_continuous_snapshot_schema(tiny_moe_cfg,
                                              tiny_moe_params):
    off = OffloadEngine(tiny_moe_params, tiny_moe_cfg, _offload_spec(),
                        quantized=True)
    eng, _ = _run_serving(tiny_moe_cfg, tiny_moe_params,
                          Telemetry(timing=True, trace=True), offload=off)
    snap = eng.metrics()
    _assert_schema(snap, kv_layout="dense", offloaded=True, timing=True,
                   plane="packed_pipelined", roofline=True)
    # the offload namespace carries real traffic and the roofline saw it
    assert snap["offload"]["demand_loads"] + snap["offload"]["spec_loads"] > 0
    assert snap["roofline"]["windows"] >= 1
    assert snap["roofline"]["measured_tok_s"] > 0
    assert snap["roofline"]["h2d_savings_ratio"] > 1.0, \
        "expert streaming should beat the naive all-experts-every-layer bound"
    assert "offload_hits" in eng.stats()


def test_speculative_snapshot_schema(tiny_moe_cfg, tiny_moe_params,
                                     tmp_path):
    """Draft-and-verify serving declares the full ``spec`` namespace
    (DESIGN.md §11) — the key set exists even before a round runs, and
    the values account the rounds that did."""
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    dcfg = get_config("tiny-draft")
    dparams = T.init_model(jax.random.key(7), dcfg)
    eng, _ = _run_serving(tiny_moe_cfg, tiny_moe_params,
                          Telemetry(timing=True, trace=True),
                          draft_params=dparams, draft_cfg=dcfg,
                          num_draft_tokens=3)
    snap = eng.metrics()
    _assert_schema(snap, kv_layout="dense", timing=True, plane="plain",
                   roofline=True, speculative=True)
    spec = snap["spec"]
    assert set(spec) == SPEC_KEYS
    assert spec["rounds"] > 0
    assert 0.0 <= spec["acceptance_rate"] <= 1.0
    assert set(spec["proposed"]) == HISTOGRAM_FIELDS
    assert spec["proposed"]["count"] == spec["rounds"]
    # acceptance_rate is emitted/proposed accounting: a round emits
    # accepted+1 tokens, so the flat projection carries spec_* keys too
    assert "spec_rounds" in flatten_legacy(snap)
    # the serialized artifact validates against the CI checker
    mpath = tmp_path / "metrics.json"
    eng.obs.write_metrics(mpath, {
        "engine": "continuous", "arch": tiny_moe_cfg.name,
        "kv_layout": "dense", "offloaded": False, "timing": True,
        "plane": "plain", "roofline": True, "speculative": True})
    assert _load_checker().check_metrics(mpath) == []


def test_offload_engine_snapshot_schema(tiny_moe_cfg, tiny_moe_params):
    prompt = _prompts(tiny_moe_cfg, 1, seed=2)[0][None]
    off = OffloadEngine(tiny_moe_params, tiny_moe_cfg, _offload_spec(),
                        quantized=True)  # default engine: telemetry off
    off.generate(prompt, 4)
    assert set(off.metrics()) == {"offload", "jit"}
    assert set(off.metrics()["offload"]) == OFFLOAD_KEYS
    telem = Telemetry(timing=True, trace=True)
    on = OffloadEngine(tiny_moe_params, tiny_moe_cfg, _offload_spec(),
                       quantized=True, telemetry=telem)
    on.generate(prompt, 4)
    snap = on.metrics()
    assert set(snap) == {"offload", "jit", "request", "exec", "roofline"}
    assert set(snap["request"]) == REQUEST_KEYS
    assert set(snap["exec"]) == EXEC_KEYS_BY_PLANE["packed_pipelined"]
    assert set(snap["roofline"]) == ROOFLINE_KEYS
    assert set(snap["jit"]) == JIT_KEYS
    assert snap["request"]["finished"] == 1


# ----------------------------------------------------------------------
# hot-path contract: bitwise-identical tokens with telemetry on or off
@pytest.mark.parametrize("mode", ["plain_dense", "plain_paged",
                                  "categorical"])
def test_parity_telemetry_on_off(tiny_moe_cfg, tiny_moe_params, mode):
    kv_page = 16 if mode == "plain_paged" else None
    sampler = (SamplerConfig(kind="categorical")
               if mode == "categorical" else None)
    _, off_toks = _run_serving(tiny_moe_cfg, tiny_moe_params, None,
                               kv_page=kv_page, sampler=sampler, seed=11)
    _, on_toks = _run_serving(tiny_moe_cfg, tiny_moe_params,
                              Telemetry(timing=True, trace=True),
                              kv_page=kv_page, sampler=sampler, seed=11)
    assert on_toks == off_toks, f"{mode}: telemetry perturbed the tokens"


def test_parity_telemetry_on_off_offloaded(tiny_moe_cfg, tiny_moe_params):
    off_eng = OffloadEngine(tiny_moe_params, tiny_moe_cfg, _offload_spec(),
                            quantized=True)
    _, base = _run_serving(tiny_moe_cfg, tiny_moe_params, None,
                           offload=off_eng, seed=11)
    _, on = _run_serving(tiny_moe_cfg, tiny_moe_params,
                         Telemetry(timing=True, trace=True),
                         offload=off_eng, seed=11)
    assert on == base, "telemetry perturbed the offloaded packed plane"


def test_parity_offload_engine_generate(tiny_moe_cfg, tiny_moe_params):
    prompt = _prompts(tiny_moe_cfg, 1, seed=9)[0][None]
    base = OffloadEngine(tiny_moe_params, tiny_moe_cfg, _offload_spec(),
                         quantized=True)
    out0, stats0 = base.generate(prompt, 6)
    on = OffloadEngine(tiny_moe_params, tiny_moe_cfg, _offload_spec(),
                       quantized=True,
                       telemetry=Telemetry(timing=True, trace=True))
    out1, stats1 = on.generate(prompt, 6)
    assert (out0 == out1).all()
    assert (stats0.hits, stats0.demand_loads) == \
        (stats1.hits, stats1.demand_loads)


# ----------------------------------------------------------------------
# serialized artifacts validate against the CI checker itself
def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_metrics_schema", ROOT / "tools" / "check_metrics_schema.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_metrics_and_trace_files_pass_ci_checker(tiny_moe_cfg,
                                                 tiny_moe_params, tmp_path):
    eng, _ = _run_serving(tiny_moe_cfg, tiny_moe_params,
                          Telemetry(timing=True, trace=True))
    mpath, tpath = tmp_path / "metrics.json", tmp_path / "trace.json"
    eng.obs.write_metrics(mpath, {
        "engine": "continuous", "arch": tiny_moe_cfg.name,
        "kv_layout": "dense", "offloaded": False, "timing": True,
        "plane": "plain", "roofline": True})
    eng.obs.write_trace(tpath)
    checker = _load_checker()
    assert checker.check_metrics(mpath) == []
    assert checker.check_trace(tpath) == []
    # and the checker actually rejects drift
    doc = json.loads(mpath.read_text())
    del doc["metrics"]["engine"]["steps"]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    assert checker.check_metrics(bad), "checker passed a broken snapshot"
