"""Continuous-batching subsystem: per-request parity with the plain
decode oracle under join/evict churn, KV-slot reuse without cross-request
leakage, scheduler invariants, and the left-pad mask fix for the static
engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.offload_engine import ExpertUsageTracker, generate_plain
from repro.models import transformer as T
from repro.serving.engine import ContinuousEngine, Request, ServeEngine
from repro.serving.kv_manager import KVSlotManager
from repro.serving.scheduler import (ExpertOverlapPolicy, GenRequest,
                                     Scheduler, fcfs_policy)


def _prompts(cfg, n, seed=0, lo=4, hi=20):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


# ----------------------------------------------------------------------
def test_continuous_parity_under_churn(tiny_moe_cfg, tiny_moe_params):
    """6 mixed-length requests through 2 slots: every request's greedy
    tokens must be bitwise those of decoding it alone."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = _prompts(cfg, 6, seed=1)
    max_news = [5, 12, 3, 9, 7, 11]
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None,
                           policy=ExpertOverlapPolicy(params, cfg))
    reqs = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    eng.run(max_steps=500)
    assert all(r.state == "finished" for r in reqs)
    # churn actually happened: more requests than slots
    assert eng.sched.joins == 6 and eng.sched.evictions == 6
    for p, m, r in zip(prompts, max_news, reqs):
        oracle = generate_plain(params, cfg, p[None], m)[0].tolist()
        assert r.generated == oracle, f"request {r.rid} diverged"


def test_kv_slot_reuse_no_leakage(tiny_moe_cfg, tiny_moe_params):
    """A request decoded in a just-vacated slot matches one decoded in a
    fresh engine — freed slots carry no state across requests."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    p1, p2 = _prompts(cfg, 2, seed=7)
    # run p1 to completion, then p2 lands in the same (only) slot
    eng = ContinuousEngine(params, cfg, max_slots=1, slot_len=64,
                           eos_id=None)
    r1 = eng.submit(p1, 8)
    eng.run(max_steps=100)
    assert r1.state == "finished" and eng.kv.n_free == 1
    r2 = eng.submit(p2, 8)
    eng.run(max_steps=100)
    fresh = ContinuousEngine(params, cfg, max_slots=1, slot_len=64,
                             eos_id=None)
    r2f = fresh.submit(p2, 8)
    fresh.run(max_steps=100)
    assert r2.slot == r1.slot, "expected slot reuse"
    assert r2.generated == r2f.generated, "state leaked across slot reuse"


def test_join_evict_churn_invariants(tiny_moe_cfg, tiny_moe_params):
    """Requests trickle in while others finish; scheduler bookkeeping
    stays consistent every step and all requests complete exactly once."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = _prompts(cfg, 8, seed=3, lo=3, hi=10)
    eng = ContinuousEngine(params, cfg, max_slots=3, slot_len=48,
                           eos_id=None)
    it = iter(zip(prompts, [3, 1, 6, 2, 5, 4, 1, 7]))
    submitted = []
    for step in range(200):
        # staggered arrivals: one new request every other step
        if step % 2 == 0:
            nxt = next(it, None)
            if nxt is not None:
                submitted.append(eng.submit(nxt[0], nxt[1]))
        eng.step()  # check_invariants() runs inside
        if len(submitted) == 8 and not eng.sched.has_waiting \
                and not eng.sched.n_running:
            break
    assert len(submitted) == 8
    assert sorted(r.rid for r in eng.sched.finished) == \
        sorted(r.rid for r in submitted)
    for r in submitted:
        assert r.state == "finished"
        assert len(r.generated) == r.max_new_tokens  # eos_id=None
    assert eng.kv.n_free == 3


def test_slot_capacity_enforced(tiny_moe_cfg, tiny_moe_params):
    eng = ContinuousEngine(tiny_moe_params, tiny_moe_cfg, max_slots=1,
                           slot_len=16, eos_id=None)
    with pytest.raises(ValueError):
        eng.submit(np.arange(1, 10, dtype=np.int32), 8)  # 9 + 8 > 16


def test_kv_manager_recurrent_slots():
    """Per-layer-kind state planes (DESIGN.md §12): the dense slot
    manager carries recurrent stacks — fixed-size carries slot exactly
    like rings (the degenerate one-page-per-slot case), and the
    snapshot/restore pair round-trips a row bitwise (the speculative
    rollback primitive for rec planes)."""
    cfg = get_config("recurrentgemma-9b").reduced()
    mgr = KVSlotManager(cfg, 2, 32)
    slot = mgr.allocate("r0")
    snap = mgr.snapshot(slot)
    mgr.restore(snap, slot)
    back = mgr.snapshot(slot)
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
def test_continuous_offloaded_decode_parity(tiny_moe_cfg, tiny_moe_params):
    """Offloaded decode mode (DESIGN.md §6): continuous batching over
    HQQ-packed experts must produce, for every request, the bitwise
    tokens of decoding the dequantized model alone — and the shared
    buffer pool must actually carry the traffic."""
    from repro.configs.base import OffloadSpec
    from repro.core.offload_engine import OffloadEngine, quantize_for_offload

    cfg, params = tiny_moe_cfg, tiny_moe_params
    spec = OffloadSpec(cache_size=4, num_speculative=2, expert_bits=3,
                       attn_bits=4)
    qdeq, _ = quantize_for_offload(params, cfg, spec)
    off = OffloadEngine(params, cfg, spec, quantized=True)
    eng = ContinuousEngine(None, cfg, max_slots=2, slot_len=48,
                           eos_id=None, offload=off)
    # narrow prompt-length set: every distinct length compiles its own
    # B=1 admission prefill (runtime guard, DESIGN.md §7)
    prompts = _prompts(cfg, 4, seed=13, lo=5, hi=8)
    max_news = [5, 8, 3, 6]
    reqs = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    eng.run(max_steps=300)
    assert all(r.state == "finished" for r in reqs)
    for p, m, r in zip(prompts, max_news, reqs):
        oracle = generate_plain(qdeq, cfg, p[None], m)[0].tolist()
        assert r.generated == oracle, f"request {r.rid} diverged"
    s = eng.stats()
    assert s["offload_demand_loads"] > 0
    assert s["offload_bytes_h2d"] == (s["offload_demand_loads"]
                                      + s["offload_spec_loads"]) \
        * off.expert_bytes


def test_scheduler_policy_and_accounting():
    reqs = [GenRequest(prompt=np.array([1, 2], np.int32)) for _ in range(3)]
    sched = Scheduler(max_slots=2, policy=fcfs_policy)
    for r in reqs:
        sched.submit(r)
    a = sched.pop_next()
    b = sched.pop_next()
    assert (a, b) == (reqs[0], reqs[1])  # FCFS order
    a.slot, b.slot = 0, 1
    sched.check_invariants()
    sched.evict(a, "length")
    assert a.state == "finished" and sched.n_running == 1
    c = sched.pop_next()
    assert c is reqs[2]


def test_expert_overlap_policy_prefers_hot_experts(tiny_moe_cfg,
                                                   tiny_moe_params):
    """With a usage histogram concentrated on one candidate's predicted
    experts, the policy must pick that candidate over FCFS order."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    pol = ExpertOverlapPolicy(params, cfg, n_spec=2)
    cands = [GenRequest(prompt=p)
             for p in _prompts(cfg, 4, seed=11, lo=3, hi=8)]
    usage = ExpertUsageTracker.for_config(cfg)
    # heat exactly the experts candidate 2 is predicted to route to
    target = pol._predict(cands[2])
    for l, ids in enumerate(target):
        usage.counts[l, np.asarray(ids).ravel()] = 100.0
    assert pol(cands, usage) == 2
    # empty histogram (uniform) -> falls back to FCFS (index 0)
    assert pol(cands, ExpertUsageTracker.for_config(cfg)) == 0


# ----------------------------------------------------------------------
def test_serve_batch_pad_mask_isolation(tiny_moe_cfg, tiny_moe_params):
    """Left-pad fix: a short prompt's output must not change when a
    longer prompt (forcing more padding) joins the batch."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    short, long1, long2 = _prompts(cfg, 3, seed=5, lo=18, hi=21)
    short = short[:5]
    eng = ServeEngine(params, cfg)
    a = eng.serve_batch([Request(short, 8), Request(long1, 8)])
    b = eng.serve_batch([Request(short, 8), Request(long2, 8)])
    assert a[0].completed == b[0].completed, \
        "short prompt's tokens depend on its neighbours' padding"


def test_padded_prefill_state_matches_unpadded(tiny_moe_cfg,
                                               tiny_moe_params):
    """A left-padded row's decode state (pos + live KV entries) matches
    prefilling the same prompt unpadded."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompt = _prompts(cfg, 1, seed=9)[0][:6]
    S, pad = 10, 4
    toks = np.zeros((1, S), np.int32)
    toks[0, pad:] = prompt
    mask = np.zeros((1, S), bool)
    mask[0, pad:] = True
    _, st_pad = jax.jit(lambda p, b: T.prefill(p, cfg, b, 24))(
        params, {"tokens": jnp.asarray(toks), "pad_mask": jnp.asarray(mask)})
    _, st_ref = jax.jit(lambda p, b: T.prefill(p, cfg, b, 24))(
        params, {"tokens": jnp.asarray(prompt[None])})
    assert np.asarray(st_pad["pos"]).item() == 6
    for sp, sr in zip(st_pad["stack"], st_ref["stack"]):
        live = np.asarray(sr["kv"]["pos"]) >= 0  # (periods? no: (P,1,W))
        np.testing.assert_array_equal(np.asarray(sp["kv"]["pos"]) * live,
                                      np.asarray(sr["kv"]["pos"]) * live)
        np.testing.assert_allclose(
            np.asarray(sp["kv"]["k"])[live.nonzero()[0], live.nonzero()[1],
                                      live.nonzero()[2]],
            np.asarray(sr["kv"]["k"])[live.nonzero()[0], live.nonzero()[1],
                                      live.nonzero()[2]], atol=1e-5)
