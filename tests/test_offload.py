"""Offload engine invariants (the paper's system, end to end)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core.offload_engine import (OffloadEngine, generate_plain,
                                       quantize_for_offload)
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-moe")
    params = T.init_model(jax.random.key(0), cfg)
    prompt = np.array([[72, 101, 108, 108, 111, 32, 119]], np.int32)
    return cfg, params, prompt


def test_offloading_is_pure_scheduling(setup):
    """Offloaded generation must be bit-identical to plain decode."""
    cfg, params, prompt = setup
    plain = generate_plain(params, cfg, prompt, 16)
    eng = OffloadEngine(params, cfg)
    off, stats = eng.generate(prompt, 16)
    assert (plain == off).all()
    assert stats.accesses == (16 - 1) * cfg.moe_layer_count * cfg.moe.top_k


def test_bigger_cache_fewer_demand_loads(setup):
    cfg, params, prompt = setup
    loads = {}
    for k in (1, 2, 4, 8):
        spec = OffloadSpec(cache_size=k, num_speculative=0)
        eng = OffloadEngine(params, cfg, spec)
        _, stats = eng.generate(prompt, 24)
        loads[k] = stats.demand_loads
    assert loads[1] >= loads[2] >= loads[4] >= loads[8]
    assert loads[8] <= cfg.moe_layer_count * cfg.moe.num_experts  # warmup only


def test_speculation_reduces_blocking_loads(setup):
    cfg, params, prompt = setup
    base = OffloadEngine(params, cfg, OffloadSpec(cache_size=2,
                                                  num_speculative=0))
    spec = OffloadEngine(params, cfg, OffloadSpec(cache_size=2,
                                                  num_speculative=2))
    _, s0 = base.generate(prompt, 24)
    _, s1 = spec.generate(prompt, 24)
    assert s1.demand_loads < s0.demand_loads
    assert s1.spec_hits > 0


def test_quantized_sizes_and_quality(setup):
    cfg, params, prompt = setup
    spec = OffloadSpec(expert_bits=3, attn_bits=4)
    qparams, sizes = quantize_for_offload(params, cfg, spec)
    assert sizes["experts"] > 0 and sizes["attn"] > 0
    # experts dominate and compress well below fp16
    from repro.quant.hqq import dense_nbytes
    fp16_experts = sum(
        l.size * 2 for l in jax.tree.leaves(
            [params["stack"][0]["moe"]["experts"]]))
    assert sizes["experts"] < 0.30 * fp16_experts  # ~3.5/16 bits
    # quantized model still generates (finite logits, valid tokens)
    eng = OffloadEngine(params, cfg, spec, quantized=True)
    out, stats = eng.generate(prompt, 8)
    assert out.shape == (1, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_throughput_estimates_ordering(setup):
    """Cost model must reproduce Table 2's hardware ordering."""
    cfg, params, prompt = setup
    eng = OffloadEngine(params, cfg, quantized=True)
    _, stats = eng.generate(prompt, 16)
    mixtral = get_config("mixtral-8x7b")  # project to paper scale
    from repro.core import cost_model as C
    tps = {hw: C.tokens_per_second(mixtral, C.HARDWARE[hw],
                                   stats.per_token(), 3)
           for hw in ("t4", "3060", "3080m", "a100")}
    assert tps["a100"] > tps["3080m"] > tps["3060"] > tps["t4"]
    # naive offloading is strictly worse than the cached policy
    naive = C.tokens_per_second(mixtral, C.HARDWARE["t4"],
                                C.TokenStats(0, 0, 0, 0), 3, naive=True)
    assert naive < tps["t4"]
