"""Offload engine invariants (the paper's system, end to end):
accounting mode is pure scheduling; packed mode executes on HQQ-packed
weights through the device buffer pool and stays bit-identical to the
dequantized model (DESIGN.md §6)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core import expert_pool as EP
from repro.core.offload_engine import (OffloadEngine, generate_plain,
                                       quantize_for_offload)
from repro.models import transformer as T
from repro.quant import hqq

import parity


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("tiny-moe")
    params = T.init_model(jax.random.key(0), cfg)
    prompt = np.array([[72, 101, 108, 108, 111, 32, 119]], np.int32)
    return cfg, params, prompt


# session-scoped shared engines/stores (tier-1 runtime guard): HQQ
# quantization + engine construction cost seconds each; every test that
# only *reads* generation behaviour shares one instance
@pytest.fixture(scope="module")
def qdeq(setup):
    cfg, params, _ = setup
    return quantize_for_offload(params, cfg, SPEC)[0]


@pytest.fixture(scope="module")
def packed_eng(setup):
    cfg, params, _ = setup
    return OffloadEngine(params, cfg, SPEC, quantized=True)


def test_offloading_is_pure_scheduling(setup):
    """Offloaded generation must be bit-identical to plain decode."""
    cfg, params, prompt = setup
    plain = generate_plain(params, cfg, prompt, 16)
    eng = OffloadEngine(params, cfg)
    off, stats = eng.generate(prompt, 16)
    assert (plain == off).all()
    assert stats.accesses == (16 - 1) * cfg.moe_layer_count * cfg.moe.top_k


def test_bigger_cache_fewer_demand_loads(setup):
    cfg, params, prompt = setup
    loads = {}
    for k in (1, 2, 4, 8):
        spec = OffloadSpec(cache_size=k, num_speculative=0)
        eng = OffloadEngine(params, cfg, spec)
        _, stats = eng.generate(prompt, 24)
        loads[k] = stats.demand_loads
    assert loads[1] >= loads[2] >= loads[4] >= loads[8]
    assert loads[8] <= cfg.moe_layer_count * cfg.moe.num_experts  # warmup only


def test_speculation_reduces_blocking_loads(setup):
    cfg, params, prompt = setup
    base = OffloadEngine(params, cfg, OffloadSpec(cache_size=2,
                                                  num_speculative=0))
    spec = OffloadEngine(params, cfg, OffloadSpec(cache_size=2,
                                                  num_speculative=2))
    _, s0 = base.generate(prompt, 24)
    _, s1 = spec.generate(prompt, 24)
    assert s1.demand_loads < s0.demand_loads
    assert s1.spec_hits > 0


def test_quantized_sizes_and_quality(setup, packed_eng):
    cfg, params, prompt = setup
    sizes = packed_eng.size_report
    assert sizes["experts"] > 0 and sizes["attn"] > 0
    # experts dominate and compress well below fp16
    fp16_experts = sum(
        l.size * 2 for l in jax.tree.leaves(
            [params["stack"][0]["moe"]["experts"]]))
    assert sizes["experts"] < 0.30 * fp16_experts  # ~3.5/16 bits
    # quantized model still generates (finite logits, valid tokens)
    out, stats = packed_eng.generate(prompt, 8)
    assert out.shape == (1, 8)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


# ----------------------------------------------------------------------
# packed execution (DESIGN.md §6)
SPEC = OffloadSpec(cache_size=2, num_speculative=2, lookahead=1,
                   expert_bits=3, attn_bits=4)


def test_packed_generate_bit_identical_to_dequantized(setup, qdeq,
                                                      packed_eng):
    """Acceptance: quantized (packed) generation is bit-identical to
    decoding the dequantized model, while experts stay HQQ-packed —
    the only dense expert weights ever built are per-slot dequants."""
    cfg, params, prompt = setup
    oracle = parity.oracle_streams(qdeq, cfg, [prompt[0]], [12])[0]
    out, stats = parity.run_offload_generate(packed_eng, prompt, 12)
    parity.assert_tokens_equal(out, oracle, "packed vs dequantized oracle")
    # real traffic happened and the LRU worked
    assert stats.demand_loads > 0 and stats.hits > 0
    assert stats.n_tokens == 11
    # no dense expert stack exists in the executable params
    for i in range(cfg.pattern_period):
        ex = packed_eng.params["stack"][i]["moe"]["experts"]
        assert all(leaf.size == 0 for leaf in jax.tree.leaves(ex))


def test_packed_einsum_mode_matches_fused(setup, packed_eng):
    """fused=False (per-slot dequant into the gather einsums) and
    fused=True (kernels/ops.dequant_matmul_batched) agree bitwise."""
    cfg, params, prompt = setup
    b = OffloadEngine(params, cfg, SPEC, quantized=True, fused=False)
    out_a, _ = packed_eng.generate(prompt, 10)
    out_b, _ = b.generate(prompt, 10)
    assert (out_a == out_b).all()


def test_packed_pipelined_matches_synchronous_unrolled(setup, packed_eng):
    """Tentpole invariant (DESIGN.md §7): the vectorized overlap-pipelined
    stream produces bitwise the tokens AND the transfer counters of the
    PR-2 synchronous per-(token, k) data plane."""
    cfg, params, prompt = setup
    base = OffloadEngine(params, cfg, SPEC, quantized=True,
                         pipelined=False, vectorized=False)
    out_b, sb = parity.run_offload_generate(base, prompt, 8)
    out_p, sp = parity.run_offload_generate(packed_eng, prompt, 8)
    parity.assert_tokens_equal(out_p, out_b, "pipelined vs sync")
    assert parity.offload_counters(sp) == parity.offload_counters(sb)


def test_generate_rng_none_samples(setup, qdeq, packed_eng):
    """Regression: ``generate(greedy=False)`` without an rng used to
    crash inside ``jax.random.split``; both engine modes must fall back
    to a seeded default key."""
    cfg, params, prompt = setup
    out_p, _ = packed_eng.generate(prompt, 4, greedy=False)
    acct = OffloadEngine(qdeq, cfg, SPEC, quantized=False)
    out_a, _ = acct.generate(prompt, 4, greedy=False)
    for out in (out_p, out_a):
        assert out.shape == (1, 4)
        assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_usage_tracker_overlap_normalizes_by_scored_layers():
    """Regression: ``ExpertUsageTracker.overlap`` summed only the first
    ``n_layers`` prediction lists but divided by the TOTAL supplied,
    deflating scores for candidates with surplus layers."""
    from repro.core.offload_engine import ExpertUsageTracker

    tr = ExpertUsageTracker(n_layers=2, n_experts=4)
    tr.update([np.array([[0, 1]]), np.array([[2, 3]])])
    pred = [np.array([[0, 1]]), np.array([[2, 3]])]
    base = tr.overlap(pred)
    assert base > 0
    # extra prediction layers beyond the tracker are not scored — they
    # must not dilute the score either
    assert tr.overlap(pred + pred) == pytest.approx(base)


def test_device_buffer_pool_holds_cache_size_slots(setup):
    """Acceptance: the device buffer pool holds exactly ``cache_size``
    expert slots per MoE layer (plus ``num_speculative`` staging
    buffers); only the host store holds all E experts."""
    cfg, params, prompt = setup
    spec = OffloadSpec(cache_size=3, num_speculative=2, expert_bits=3,
                       attn_bits=4)
    eng = OffloadEngine(params, cfg, spec, quantized=True)
    _, _ = eng.generate(prompt, 4)
    ps = eng._last_pool_state
    L = eng.n_moe_layers
    for qt in ps.pool:
        assert qt.shape[:2] == (L, spec.cache_size)
    for qt in ps.staging:
        assert qt.shape[:2] == (L, spec.num_speculative)
    for qt in eng.store:
        assert qt.shape[:2] == (L, cfg.moe.num_experts)
    assert ps.lru.cache_ids.shape == (L, spec.cache_size)


def test_packed_stats_are_measured_copies(setup, packed_eng):
    """expert_bytes equals the real packed size of one expert's slot
    (packed codes + scale/zero + meta), not a cost-model estimate."""
    one = packed_eng.store.slice(0, 0)
    assert packed_eng.expert_bytes == one.nbytes()
    assert packed_eng.size_report["experts"] == packed_eng.store.nbytes()


def test_packed_counters_match_accounting_replay(setup, qdeq, packed_eng):
    """The packed engine's measured hit/load counters equal the
    accounting engine's PyLRU replay over the (bitwise-identical)
    dequantized model — same routing, same cache policy, two
    implementations."""
    cfg, params, prompt = setup
    acct = OffloadEngine(qdeq, cfg, SPEC, quantized=False)
    out_p, sp = parity.run_offload_generate(packed_eng, prompt, 12)
    out_a, sa = parity.run_offload_generate(acct, prompt, 12)
    parity.assert_tokens_equal(out_p, out_a, "packed vs accounting")
    assert parity.offload_counters(sp) == parity.offload_counters(sa)


def test_pool_slots_agree_with_lru_state(setup, packed_eng):
    """Data-plane/state-machine coherence: after generation, each LRU
    slot's packed bytes are exactly the host store's bytes for the
    expert the state machine says lives there."""
    cfg, params, prompt = setup
    eng = packed_eng
    eng.generate(prompt, 10)
    ps = eng._last_pool_state
    ids = np.asarray(ps.lru.cache_ids)  # (L, k)
    for l in range(eng.n_moe_layers):
        for s in range(SPEC.cache_size):
            e = int(ids[l, s])
            if e < 0:
                continue
            slot = ps.pool.slice(l, s)
            ref = eng.store.slice(l, e)
            for qs, qr in zip(slot, ref):
                assert (np.asarray(qs.packed) == np.asarray(qr.packed)).all()
                assert (np.asarray(qs.scale) == np.asarray(qr.scale)).all()


def _packed_moe_setup(bits=3):
    """Store + cold pool for moe-level packed-path unit tests."""
    cfg = get_config("tiny-moe")  # dims divide every scheme's group size
    params = T.init_model(jax.random.key(20), cfg)
    spec = OffloadSpec(cache_size=2, num_speculative=2, expert_bits=bits,
                       attn_bits=4)
    store = EP.build_store(params, cfg, spec)
    pstate = EP.init_pool_state(store, spec)
    return cfg, params, spec, store, pstate


@pytest.mark.parametrize("fused", [True, False])
def test_moe_packed_matches_gather_on_dequantized_stack(fused):
    """moe_apply_packed == moe_apply_gather over the dequantized expert
    stack, bitwise — per-slot dequant commutes with stacking and both
    paths run the same matmuls (the packed-execution parity invariant
    at the single-layer level)."""
    import jax.numpy as jnp

    from repro.core.trace import stacked_routers
    from repro.models import moe as M

    cfg, params, spec, store, pstate = _packed_moe_setup()
    l = 2
    p_moe = T.layer_params(params, cfg, l)["moe"]
    ex_deq = {name: hqq.dequantize(hqq.slice_leading(qt, l),
                                   jnp.dtype(cfg.dtype))
              for name, qt in zip(("w_gate", "w_up", "w_down"), store)}
    x = jax.random.normal(jax.random.key(21), (1, cfg.d_model))
    y_ref, route_ref = M.moe_apply_gather(
        {"router": p_moe["router"], "experts": ex_deq}, cfg, x)
    routers = jnp.asarray(stacked_routers(params, cfg))
    y, route, pstate2 = M.moe_apply_packed(
        p_moe, cfg, x, store, pstate, jnp.asarray(l), routers,
        lookahead=spec.lookahead, n_spec=spec.num_speculative, fused=fused)
    assert (np.asarray(route["ids"]) == np.asarray(route_ref["ids"])).all()
    assert (np.asarray(y) == np.asarray(y_ref)).all()
    # cold pool -> both routed experts were demand loads
    counts = np.asarray(pstate2.counts)
    assert counts[2] == cfg.moe.top_k
    # speculation staged into layer l+1's buffers
    assert counts[3] > 0
    assert (np.asarray(pstate2.lru.spec_ids[l + 1]) >= 0).all()


def test_moe_packed_prefill_ffn_matches_dense_dispatch():
    """Expert-streaming dispatch (the packed prefill path) == dispatch
    over the dequantized stack, bitwise."""
    import jax.numpy as jnp

    from repro.models import moe as M

    cfg, params, spec, store, pstate = _packed_moe_setup()
    l = 0
    p_moe = T.layer_params(params, cfg, l)["moe"]
    ex_deq = {name: hqq.dequantize(hqq.slice_leading(qt, l),
                                   jnp.dtype(cfg.dtype))
              for name, qt in zip(("w_gate", "w_up", "w_down"), store)}
    x = jax.random.normal(jax.random.key(22), (24, cfg.d_model))
    y_ref, _ = M.moe_apply_dispatch(
        {"router": p_moe["router"], "experts": ex_deq}, cfg, x)
    y, _ = M.moe_apply_dispatch(
        p_moe, cfg, x,
        expert_ffn_fn=M.packed_expert_ffn(store, jnp.asarray(l), cfg))
    assert (np.asarray(y) == np.asarray(y_ref)).all()


def test_throughput_estimates_ordering(setup, packed_eng):
    """Cost model must reproduce Table 2's hardware ordering."""
    cfg, params, prompt = setup
    eng = packed_eng  # default spec == SPEC; shared engine (runtime guard)
    _, stats = eng.generate(prompt, 16)
    mixtral = get_config("mixtral-8x7b")  # project to paper scale
    from repro.core import cost_model as C
    tps = {hw: C.tokens_per_second(mixtral, C.HARDWARE[hw],
                                   stats.per_token(), 3)
           for hw in ("t4", "3060", "3080m", "a100")}
    assert tps["a100"] > tps["3080m"] > tps["3060"] > tps["t4"]
    # naive offloading is strictly worse than the cached policy
    naive = C.tokens_per_second(mixtral, C.HARDWARE["t4"],
                                C.TokenStats(0, 0, 0, 0), 3, naive=True)
    assert naive < tps["t4"]
