"""Serving CLI regression tests (mostly no engine construction — the
arg handling itself is under test; the one end-to-end case at the
bottom checks ``--num-draft-tokens 1`` really decodes bitwise).

The load-bearing ones: ``--cache-size 0`` / ``--num-speculative 0`` are
the paper's no-cache / no-speculation ablations, and ``--num-draft-
tokens 0`` is the no-speculation ablation of DESIGN.md §11; the
launcher used to treat zero as "flag not given" via ``or``-truthiness
and silently ran the defaults instead."""
import re

import pytest

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.launch.serve import (build_parser, resolve_draft,
                                resolve_kv_features, resolve_offload_spec,
                                resolve_top_k)


def _spec_for(argv):
    """Exactly what ``main`` computes for ``--offload`` runs."""
    args = build_parser().parse_args(argv)
    cfg = get_config(args.arch)
    return resolve_offload_spec(cfg.offload or OffloadSpec(),
                                args.cache_size, args.num_speculative)


def test_zero_ablation_flags_respected():
    spec = _spec_for(["--offload", "--cache-size", "0",
                      "--num-speculative", "0"])
    assert spec.cache_size == 0
    assert spec.num_speculative == 0


def test_unset_flags_keep_arch_defaults():
    base = get_config("tiny-moe").offload
    spec = _spec_for(["--offload"])
    assert spec == base


def test_partial_override_keeps_other_default():
    base = get_config("tiny-moe").offload
    spec = _spec_for(["--offload", "--cache-size", "5"])
    assert spec.cache_size == 5
    assert spec.num_speculative == base.num_speculative
    spec = _spec_for(["--offload", "--num-speculative", "0"])
    assert spec.cache_size == base.cache_size
    assert spec.num_speculative == 0


def test_resolve_is_identity_without_overrides():
    base = OffloadSpec(cache_size=4, num_speculative=1)
    assert resolve_offload_spec(base) is base


# ----------------------------------------------------------------------
# token-level speculation flags (DESIGN.md §11)
def test_draft_flags_unset_disable_cleanly():
    # no --draft-config: speculation off no matter what k says
    assert resolve_draft(None, None) == (None, 0)
    assert resolve_draft(None, 5) == (None, 0)
    args = build_parser().parse_args([])
    assert resolve_draft(args.draft_config, args.num_draft_tokens) == \
        (None, 0)


def test_draft_zero_tokens_is_real_ablation():
    # --num-draft-tokens 0 disables; it must NOT or-truthiness back to 4
    assert resolve_draft("tiny-draft", 0) == (None, 0)
    assert resolve_draft("tiny-draft", -3) == (None, 0)
    args = build_parser().parse_args(
        ["--continuous", "--draft-config", "tiny-draft",
         "--num-draft-tokens", "0"])
    assert resolve_draft(args.draft_config, args.num_draft_tokens) == \
        (None, 0)


def test_draft_default_and_explicit_k():
    assert resolve_draft("tiny-draft", None) == ("tiny-draft", 4)
    assert resolve_draft("tiny-draft", 1) == ("tiny-draft", 1)


# ----------------------------------------------------------------------
# --top-k-override (DESIGN.md §12's E=1 spectrum, served live): routing
# to fewer experts per token than the arch default is the h2d ablation
# knob, and it must obey the same None-vs-0 discipline as the flags above
def test_top_k_override_unset_keeps_arch_default():
    cfg = get_config("tiny-moe")
    assert resolve_top_k(cfg, None) is cfg
    args = build_parser().parse_args([])
    assert args.top_k_override is None


def test_top_k_override_zero_is_error_not_default():
    # 0/negative must raise, NOT or-truthiness back to the arch top_k
    cfg = get_config("tiny-moe")
    with pytest.raises(ValueError, match=">= 1"):
        resolve_top_k(cfg, 0)
    with pytest.raises(ValueError, match=">= 1"):
        resolve_top_k(cfg, -2)


def test_top_k_override_applies_and_clamps():
    cfg = get_config("tiny-moe")
    assert resolve_top_k(cfg, 1).moe.top_k == 1
    # can't route to more experts than the router scores: clamp down
    assert resolve_top_k(cfg, 999).moe.top_k == cfg.moe.top_k
    # only routing changes — expert population is untouched
    assert resolve_top_k(cfg, 1).moe.num_experts == cfg.moe.num_experts


def test_top_k_override_rejects_dense_arch():
    with pytest.raises(ValueError, match="dense"):
        resolve_top_k(get_config("stablelm-1.6b"), 1)


# ----------------------------------------------------------------------
# prefix-cache / preemption flags (DESIGN.md §13): same None-vs-0
# discipline — 0 pages is the no-cache (resp. recompute-only) ablation,
# never a silent fall-back to a default
def test_kv_features_unset_are_off():
    args = build_parser().parse_args([])
    assert args.prefix_cache is None
    assert args.kv_host_pages is None
    assert args.preemption == "off"
    assert resolve_kv_features(None, "off", None) == (0, False, 0)


def test_prefix_cache_zero_is_real_ablation():
    # --prefix-cache 0 must disable the cache, not or-truthiness into
    # some default budget; negatives are an explicit error
    assert resolve_kv_features(0, "off", None) == (0, False, 0)
    assert resolve_kv_features(0, "on", None) == (0, True, 0)
    with pytest.raises(ValueError, match=">= 0"):
        resolve_kv_features(-1, "off", None)


def test_kv_host_pages_zero_is_recompute_only():
    # --kv-host-pages 0 with preemption on = drop-and-recompute mode, a
    # real ablation distinct from "flag not given"
    assert resolve_kv_features(None, "on", 0) == (0, True, 0)
    assert resolve_kv_features(4, "on", 16) == (4, True, 16)
    with pytest.raises(ValueError, match=">= 0"):
        resolve_kv_features(None, "on", -8)


def test_kv_host_pages_without_preemption_rejected():
    # a swap pool nothing ever swaps into is a config error, even at 0
    with pytest.raises(ValueError, match="--preemption"):
        resolve_kv_features(None, "off", 16)
    with pytest.raises(ValueError, match="--preemption"):
        resolve_kv_features(None, "off", 0)


def test_kv_feature_flags_parse():
    args = build_parser().parse_args(
        ["--continuous", "--kv-page", "8", "--prefix-cache", "0",
         "--preemption", "on", "--kv-host-pages", "0"])
    assert resolve_kv_features(args.prefix_cache, args.preemption,
                               args.kv_host_pages) == (0, True, 0)


def test_config_alias_for_arch():
    # the zoo entry point: --config is the documented spelling, --arch
    # the historical one; both land in args.arch
    assert build_parser().parse_args(
        ["--config", "xlstm-1.3b"]).arch == "xlstm-1.3b"
    assert build_parser().parse_args(
        ["--arch", "tiny-moe"]).arch == "tiny-moe"


def test_draft_one_token_bitwise_end_to_end(monkeypatch, capsys):
    """``--num-draft-tokens 1`` (the C=2 boundary) through ``main()``
    itself: the per-request generations printed by the continuous run
    must be identical with and without speculation."""
    from repro.launch import serve

    def run(extra):
        argv = ["serve", "--continuous", "--arch", "tiny-moe",
                "--n-requests", "2", "--max-new", "8", "--max-slots", "2",
                "--slot-len", "64", "--seed", "3"] + extra
        monkeypatch.setattr("sys.argv", argv)
        serve.main()
        out = capsys.readouterr().out
        found = re.findall(r"req (\d+) finished .*?: ('.*')", out)
        assert len(found) == 2, f"expected 2 finished requests:\n{out}"
        # rids are a process-global counter — compare texts in rid order
        return [t for _, t in sorted(found, key=lambda x: int(x[0]))], out

    base, _ = run([])
    spec, out = run(["--draft-config", "tiny-draft",
                     "--num-draft-tokens", "1"])
    assert spec == base, "k=1 speculation changed the decoded text"
    assert "[spec]" in out, "speculative run must report spec accounting"
