"""Serving CLI regression tests (no engine construction — the arg
handling itself is under test).

The load-bearing one: ``--cache-size 0`` / ``--num-speculative 0`` are
the paper's no-cache / no-speculation ablations; the launcher used to
treat them as "flag not given" via ``or``-truthiness and silently ran
the arch defaults instead."""
import pytest

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.launch.serve import build_parser, resolve_offload_spec


def _spec_for(argv):
    """Exactly what ``main`` computes for ``--offload`` runs."""
    args = build_parser().parse_args(argv)
    cfg = get_config(args.arch)
    return resolve_offload_spec(cfg.offload or OffloadSpec(),
                                args.cache_size, args.num_speculative)


def test_zero_ablation_flags_respected():
    spec = _spec_for(["--offload", "--cache-size", "0",
                      "--num-speculative", "0"])
    assert spec.cache_size == 0
    assert spec.num_speculative == 0


def test_unset_flags_keep_arch_defaults():
    base = get_config("tiny-moe").offload
    spec = _spec_for(["--offload"])
    assert spec == base


def test_partial_override_keeps_other_default():
    base = get_config("tiny-moe").offload
    spec = _spec_for(["--offload", "--cache-size", "5"])
    assert spec.cache_size == 5
    assert spec.num_speculative == base.num_speculative
    spec = _spec_for(["--offload", "--num-speculative", "0"])
    assert spec.cache_size == base.cache_size
    assert spec.num_speculative == 0


def test_resolve_is_identity_without_overrides():
    base = OffloadSpec(cache_size=4, num_speculative=1)
    assert resolve_offload_spec(base) is base
