"""Shared cross-plane parity harness (no ``test_`` prefix — imported,
not collected).

The repo's load-bearing acceptance invariant is that every execution
plane, KV layout and admission mode emits the SAME greedy token stream
— and, where the design says so, the same h2d transfer counters.  That
invariant used to be asserted by hand-rolled loops scattered across
``test_offload.py`` / ``test_runtime.py`` / ``test_paged_kv.py``; this
module is the one implementation they (and the speculative-decoding
matrix in ``test_spec_decode.py``) all drive, so a new plane or KV
layout gets the whole grid by adding one factory entry.

Pieces:

* :func:`make_prompts` / :func:`oracle_streams` — seeded workloads and
  the ``generate_plain`` B=1 oracle every engine must reproduce.
* :func:`run_offload_generate` / :func:`offload_plane_engines` — the
  batch OffloadEngine across its planes (packed pipelined / vectorized
  / PR-2 sync / accounting replay) with measured-counter extraction.
* :func:`run_continuous` + :data:`CONTINUOUS_KV_VARIANTS` — the
  continuous engine across KV layouts (dense / paged / pinned-horizon
  paged) and admission modes (whole-prompt / chunked / budgeted).
* :func:`assert_tokens_equal` / :func:`offload_counters` /
  :func:`continuous_counters` — the equality assertions, with readable
  divergence output.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.offload_engine import OffloadEngine, generate_plain
from repro.serving.engine import ContinuousEngine

# the four measured transfer counters every offload plane must agree on
OFFLOAD_COUNTERS = ("hits", "spec_hits", "demand_loads", "spec_loads")
# the continuous engine's legacy-flat h2d keys (offloaded mode)
CONTINUOUS_H2D_KEYS = ("offload_demand_loads", "offload_spec_loads",
                       "offload_bytes_h2d")

# ContinuousEngine constructor overlays, keyed by variant name — the KV
# layout x admission grid the parity tests sweep.  ``paged_exact`` pins
# the table horizon (bitwise-logits mode); the others are the perf modes
# whose greedy token streams must still match.
CONTINUOUS_KV_VARIANTS: Dict[str, dict] = {
    "dense": {},
    "dense_chunked": dict(prefill_chunk=4),
    "paged": dict(kv_page=16),
    "paged_exact": dict(kv_page=16, ragged_bucket=False),
    "paged_chunked": dict(kv_page=16, prefill_chunk=4),
    # prefix-reuse mode (DESIGN.md §13): cached full pages are adopted
    # at admission and prefill starts at the divergence point — the
    # greedy stream must stay bitwise whether or not any prompt hits
    "paged_prefix": dict(kv_page=8, prefix_cache_pages=8),
}


def make_prompts(cfg, lens: Sequence[int], seed: int = 1
                 ) -> List[np.ndarray]:
    """Seeded random prompts (token 0 excluded — it is the pad id)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, int(n)).astype(np.int32)
            for n in lens]


def make_extras(cfg, n: int, seed: int = 3) -> List[dict]:
    """Per-request admission extras: enc-dec archs get a seeded
    ``audio_embeds`` frontend output each; everything else gets None."""
    if not cfg.is_encoder_decoder:
        return [None] * n
    rng = np.random.default_rng(seed)
    return [{"audio_embeds": rng.standard_normal(
        (cfg.encoder_seq, cfg.d_model)).astype(np.float32)}
        for _ in range(n)]


def oracle_streams(params, cfg, prompts, max_news,
                   extras=None) -> List[List[int]]:
    """The B=1 ``generate_plain`` greedy stream per request — the
    reference every engine/plane/layout must reproduce bitwise."""
    extras = extras or [None] * len(prompts)

    def batched(e):  # the B=1 oracle wants a leading batch axis
        if e is None:
            return None
        return {"audio_embeds": np.asarray(e["audio_embeds"],
                                           np.float32)[None]}

    return [generate_plain(params, cfg, p[None], m,
                           extras=batched(e))[0].tolist()
            for p, m, e in zip(prompts, max_news, extras)]


def assert_tokens_equal(got, want, label: str) -> None:
    assert got == want, (f"{label}: token stream diverged\n"
                         f"  got : {got}\n  want: {want}")


# ----------------------------------------------------------------------
# batch OffloadEngine drivers
def offload_counters(stats):
    """OffloadStats -> the measured transfer-counter tuple."""
    return tuple(getattr(stats, k) for k in OFFLOAD_COUNTERS)


def run_offload_generate(eng: OffloadEngine, prompt, max_new: int, **kw):
    """One B=1 generation -> (token list, OffloadStats)."""
    prompt = np.asarray(prompt, np.int32).reshape(1, -1)
    out, stats = eng.generate(prompt, max_new, **kw)
    return out[0].tolist(), stats


def offload_plane_engines(params, qdeq, cfg, spec
                          ) -> Dict[str, OffloadEngine]:
    """The offload engine across its execution planes.  ``qdeq`` is the
    dequantized model from ``quantize_for_offload`` — the accounting
    plane decodes it so its tokens are comparable bitwise with the
    packed planes (which execute the same quantized weights)."""
    return {
        "packed_pipelined": OffloadEngine(params, cfg, spec,
                                          quantized=True),
        "packed_vectorized": OffloadEngine(params, cfg, spec,
                                           quantized=True,
                                           pipelined=False),
        "packed_sync": OffloadEngine(params, cfg, spec, quantized=True,
                                     pipelined=False, vectorized=False),
        "accounting": OffloadEngine(qdeq, cfg, spec, quantized=False),
    }


# ----------------------------------------------------------------------
# ContinuousEngine driver
def run_continuous(params, cfg, prompts, max_news, *, max_slots: int = 2,
                   slot_len: int = 64, eos_id=None, max_steps: int = 800,
                   extras=None, **kw):
    """Build, submit, drain -> (per-request token lists, engine).
    Asserts every request actually finished (a hung engine must fail
    the parity test, not time out silently)."""
    eng = ContinuousEngine(params, cfg, max_slots=max_slots,
                           slot_len=slot_len, eos_id=eos_id, **kw)
    extras = extras or [None] * len(prompts)
    reqs = [eng.submit(p, m, extras=e)
            for p, m, e in zip(prompts, max_news, extras)]
    eng.run(max_steps=max_steps)
    unfinished = [r.rid for r in reqs if r.state != "finished"]
    assert not unfinished, f"requests never finished: {unfinished}"
    return [r.generated for r in reqs], eng


def continuous_counters(eng: ContinuousEngine) -> Dict[str, float]:
    """The offloaded continuous engine's h2d counters (legacy-flat)."""
    s = eng.stats()
    return {k: s[k] for k in CONTINUOUS_H2D_KEYS}
