"""Speculative expert prediction (paper §3.2) behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import speculative as S


def test_predict_shapes():
    router = jax.random.normal(jax.random.key(0), (16, 8))
    hidden = jax.random.normal(jax.random.key(1), (3, 16))
    ids = S.predict_experts(router, hidden, 2)
    assert ids.shape == (3, 2)
    assert bool((ids >= 0).all()) and bool((ids < 8).all())


def test_recall_perfect_when_hidden_identical():
    """If hidden states don't change between layers, lookahead-1 recall at
    n=top_k is exactly 1 (the inductive bias the paper exploits, in the
    limit)."""
    rng = np.random.default_rng(0)
    T, L, D, E, K = 40, 5, 16, 8, 2
    hiddens = np.repeat(rng.standard_normal((T, 1, D)), L, axis=1)
    routers = np.repeat(rng.standard_normal((1, D, E)), L, axis=0)
    logits = np.einsum("tld,lde->tle", hiddens, routers)
    actual = np.argsort(-logits, -1)[..., :K]
    rec = S.recall_curve(hiddens, routers, actual, lookaheads=[1],
                         n_fetch_list=[K])
    assert rec[(1, K)] == 1.0


def test_recall_increases_with_n_fetch():
    rng = np.random.default_rng(1)
    T, L, D, E, K = 60, 6, 16, 8, 2
    hiddens = rng.standard_normal((T, L, D))
    # consecutive hidden states correlated (residual stream)
    for l in range(1, L):
        hiddens[:, l] = 0.9 * hiddens[:, l - 1] + 0.45 * hiddens[:, l]
    routers = rng.standard_normal((L, D, E))
    logits = np.einsum("tld,lde->tle", hiddens, routers)
    actual = np.argsort(-logits, -1)[..., :K]
    rec = S.recall_curve(hiddens, routers, actual, [1, 2],
                         [1, 2, 4, 8])
    vals = [rec[(1, n)] for n in (1, 2, 4, 8)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert rec[(1, 8)] == 1.0  # fetching all experts is always perfect
    # correlated stream: nearer lookahead predicts at least as well
    assert rec[(1, 2)] >= rec[(2, 2)] - 0.05


def test_recall_curve_offline_smoke():
    """Offline smoke on a fully synthetic random trace (no structure at
    all): every recall value is a probability, monotone in n_fetch for
    EVERY lookahead, and n=E is exactly 1.0 — the sanity floor for the
    Fig-2 reproduction machinery."""
    rng = np.random.default_rng(5)
    T, L, D, E, K = 32, 4, 8, 8, 2
    hiddens = rng.standard_normal((T, L, D))
    routers = rng.standard_normal((L, D, E))
    actual = rng.integers(0, E, (T, L, K))
    lookaheads, fetches = [1, 2, 3], [1, 2, 4, 8]
    rec = S.recall_curve(hiddens, routers, actual, lookaheads, fetches)
    assert set(rec) == {(j, n) for j in lookaheads for n in fetches}
    assert all(0.0 <= v <= 1.0 for v in rec.values())
    for j in lookaheads:
        vals = [rec[(j, n)] for n in fetches]
        assert all(b >= a for a, b in zip(vals, vals[1:])), \
            f"recall not monotone in n_fetch at lookahead {j}: {vals}"
        assert rec[(j, E)] == 1.0
