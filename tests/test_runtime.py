"""Unified step-plan runtime (DESIGN.md §8): chunked prefill is
bitwise-identical to whole-prompt prefill on every plane, the
token-budget policy's scheduling invariants hold on random traces
(property-based when ``hypothesis`` is installed, with a seeded stdlib
fallback that ALWAYS runs), engines share one compiled block program per
(cfg, plane, mode) per process, and chunked continuous serving matches
unchunked serving token-for-token — including composed with packed
offloading, where the h2d counters must agree exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on hosts w/o the extra
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core.offload_engine import (OffloadEngine, generate_plain,
                                       quantize_for_offload)
from repro.models import transformer as T
from repro.runtime import Admission, Executor, TokenBudgetPolicy
from repro.serving.engine import ContinuousEngine

import parity


def _state_leaves(state):
    return [np.asarray(l) for l in jax.tree.leaves(state)]


def _assert_states_bitwise(a, b):
    for la, lb in zip(_state_leaves(a), _state_leaves(b)):
        np.testing.assert_array_equal(la, lb)


def _prompt(cfg, S, seed=0, B=1):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)


# ----------------------------------------------------------------------
# chunked == whole, bitwise, per plane
def test_chunked_prefill_bitwise_plain(tiny_moe_cfg, tiny_moe_params):
    """Acceptance: chunk size never changes a bit of the prefill result
    — logits, KV state and positions — because a chunk only changes the
    number of query rows per dispatch, never a reduction shape."""
    ex = Executor(tiny_moe_params, tiny_moe_cfg)
    prompt = _prompt(tiny_moe_cfg, 13, seed=3, B=2)  # B=2 lock-step rows
    whole_l, whole_s, _ = ex.prefill(prompt, 32)
    for chunk in (1, 4, 5, 13, 64):
        l, s, _ = ex.prefill(prompt, 32, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(whole_l[:, -1]),
                                      np.asarray(l[:, -1]))
        _assert_states_bitwise(whole_s, s)


@pytest.fixture(scope="module")
def packed_setup(tiny_moe_cfg, tiny_moe_params):
    spec = OffloadSpec(cache_size=2, num_speculative=2, expert_bits=3,
                       attn_bits=4)
    exec_params, _, store = quantize_for_offload(
        tiny_moe_params, tiny_moe_cfg, spec, pack_experts=True)
    qdeq, _ = quantize_for_offload(tiny_moe_params, tiny_moe_cfg, spec)
    return spec, exec_params, store, qdeq


@pytest.mark.parametrize("plane", ["packed_vectorized", "packed_pipelined"])
def test_chunked_prefill_bitwise_packed(tiny_moe_cfg, packed_setup, plane):
    """Same acceptance on the packed planes — chunks stream experts from
    the host store, and the result equals BOTH any other chunking AND
    the plain-plane prefill of the dequantized model, bitwise."""
    spec, exec_params, store, qdeq = packed_setup
    ex = Executor(exec_params, tiny_moe_cfg, plane=plane, spec=spec,
                  store=store)
    prompt = _prompt(tiny_moe_cfg, 11, seed=5)
    whole_l, whole_s, _ = ex.prefill(prompt, 24)
    for chunk in (3, 11):
        l, s, _ = ex.prefill(prompt, 24, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(whole_l[:, -1]),
                                      np.asarray(l[:, -1]))
        _assert_states_bitwise(whole_s, s)
    # packed chunked prefill == dequantized-model prefill (plain plane)
    oracle = Executor(qdeq, tiny_moe_cfg)
    ol, os_, _ = oracle.prefill(prompt, 24, chunk=4)
    np.testing.assert_array_equal(np.asarray(whole_l[:, -1]),
                                  np.asarray(ol[:, -1]))
    _assert_states_bitwise(whole_s, os_)


def test_recurrent_stacks_prefill_chunked_bitwise():
    """Recurrent mixers run the SAME chunked prefill program as
    attention stacks (DESIGN.md §12): the chunk forms compose their
    carries exactly, so whole-prompt prefill and any chunking of it
    agree bitwise on every state plane — the chunkwise==recurrent
    guarantee of tests/test_recurrent.py lifted to the executor.
    (Chunk sizes avoid a size-1 tail: the dense MLP's S=1 GEMV path
    folds differently from its GEMM path at ~1e-7, so only C >= 2
    chunkings of MLP-bearing stacks are bitwise.)"""
    cfg = get_config("recurrentgemma-9b").reduced()
    params = T.init_model(jax.random.key(2), cfg)
    prompt = _prompt(cfg, 7, seed=2)
    ex = Executor(params, cfg)
    whole_l, whole_s, _ = ex.prefill(prompt, 16)
    for chunk in (4, 7):  # 7 -> 4+3 and whole; no size-1 tails
        l, s, _ = ex.prefill(prompt, 16, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(whole_l[:, -1]),
                                      np.asarray(l[:, -1]))
        _assert_states_bitwise(whole_s, s)
    # ... and generate_plain is prefill-chunk invariant at token level
    out = generate_plain(params, cfg, prompt, 5)
    assert out.shape == (1, 5)
    out_c = generate_plain(params, cfg, prompt, 5, prefill_chunk=4)
    assert (out == out_c).all()


def test_generate_plain_prefill_chunk_invariant(tiny_moe_cfg,
                                                tiny_moe_params):
    prompt = _prompt(tiny_moe_cfg, 9, seed=7)
    a = generate_plain(tiny_moe_params, tiny_moe_cfg, prompt, 10)
    b = generate_plain(tiny_moe_params, tiny_moe_cfg, prompt, 10,
                       prefill_chunk=2)
    assert (a == b).all()


# ----------------------------------------------------------------------
# continuous serving: chunked admission == unchunked, token for token
def test_continuous_chunked_matches_unchunked(tiny_moe_cfg,
                                              tiny_moe_params):
    """Acceptance: with --prefill-chunk the engine emits, per request,
    bitwise the tokens of unchunked admission under greedy decoding —
    while long prompts no longer monopolise whole steps."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = parity.make_prompts(cfg, (21, 5, 17, 4, 12), seed=17)
    max_news = [6, 9, 4, 8, 5]

    base, _ = parity.run_continuous(params, cfg, prompts, max_news)
    for chunk in (4, 7):
        toks, eng = parity.run_continuous(params, cfg, prompts, max_news,
                                          prefill_chunk=chunk)
        parity.assert_tokens_equal(toks, base, f"chunked({chunk})")
        # the budget really bounded every step
        assert eng.budget.token_budget == 2 + chunk
    # and both match the B=1 oracle
    parity.assert_tokens_equal(
        base, parity.oracle_streams(params, cfg, prompts, max_news),
        "unchunked vs oracle")


def test_continuous_offloaded_chunked_matches_and_counters_agree(
        tiny_moe_cfg, tiny_moe_params):
    """Acceptance (packed plane): chunked prefill composed with packed
    offloading matches unchunked token-for-token, and the h2d transfer
    counters are IDENTICAL — prefill chunks stream from the host store
    (zero pool traffic) and, with the pool sized to the expert count,
    decode misses are exactly the cold set either way."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    spec = OffloadSpec(cache_size=cfg.moe.num_experts, num_speculative=0,
                       expert_bits=3, attn_bits=4)
    off = OffloadEngine(params, cfg, spec, quantized=True)
    prompts = parity.make_prompts(cfg, (19, 5, 14), seed=23)
    max_news = [5, 7, 4]

    base_toks, base_eng = parity.run_continuous(
        None, cfg, prompts, max_news, slot_len=48, offload=off)
    toks, eng = parity.run_continuous(
        None, cfg, prompts, max_news, slot_len=48, offload=off,
        prefill_chunk=5)
    parity.assert_tokens_equal(toks, base_toks, "offloaded chunked")
    base_c, c = (parity.continuous_counters(e) for e in (base_eng, eng))
    assert c == base_c, f"h2d counters changed under chunking: {c} " \
        f"vs {base_c}"
    assert c["offload_demand_loads"] > 0


# ----------------------------------------------------------------------
# token-budget policy invariants (property + seeded fallback)
def _check_budget_policy(chunk_size, token_budget, max_rows, prompt_lens,
                         decode_pattern_seed):
    """Drive the policy over a synthetic admission trace; assert every
    plan respects the budget, chunks are emitted in order and partition
    each prompt, and decode rows are never dropped from a plan."""
    policy = TokenBudgetPolicy(chunk_size=chunk_size,
                               token_budget=token_budget,
                               max_rows=max_rows)
    admissions = [Admission(rid=i, slot=i % max_rows, total=n)
                  for i, n in enumerate(prompt_lens)]
    seen = {a.rid: [] for a in admissions}
    rng = np.random.default_rng(decode_pattern_seed)
    steps = 0
    while admissions:
        n_rows = int(rng.integers(0, max_rows + 1))
        decode_rows = list(range(n_rows))
        plan = policy.plan(decode_rows, admissions)
        # 1. hard budget cap
        assert plan.total_tokens <= token_budget
        # 2. decode rows never starved: every planned step decodes them all
        assert plan.decode_rows == decode_rows
        # 3. progress: the first admission always advances
        assert not admissions or any(c.rid == admissions[0].rid
                                     for c in plan.chunks)
        for c in plan.chunks:
            adm = next(a for a in admissions if a.rid == c.rid)
            # 4. in order, gapless
            assert c.lo == adm.next_lo
            assert c.hi <= adm.total
            assert c.last == (c.hi == adm.total)
            seen[c.rid].append((c.lo, c.hi))
            adm.next_lo = c.hi
        admissions = [a for a in admissions if not a.done]
        steps += 1
        assert steps < 10_000, "policy livelocked"
    # 5. chunks partition each prompt exactly
    for adm_id, chunks in seen.items():
        total = prompt_lens[adm_id]
        assert chunks[0][0] == 0 and chunks[-1][1] == total
        for (a0, a1), (b0, b1) in zip(chunks, chunks[1:]):
            assert a1 == b0


FALLBACK_CASES = [
    (1, 5, 4, (1, 1, 9), 0),
    (4, 8, 4, (13, 2, 7, 31), 1),
    (8, 16, 8, (64, 1, 8, 9, 17), 2),
    (3, 20, 2, (5, 5, 5, 4), 3),
    (16, 18, 2, (100,), 4),
]


def test_budget_policy_invariants_fallback():
    """Seeded stdlib fallback that always runs (property-module guard:
    the scheduling invariants must not vanish with optional deps)."""
    for case in FALLBACK_CASES:
        _check_budget_policy(*case)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(chunk_size=st.integers(1, 16),
           extra_budget=st.integers(0, 32),
           max_rows=st.integers(1, 8),
           prompt_lens=st.lists(st.integers(1, 80), min_size=1,
                                max_size=6),
           seed=st.integers(0, 2**16))
    def test_budget_policy_invariants_property(chunk_size, extra_budget,
                                               max_rows, prompt_lens,
                                               seed):
        token_budget = chunk_size + max_rows + extra_budget
        _check_budget_policy(chunk_size, token_budget, max_rows,
                             tuple(prompt_lens), seed)


def test_budget_policy_rejects_livelock_budget():
    with pytest.raises(ValueError):
        TokenBudgetPolicy(chunk_size=8, token_budget=8, max_rows=4)
    with pytest.raises(ValueError):
        ContinuousEngine(None, get_config("tiny-moe"), max_slots=2,
                         slot_len=32, token_budget=16)  # no prefill_chunk


# ----------------------------------------------------------------------
# compile-once: shared block programs per (cfg, plane, mode)
def test_executor_block_programs_compile_once(tiny_moe_cfg,
                                              tiny_moe_params,
                                              packed_setup):
    """The runtime refactor's shared block programs build once per
    (cfg, plane, mode) per process: constructing and running a SECOND
    executor/engine of an identical mode adds zero cache builds."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    spec, exec_params, store, _ = packed_setup
    prompt = _prompt(cfg, 6, seed=9)

    def exercise(make):
        ex = make()
        if ex.packed:
            ps = ex.init_pool_state()
            logits, state, ps = ex.prefill(prompt, 12, pstate=ps)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            ex.decode(state, tok, ps)
        else:
            logits, state, _ = ex.prefill(prompt, 12)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            ex.decode(state, tok)

    for make in (
            lambda: Executor(params, cfg),
            lambda: Executor(exec_params, cfg, plane="packed_pipelined",
                             spec=spec, store=store),
            lambda: Executor(exec_params, cfg, plane="packed_vectorized",
                             spec=spec, store=store)):
        exercise(make)  # first pass may build missing programs
        before = T.cached_jit_stats()["builds"]
        exercise(make)  # identical mode: every program must be a hit
        after = T.cached_jit_stats()["builds"]
        assert after == before, \
            f"identical executor mode rebuilt {after - before} programs"


def test_cached_jit_stats_and_clear():
    key = ("__test_runtime_probe__",)
    T.cached_jit(key, lambda: object())
    s = T.cached_jit_stats()
    assert key in s["keys"] and s["entries"] >= 1 and s["builds"] >= 1
    T.cached_jit(key, lambda: object())
    assert T.cached_jit_stats()["hits"] >= 1
    T.cached_jit_clear()
    s = T.cached_jit_stats()
    assert s["entries"] == 0 and s["builds"] == 0 and s["hits"] == 0
