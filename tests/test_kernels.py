"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU).

Per the deliverable: for each kernel, sweep shapes/dtypes and
assert_allclose against ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.quant import hqq
from repro.quant.hqq import _meta_dequantize


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("M,K,N", [(8, 128, 128), (128, 256, 128),
                                   (32, 512, 256)])
def test_dequant_matmul_sweep(bits, M, K, N):
    w = jax.random.normal(jax.random.key(0), (K, N)) * 0.05
    qt = hqq.quantize(w, bits, group_size=64, scale_group=None)
    x = jax.random.normal(jax.random.key(1), (M, K))
    scale, zero = _meta_dequantize(qt)
    y_ref = ref.dequant_matmul_ref(x, qt.packed, scale, zero,
                                   bits=bits, group_size=64)
    y = dequant_matmul_pallas(x, qt.packed, scale, zero, bits=bits,
                              group_size=64, bm=min(8, M), bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_matmul_dtypes(dtype):
    w = jax.random.normal(jax.random.key(2), (256, 128)) * 0.05
    qt = hqq.quantize(w, 4, group_size=64, scale_group=None)
    x = jax.random.normal(jax.random.key(3), (16, 256)).astype(dtype)
    y = ops.dequant_matmul(x, qt)
    y_true = x.astype(jnp.float32) @ hqq.dequantize(qt)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_true),
                               rtol=tol, atol=tol)


def test_dequant_matmul_3bit_fallback():
    """3-bit codes use the jnp reference path (documented)."""
    w = jax.random.normal(jax.random.key(4), (128, 128)) * 0.05
    qt = hqq.quantize(w, 3, group_size=64, scale_group=None)
    x = jax.random.normal(jax.random.key(5), (8, 128))
    y = ops.dequant_matmul(x, qt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ hqq.dequantize(qt)),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# batched / slot-gather variants (DESIGN.md §7)
def _stacked_qt(S, K, N, bits, seed=0):
    w = jax.random.normal(jax.random.key(seed), (S, K, N)) * 0.05
    return w, hqq.quantize(w, bits, group_size=64, scale_group=None)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
@pytest.mark.parametrize("B,M,K,N", [(4, 8, 128, 128), (6, 1, 128, 256)])
def test_dequant_matmul_batched_matches_per_slice(bits, B, M, K, N):
    """One batched dispatch == B per-slice dequant_matmul calls, bitwise
    (the packed MoE path's compile-time/dispatch win must be free)."""
    w, qt = _stacked_qt(B, K, N, bits)
    x = jax.random.normal(jax.random.key(1), (B, M, K))
    y = ops.dequant_matmul_batched(x, qt)
    y_ref = jnp.stack([ops.dequant_matmul(x[b], hqq.slice_leading(qt, b))
                       for b in range(B)])
    assert (np.asarray(y) == np.asarray(y_ref)).all()


@pytest.mark.parametrize("bits", [2, 4])
def test_dequant_matmul_slots_gathers_in_kernel(bits):
    """The scalar-prefetch slot kernel serves by index into the whole
    packed tier — equal to gathering first, duplicate slots included."""
    S, B, M, K, N = 5, 6, 8, 128, 128
    w, qt = _stacked_qt(S, K, N, bits, seed=2)
    slots_py = [4, 0, 2, 0, 1, 4]
    slots = jnp.asarray(slots_py, jnp.int32)
    x = jax.random.normal(jax.random.key(3), (B, M, K))
    y = ops.dequant_matmul_slots(x, qt, slots)
    y_ref = jnp.stack([ops.dequant_matmul(x[b],
                                          hqq.slice_leading(qt, s))
                       for b, s in enumerate(slots_py)])
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # kernel path really was eligible (alignment) for this shape
    from repro.kernels.dequant_matmul import dequant_matmul_slots_pallas
    scale, zero = _meta_dequantize(qt)
    y_k = dequant_matmul_slots_pallas(x, qt.packed, scale, zero, slots,
                                      bits=bits, group_size=64, bm=8)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("BH,BKV,Sq,Skv,d", [
    (4, 2, 128, 128, 64),     # GQA G=2
    (8, 8, 256, 256, 32),     # MHA
    (6, 1, 128, 256, 64),     # MQA, decode-ish q_offset
    (2, 2, 8, 128, 128),      # short q against long kv
])
def test_flash_attention_sweep(BH, BKV, Sq, Skv, d):
    q = jax.random.normal(jax.random.key(0), (BH, Sq, d), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (BKV, Skv, d), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (BKV, Skv, d), jnp.float32)
    off = Skv - Sq
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, q_offset=off)
    o = ops.flash_attention(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64, None])
def test_flash_attention_window(window):
    q = jax.random.normal(jax.random.key(3), (4, 128, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(4), (2, 128, 32), jnp.float32)
    v = jax.random.normal(jax.random.key(5), (2, 128, 32), jnp.float32)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    o = flash_attention_pallas(q, k, v, causal=True, window=window,
                               bq=8, bk=128)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window,bq,bk,q_offset", [
    (16, 8, 16, 0),    # grid 8 -> 3 visited blocks
    (16, 8, 8, 0),     # grid 16 -> 4
    (32, 8, 16, 192),  # decode-ish offset, grid 16 -> 4
    (100, 128, 64, 0),  # window spans the whole grid: no skip
])
def test_flash_attention_window_skip_bitwise(window, bq, bk, q_offset):
    """Sliding-window blocks outside the window are dropped from the KV
    grid (index-map offset) — bitwise the full-grid kernel (skipped
    leading blocks are wiped by alpha=exp(-inf)=0, trailing ones
    contribute p=exp(-inf)=0 exactly) and correct vs the reference."""
    Sq = 128 if q_offset == 0 else 64
    Skv = Sq + q_offset
    q = jax.random.normal(jax.random.key(20), (4, Sq, 32), jnp.float32)
    k = jax.random.normal(jax.random.key(21), (2, Skv, 32), jnp.float32)
    v = jax.random.normal(jax.random.key(22), (2, Skv, 32), jnp.float32)
    o_skip = flash_attention_pallas(q, k, v, causal=True, window=window,
                                    bq=bq, bk=bk, q_offset=q_offset,
                                    skip_window_blocks=True)
    o_full = flash_attention_pallas(q, k, v, causal=True, window=window,
                                    bq=bq, bk=bk, q_offset=q_offset,
                                    skip_window_blocks=False)
    assert (np.asarray(o_skip) == np.asarray(o_full)).all(), \
        "grid skip changed bits"
    o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                    q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(o_skip), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.key(6), (2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(jax.random.key(7), (2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(jax.random.key(8), (2, 128, 64), jnp.bfloat16)
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_matches_model_attention_core():
    """Kernel agrees with the model's chunked-attention implementation."""
    from repro.models.layers import attention_core
    B, S, Hkv, G, d = 2, 128, 2, 2, 32
    q = jax.random.normal(jax.random.key(9), (B, S, Hkv * G, d))
    k = jax.random.normal(jax.random.key(10), (B, S, Hkv, d))
    v = jax.random.normal(jax.random.key(11), (B, S, Hkv, d))
    pos = jnp.arange(S, dtype=jnp.int32)
    o_model = attention_core(q, k, v, pos, pos, causal=True, window=None)
    qk = q.reshape(B, S, Hkv, G, d).transpose(0, 2, 3, 1, 4).reshape(
        B * Hkv * G, S, d)
    kk = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, d)
    vv = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, d)
    o_kern = ops.flash_attention(qk, kk, vv, causal=True)
    o_kern = o_kern.reshape(B, Hkv, G, S, d).transpose(0, 3, 1, 2, 4) \
        .reshape(B, S, Hkv * G, d)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_model),
                               rtol=2e-4, atol=2e-4)
