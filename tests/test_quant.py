"""HQQ quantization: packing exactness, error monotonicity, size accounting
(paper Table 1 machinery)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant import hqq


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_pack_unpack_roundtrip(bits):
    g = hqq.PAPER_SCHEMES[bits]["group_size"]
    q = jax.random.randint(jax.random.key(bits), (3, g, 16), 0,
                           2 ** bits).astype(jnp.uint8)
    rt = hqq.unpack_codes(hqq.pack_codes(q, bits), bits, g)
    assert (np.asarray(rt) == np.asarray(q)).all()


def test_error_monotone_in_bits():
    w = jax.random.normal(jax.random.key(0), (512, 256)) * 0.04
    errs = {b: hqq.quant_error(w, hqq.quantize(w, b))["rel_fro"]
            for b in (2, 3, 4, 8)}
    assert errs[8] < errs[4] < errs[3] < errs[2]
    assert errs[8] < 0.02 and errs[2] < 0.5


def test_hqq_beats_round_to_nearest():
    """The half-quadratic zero-point optimization must reduce error vs
    plain min-max affine quantization (iters=0)."""
    w = jax.random.normal(jax.random.key(1), (512, 128)) * 0.05
    # heavy-tailed outliers, where HQQ's lp<1 objective matters
    w = w + (jax.random.uniform(jax.random.key(2), w.shape) < 0.01) * 0.5
    e_hqq = hqq.quant_error(w, hqq.quantize(w, 3, iters=20))["rel_fro"]
    e_rtn = hqq.quant_error(w, hqq.quantize(w, 3, iters=0))["rel_fro"]
    assert e_hqq < e_rtn


def test_bits_per_param_accounting():
    w = jax.random.normal(jax.random.key(3), (1024, 256))
    # paper's 2-bit scheme (g=16 + 8-bit meta scales) costs ~3 bits real
    bpp2 = hqq.bits_per_param(hqq.quantize(w, 2))
    assert 2.5 < bpp2 < 3.5
    bpp4 = hqq.bits_per_param(hqq.quantize(w, 4))
    assert 4.0 < bpp4 < 5.0


@settings(max_examples=15, deadline=None)
@given(k_groups=st.integers(1, 8), n=st.integers(1, 64),
       bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**16))
def test_quantize_dequantize_shape_property(k_groups, n, bits, seed):
    g = hqq.PAPER_SCHEMES[bits]["group_size"]
    w = jax.random.normal(jax.random.key(seed), (k_groups * g, n)) * 0.1
    qt = hqq.quantize(w, bits)
    wd = hqq.dequantize(qt)
    assert wd.shape == w.shape
    # dequantized values stay within the observed range of each group
    assert float(jnp.abs(wd).max()) <= float(jnp.abs(w).max()) * 1.5 + 1e-3


def test_tree_quantization_sizes():
    tree = {"a": jax.random.normal(jax.random.key(4), (128, 64)),
            "b": jax.random.normal(jax.random.key(5), (7,))}
    qtree = hqq.quantize_tree(tree, 4)
    assert isinstance(qtree["a"], hqq.QTensor)
    assert not isinstance(qtree["b"], hqq.QTensor)  # 1-D stays dense
    nb = hqq.tree_nbytes(qtree)
    assert nb < hqq.dense_nbytes(tree)
