"""Data pipeline, optimizer, checkpointing, serving, sharding helpers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as C
from repro.configs import get_config
from repro.data.pipeline import (EOS, DataConfig, PackedDataset,
                                 build_corpus, decode_bytes, encode_text)
from repro.models import transformer as T
from repro.serving.engine import Request, ServeEngine
from repro.serving.sampler import SamplerConfig, sample
from repro.training import optimizer as O


# ---------------------------------------------------------------- data
def test_corpus_deterministic():
    c1 = build_corpus(max_bytes=100_000)
    c2 = build_corpus(max_bytes=100_000)
    assert (c1 == c2).all()
    assert (c1 < 512).all() and (c1 >= 0).all()
    assert (c1 == EOS).sum() > 0  # document separators present


def test_batches_shapes_and_determinism():
    ds = PackedDataset(DataConfig(seq_len=64, batch_size=4,
                                  max_bytes=200_000, seed=3))
    b1 = next(iter(ds.batches()))
    ds2 = PackedDataset(DataConfig(seq_len=64, batch_size=4,
                                   max_bytes=200_000, seed=3))
    b2 = next(iter(ds2.batches()))
    assert b1["tokens"].shape == (4, 64)
    assert (b1["tokens"] == b2["tokens"]).all()
    # labels are next-token
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()


def test_encode_decode_roundtrip():
    s = "def foo(): pass"
    assert decode_bytes(encode_text(s)) == s


# ---------------------------------------------------------------- optimizer
def test_adamw_first_step_is_signed_lr():
    """After one step from zero moments, |update| == lr (Adam property)."""
    cfg = O.OptimizerConfig(lr=1e-2, warmup_steps=1, weight_decay=0.0,
                            clip_norm=1e9)
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 2.0)}
    st = O.init_opt_state(params, cfg)
    new, st2, m = O.apply_updates(params, grads, st, cfg)
    upd = np.asarray(params["w"] - new["w"])
    np.testing.assert_allclose(upd, 1e-2, rtol=1e-4)
    assert int(st2["step"]) == 1


def test_grad_clipping():
    cfg = O.OptimizerConfig(lr=1e-2, clip_norm=1.0)
    params = {"w": jnp.zeros((10,))}
    grads = {"w": jnp.full((10,), 100.0)}
    _, _, m = O.apply_updates(grads, grads, O.init_opt_state(params, cfg), cfg)
    assert float(m["grad_norm"]) > 1.0  # raw norm reported


def test_schedule_warmup_and_decay():
    cfg = O.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    assert float(O.schedule(cfg, 0)) < float(O.schedule(cfg, 9))
    assert abs(float(O.schedule(cfg, 10))) <= 1.0
    assert float(O.schedule(cfg, 99)) < 0.2


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("tiny-moe")
    params = T.init_model(jax.random.key(0), cfg)
    path = str(tmp_path / "ck.npz")
    C.save(path, params, meta={"arch": "tiny-moe", "step": 3})
    assert C.load_meta(path)["step"] == 3
    tmpl = jax.eval_shape(lambda: T.init_model(jax.random.key(0), cfg))
    back = C.restore(path, tmpl)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    C.save(path, {"w": np.zeros((2, 2))})
    with pytest.raises(ValueError):
        C.restore(path, {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


# ---------------------------------------------------------------- serving
def test_serve_batch_completes(tiny_moe_cfg, tiny_moe_params):
    eng = ServeEngine(tiny_moe_params, tiny_moe_cfg,
                      SamplerConfig(kind="greedy"))
    reqs = [Request(encode_text("ab"), 8), Request(encode_text("xyz"), 5)]
    out = eng.serve_batch(reqs)
    assert len(out[0].completed) == 8
    assert len(out[1].completed) <= 5
    assert all(0 <= t < tiny_moe_cfg.vocab_size
               for r in out for t in r.completed)


def test_samplers():
    logits = jnp.array([[0.0, 10.0, 0.0]])
    assert int(sample(jax.random.key(0), logits,
                      SamplerConfig(kind="greedy"))[0]) == 1
    t = sample(jax.random.key(0), logits,
               SamplerConfig(kind="topk", top_k=1, temperature=0.5))
    assert int(t[0]) == 1


# ---------------------------------------------------------------- sharding
def test_param_spec_tree_covers_all_leaves():
    from jax.sharding import PartitionSpec as P
    from repro.sharding.specs import param_spec_tree

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ("mixtral-8x7b", "xlstm-1.3b", "whisper-medium",
                 "recurrentgemma-9b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: T.init_model(jax.random.key(0), c))
        specs = param_spec_tree(cfg, FakeMesh(), shapes)
        n_shapes = len(jax.tree.leaves(shapes))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda s: isinstance(s, P)))
        assert n_shapes == n_specs
        # every spec rank matches its leaf rank and divides evenly
        flat_s = jax.tree.leaves(shapes)
        flat_p = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for sh, sp in zip(flat_s, flat_p):
            assert len(sp) <= len(sh.shape)
            for dim, entry in zip(sh.shape, tuple(sp)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                sz = 1
                for ax in axes:
                    sz *= FakeMesh.shape[ax]
                assert dim % sz == 0, (arch, sh.shape, sp)
