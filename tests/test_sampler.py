"""The single sampling surface (serving/sampler.py): filter semantics
(top-k / top-p), per-request temperature, and the seeded regression
guarantees for the engines that route through it — ContinuousEngine and
OffloadEngine must produce reproducible sampled streams from a seed and
must have NO private greedy/rng branches left."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OffloadSpec
from repro.core.offload_engine import OffloadEngine
from repro.serving.engine import ContinuousEngine
from repro.serving.sampler import SamplerConfig, sample


def _logits(seed=0, B=2, V=32):
    return jax.random.normal(jax.random.key(seed), (B, V)) * 3.0


# ----------------------------------------------------------------------
def test_greedy_is_argmax():
    logits = _logits()
    out = sample(jax.random.key(1), logits, SamplerConfig(kind="greedy"))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_topk_never_leaves_top_k():
    logits = _logits(seed=2, B=4, V=64)
    cfg = SamplerConfig(kind="topk", top_k=5)
    top5 = np.asarray(jax.lax.top_k(logits, 5)[1])
    for s in range(20):
        out = np.asarray(sample(jax.random.key(s), logits, cfg))
        for b in range(4):
            assert out[b] in top5[b]


def test_topp_keeps_nucleus_only():
    # one dominant token (p ~ 0.97) -> tiny nucleus; top_p=0.5 must
    # always return it
    logits = jnp.full((1, 16), -2.0).at[0, 3].set(4.0)
    cfg = SamplerConfig(kind="topp", top_p=0.5)
    for s in range(20):
        assert int(sample(jax.random.key(s), logits, cfg)[0]) == 3
    # top_p=1.0 keeps everything -> other tokens appear
    cfg_all = SamplerConfig(kind="topp", top_p=1.0)
    seen = {int(sample(jax.random.key(s), logits, cfg_all)[0])
            for s in range(50)}
    assert len(seen) > 1


def test_topp_most_likely_token_always_survives():
    # near-uniform logits with top_p smaller than any single prob: the
    # argmax must still be sampleable (the nucleus is never empty)
    logits = _logits(seed=5, B=3, V=8) * 0.01
    cfg = SamplerConfig(kind="topp", top_p=1e-6)
    out = np.asarray(sample(jax.random.key(0), logits, cfg))
    np.testing.assert_array_equal(out, np.asarray(jnp.argmax(logits, -1)))


def test_per_request_temperature_row_wise():
    """A (B,) temperature divides each row by its own value: a very cold
    row becomes deterministic argmax while a hot row still varies."""
    logits = _logits(seed=7, B=2, V=16)
    cfg = SamplerConfig(kind="categorical", temperature=1.0)
    temps = np.array([1e-4, 3.0], np.float32)
    cold = [int(sample(jax.random.key(s), logits, cfg,
                       temperature=temps)[0]) for s in range(25)]
    assert set(cold) == {int(jnp.argmax(logits[0]))}
    hot = {int(sample(jax.random.key(s), logits, cfg,
                      temperature=temps)[1]) for s in range(25)}
    assert len(hot) > 1


# ----------------------------------------------------------------------
# engine regressions: seeded streams reproduce
def test_continuous_engine_sampled_stream_reproducible(tiny_moe_cfg,
                                                       tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    rng = np.random.default_rng(31)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9, 4)]

    def run(seed, temps=(None, 0.7, None)):
        eng = ContinuousEngine(
            params, cfg, max_slots=2, slot_len=48, eos_id=None,
            sampler=SamplerConfig(kind="topk", top_k=8, temperature=1.3),
            seed=seed)
        reqs = [eng.submit(p, 5, temperature=t)
                for p, t in zip(prompts, temps)]
        eng.run(max_steps=300)
        assert all(r.state == "finished" for r in reqs)
        return [r.generated for r in reqs]

    a, b, c = run(0), run(0), run(1)
    assert a == b, "same seed must reproduce the sampled stream"
    assert a != c, "different seed should perturb the stream"


def test_offload_engine_sampled_stream_reproducible(tiny_moe_cfg,
                                                    tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    eng = OffloadEngine(params, cfg)  # accounting mode, plain plane
    prompt = np.array([[5, 9, 2, 11]], np.int32)
    a, _ = eng.generate(prompt, 6, greedy=False, rng=jax.random.key(4))
    b, _ = eng.generate(prompt, 6, greedy=False, rng=jax.random.key(4))
    c, _ = eng.generate(prompt, 6, greedy=False)  # seeded default key
    d, _ = eng.generate(prompt, 6, greedy=False)
    assert (a == b).all()
    assert (c == d).all(), "rng=None must fall back to a FIXED seed"
    # explicit sampler configs route through the same surface
    e, _ = eng.generate(prompt, 6, rng=jax.random.key(4),
                        sampler=SamplerConfig(kind="topp", top_p=0.8))
    f, _ = eng.generate(prompt, 6, rng=jax.random.key(4),
                        sampler=SamplerConfig(kind="topp", top_p=0.8))
    assert (e == f).all()
    assert e.shape == (1, 6)
    assert (e >= 0).all() and (e < cfg.vocab_size).all()


def test_greedy_engine_rejects_per_request_temperature(tiny_moe_cfg,
                                                       tiny_moe_params):
    """A greedy engine's argmax would silently ignore a requested
    temperature — submit must reject it loudly instead."""
    eng = ContinuousEngine(tiny_moe_params, tiny_moe_cfg, max_slots=1,
                           slot_len=32)
    with pytest.raises(ValueError, match="stochastic sampler"):
        eng.submit(np.array([1, 2, 3], np.int32), 4, temperature=0.7)


def test_no_private_sampling_branches_left():
    """Engines must not re-grow ad-hoc rng/argmax sampling: the only
    `jax.random.categorical` call sites live in serving/sampler.py."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    offenders = []
    for path in root.rglob("*.py"):
        if path.name == "sampler.py":
            continue
        if "jax.random.categorical" in path.read_text():
            offenders.append(str(path))
    assert not offenders, f"ad-hoc sampling outside sampler.py: {offenders}"
