"""Roofline HLO parser unit tests on a synthetic program + live lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import roofline as R

SYNTH = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
}

ENTRY %main (x: f32[8,16]) -> f32[8,16] {
  %x = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,16]) tuple(%zero, %x)
  %w2 = f32[8,16] while(%t0), condition=%cond, body=%body
  ROOT %gte = f32[8,16] get-tuple-element(%w2), index=1
}
"""


def test_synthetic_while_scaling():
    rep = R.analyze(SYNTH, n_devices=8, default_trips=1)
    # dot: 2*8*16*16 flops, x 5 loop trips
    assert rep.flops == pytest.approx(5 * 2 * 8 * 16 * 16)
    # all-reduce: out 8*16*4 bytes * 2 (reduce+bcast) * (4-1)/4 ring * 5
    expect = 8 * 16 * 4 * 2 * (3 / 4) * 5
    assert rep.coll_bytes == pytest.approx(expect)
    assert rep.coll_by_type["all-reduce"] == pytest.approx(expect)


def test_shape_parsing():
    assert R._parse_shape("f32[8,16]") == 8 * 16 * 4
    assert R._parse_shape("bf16[2,3]{1,0}") == 12
    assert R._parse_shape("(s32[], f32[4])") == 4 + 16
    assert R._parse_dims("u8[5,7]{1,0}") == ("u8", [5, 7])


def test_live_lowering_scaled_vs_cost_analysis():
    """On a real compiled scan, parsed flops ~= XLA flops x trip count."""
    L, M, K = 7, 32, 64

    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                         jax.ShapeDtypeStruct((L, K, K), jnp.float32)
                         ).compile()
    rep = R.analyze(c.as_text(), n_devices=1, default_trips=L)
    xla = R.xla_cost_analysis(c)["flops"]  # body counted once
    assert rep.flops == pytest.approx(xla * L, rel=0.05)


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_config

    dense = R.model_flops(get_config("qwen1.5-4b"), 1000, "serve")
    moe = R.model_flops(get_config("mixtral-8x7b"), 1000, "serve")
    # mixtral active ~12.9B of 46.7B: flops must reflect ACTIVE params
    assert moe < 2 * 14e9 * 1000 * 1.1
    assert moe > 2 * 11e9 * 1000 * 0.9
    assert dense == pytest.approx(2 * 3.56e9 * 1000, rel=0.05)
