"""Recurrent mixers: chunkwise mLSTM == recurrent oracle; RG-LRU assoc-scan
== step recurrence; sLSTM stability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'test' extra")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_config
from repro.models import recurrent as R

CFG = get_config("xlstm-1.3b").reduced()
RG = get_config("recurrentgemma-9b").reduced()


@pytest.mark.parametrize("S,chunk", [(16, 4), (32, 8), (24, 24), (17, 8)])
def test_mlstm_chunkwise_equals_recurrent(S, chunk):
    B, H, dh = 2, 2, 16
    ks = jax.random.split(jax.random.key(S * 31 + chunk), 5)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)) + 2.0)
    li = jax.random.normal(ks[4], (B, S, H)) - 1.0
    state = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
             "m": jnp.zeros((B, H))}
    h_ref, st_ref = R.mlstm_recurrent_ref(q, k, v, lf, li, state)
    h_chk, st_chk = R.mlstm_scan_core(q, k, v, lf, li, state, chunk)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref),
                               rtol=2e-5, atol=2e-5)
    for key in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_chk[key]),
                                   np.asarray(st_ref[key]),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(S=st.integers(2, 40), chunk=st.sampled_from([2, 4, 8, 16]),
       seed=st.integers(0, 2**16))
def test_mlstm_chunkwise_property(S, chunk, seed):
    B, H, dh = 1, 1, 8
    ks = jax.random.split(jax.random.key(seed), 5)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)) * 3)
    li = jax.random.normal(ks[4], (B, S, H)) * 2
    state = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
             "m": jnp.zeros((B, H))}
    h_ref, _ = R.mlstm_recurrent_ref(q, k, v, lf, li, state)
    h_chk, _ = R.mlstm_scan_core(q, k, v, lf, li, state, chunk)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_ref),
                               rtol=5e-5, atol=5e-5)


def test_mlstm_extreme_gates_stable():
    """log-space stabilizers: huge input gates must not overflow."""
    B, S, H, dh = 1, 12, 1, 8
    ks = jax.random.split(jax.random.key(0), 3)
    q, k, v = (jax.random.normal(ks[i], (B, S, H, dh)) for i in range(3))
    lf = jnp.full((B, S, H), -0.01)
    li = jnp.full((B, S, H), 50.0)  # e^50 would overflow unstabilized f32
    state = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
             "m": jnp.zeros((B, H))}
    h, st = R.mlstm_scan_core(q, k, v, lf, li, state, 4)
    assert bool(jnp.isfinite(h).all()) and bool(jnp.isfinite(st["C"]).all())


def test_rglru_train_equals_decode_steps():
    B, S = 2, 10
    p = R.init_rglru(jax.random.key(1), RG)
    x = jax.random.normal(jax.random.key(2), (B, S, RG.d_model),
                          jnp.float32)
    y_full, st_full = R.rglru_train(p, RG, x)
    st = R.init_rglru_state(RG, B)
    ys = []
    for t in range(S):
        y_t, st = R.rglru_decode(p, RG, x[:, t: t + 1], st)
        ys.append(y_t[:, 0])
    y_dec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_full["h"]),
                               rtol=2e-4, atol=2e-5)


def test_rglru_decay_bounds():
    """RG-LRU a = exp(-c softplus(L) r) must lie in (0, 1)."""
    p = R.init_rglru(jax.random.key(3), RG)
    x = jax.random.normal(jax.random.key(4), (1, 8, RG.d_model)) * 3
    xi = jnp.einsum("bsd,dr->bsr", x, p["w_x"])
    a, b = R._rglru_gates(p, xi)
    assert float(a.min()) > 0.0 and float(a.max()) < 1.0


def test_slstm_train_equals_decode_steps():
    B, S = 2, 8
    p = R.init_slstm(jax.random.key(5), CFG)
    x = jax.random.normal(jax.random.key(6), (B, S, CFG.d_model),
                          jnp.float32)
    y_full, st_full = R.slstm_train(p, CFG, x)
    st = R.init_slstm_state(CFG, B)
    ys = []
    for t in range(S):
        y_t, st = R.slstm_decode(p, CFG, x[:, t: t + 1], st)
        ys.append(y_t[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_full["h"]),
                               rtol=2e-4, atol=2e-5)
