"""Fault-injection plane + request-lifecycle hardening (DESIGN.md §14).

Two layers of coverage:

* Pure injector semantics — seeded determinism, the ``at``/``rate``/
  ``max_fires``/``start`` schedule algebra, per-site stream independence
  and the ``--inject-faults`` CLI grammar.  No jax, runs in
  milliseconds.
* Engine-level lifecycle hardening — cancellation across every KV
  variant (queued / mid-prefill / mid-decode / swapped-out), bounded
  admission with rejection, step- and wall-clock deadlines, NaN-row
  quarantine on the plain AND packed planes, expert-fetch
  retry-then-degrade, swap-path faults falling back to recompute, and
  admission-time pool-exhaustion faults.

The load-bearing acceptance criterion everywhere: requests the fault did
NOT hit finish bitwise identical to the fault-free run.  Because the
continuous engine's parity grid (``tests/parity.py``) already pins every
variant to the B=1 ``generate_plain`` oracle, "bitwise identical to a
run where the victim never existed" reduces to "equal to the oracle
stream" — which is what these tests assert.  Cancelled / quarantined
rows must hold a strict *prefix* of their oracle stream.

Every engine here runs with ``check_invariants=True``, so the
step-boundary accounting audit (scheduler state lists, page free/live
partition + refcounts, draft ring, host-pool occupancy) executes after
every single step of every test in this module.
"""
from __future__ import annotations

import heapq

import numpy as np
import pytest

from repro.core.offload_engine import generate_plain
from repro.serving.engine import ContinuousEngine
from repro.serving.faults import SITES, FaultInjector, FaultSpec

from tests.parity import CONTINUOUS_KV_VARIANTS, make_prompts

# ----------------------------------------------------------------------
# shared workload + oracle cache (generate_plain is slow; the same
# workload's reference streams are reused across variants)
LENS, MAX_NEWS = (6, 12, 5), (6, 8, 6)
_ORACLES: dict = {}


def _oracles(params, cfg, prompts, max_news, key="plain"):
    k = (key, tuple(tuple(p.tolist()) for p in prompts), tuple(max_news))
    if k not in _ORACLES:
        _ORACLES[k] = [generate_plain(params, cfg, p[None], m)[0].tolist()
                       for p, m in zip(prompts, max_news)]
    return _ORACLES[k]


def _check_rows(reqs, oracles, *, victims=()):
    """Survivors bitwise == oracle; victims hold a strict prefix."""
    for r, want in zip(reqs, oracles):
        if r.rid in victims:
            assert len(r.generated) < len(want), \
                f"victim {r.rid} was not actually interrupted"
            assert r.generated == want[:len(r.generated)], \
                f"victim {r.rid} diverged before termination"
        else:
            assert r.status == "completed", \
                f"survivor {r.rid} ended {r.status!r}"
            assert r.generated == want, f"survivor {r.rid} diverged"


# ----------------------------------------------------------------------
# injector semantics (no jax)
def test_injector_determinism_and_seed_sensitivity():
    def draw(seed):
        inj = FaultInjector([FaultSpec(site="expert_fetch", rate=0.5)],
                            seed=seed)
        return [inj.fires("expert_fetch") for _ in range(200)]

    a, b, c = draw(7), draw(7), draw(8)
    assert a == b, "same seed+schedule must fire identically"
    assert a != c, "different seeds should diverge (p ~ 2^-200 otherwise)"
    assert 0 < sum(a) < 200


def test_injector_schedule_algebra():
    inj = FaultInjector([FaultSpec(site="swap_out", at=(1, 3), max_fires=2),
                         FaultSpec(site="page_pool", rate=1.0, start=2,
                                   max_fires=3)], seed=0)
    # ``at`` ordinals fire exactly; max_fires caps even explicit ordinals
    assert [inj.fires("swap_out") for _ in range(6)] == \
        [False, True, False, True, False, False]
    # rate-firing suppressed before ``start``; capped at max_fires
    assert [inj.fires("page_pool") for _ in range(6)] == \
        [False, False, True, True, True, False]
    # unscheduled sites never fire but still count opportunities
    assert not inj.fires("nan_logits")
    assert inj.opportunities["nan_logits"] == 1
    assert inj.total_fired == 5
    s = inj.stats()
    assert s["injected"] == 5
    assert s["fired_swap_out"] == 2 and s["fired_page_pool"] == 3
    assert set(s) == {"injected"} | {f"fired_{x}" for x in SITES}


def test_injector_site_stream_independence():
    """A site's rate stream must not shift when OTHER sites are
    consulted in between — each site owns an independent rng."""
    sched = [FaultSpec(site="expert_fetch", rate=0.5)]
    solo = FaultInjector(sched, seed=3)
    noisy = FaultInjector(sched, seed=3)
    a = [solo.fires("expert_fetch") for _ in range(64)]
    b = []
    for _ in range(64):
        noisy.fires("swap_in")
        b.append(noisy.fires("expert_fetch"))
        noisy.fires("slow_step")
    assert a == b


def test_injector_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="warp_core")
    with pytest.raises(ValueError, match="rate"):
        FaultSpec(site="swap_out", rate=1.5)
    with pytest.raises(ValueError, match="duplicate"):
        FaultInjector([FaultSpec(site="swap_out"),
                       FaultSpec(site="swap_out", rate=0.1)])
    with pytest.raises(KeyError):  # typo'd site on the hot path
        FaultInjector().fires("expert_fetchh")


def test_injector_parse_grammar():
    inj = FaultInjector.parse(
        "expert_fetch=0.05, nan_logits@2, swap_out@0@4, slow_step@5:25",
        seed=9)
    assert inj.seed == 9
    assert inj.schedule["expert_fetch"].rate == 0.05
    assert inj.schedule["nan_logits"].at == (2,)
    assert inj.schedule["swap_out"].at == (0, 4)
    assert inj.schedule["slow_step"].stall_ms == 25.0
    assert inj.stall_ms() == 25.0
    assert FaultInjector.parse("").schedule == {}
    with pytest.raises(ValueError):
        FaultInjector.parse("no_such_site=0.5")


# ----------------------------------------------------------------------
# cancellation across the KV-variant grid
@pytest.mark.parametrize("variant", sorted(CONTINUOUS_KV_VARIANTS))
def test_cancel_survivors_bitwise(variant, tiny_moe_cfg, tiny_moe_params):
    """Cancel one request mid-flight on every KV layout / admission
    mode; survivors must finish bitwise identical to the fault-free
    oracle and the step-boundary audit must stay green throughout.
    On the chunked variants the 12-token victim (chunk=4) is still
    mid-prefill at the cancel point, so the admission teardown path is
    exercised too; elsewhere the cancel lands mid-decode."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    kw = CONTINUOUS_KV_VARIANTS[variant]
    prompts = make_prompts(cfg, LENS)
    want = _oracles(params, cfg, prompts, MAX_NEWS)

    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, check_invariants=True, **kw)
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    eng.step(), eng.step()
    victim = reqs[1]
    assert eng.cancel(victim.rid)
    assert victim.status == "cancelled" and victim.state == "finished"
    assert not eng.cancel(victim.rid), "double-cancel must be a no-op"
    eng.run(max_steps=400)
    _check_rows(reqs, want, victims={victim.rid})
    s = eng.stats()
    assert s["faults_cancelled"] == 1 and s["faults_completed"] == 2
    assert s["faults_enabled"] == 0 and s["faults_injected"] == 0
    eng.check_invariants()


def test_cancel_while_queued(tiny_moe_cfg, tiny_moe_params):
    """Cancelling before any step runs tears the request out of the
    waiting queue — it must never touch a KV slot."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    want = _oracles(params, cfg, prompts, MAX_NEWS)
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, check_invariants=True)
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    assert eng.cancel(reqs[2].rid)
    assert reqs[2].status == "cancelled" and reqs[2].generated == []
    eng.run(max_steps=400)
    for r, w in zip(reqs[:2], want[:2]):
        assert r.status == "completed" and r.generated == w
    eng.check_invariants()


def test_cancel_restores_page_pool_exactly(tiny_moe_cfg, tiny_moe_params):
    """Crash-consistent KV accounting: after cancel + drain the page
    pool is byte-for-byte back at its pre-submit state — every page
    free, zero refcounts, no reservations (non-prefix layout: a prefix
    cache would legitimately retain pages as its own capital)."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, kv_page=4, check_invariants=True)
    pool = eng.kv.pool
    assert pool.n_free == pool.n_pages and pool.refs == {}
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    eng.step(), eng.step()
    assert pool.n_free < pool.n_pages  # someone actually held pages
    assert eng.cancel(reqs[0].rid)
    eng.run(max_steps=400)
    assert pool.n_free == pool.n_pages
    assert pool.refs == {} and not pool.reserved
    assert not any(pool.owned.values())
    eng.check_invariants()


# ----------------------------------------------------------------------
# bounded admission queue
def test_queue_cap_rejects_with_backpressure(tiny_moe_cfg, tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, (5, 5, 5, 5), seed=2)
    want = _oracles(params, cfg, prompts[:1], (4,))
    eng = ContinuousEngine(params, cfg, max_slots=1, slot_len=64,
                           eos_id=None, queue_cap=1, check_invariants=True)
    reqs = [eng.submit(p, 4) for p in prompts]
    kept, rejected = reqs[:1], reqs[1:]
    for r in rejected:
        # rejected synchronously: terminal, never retained, no tokens
        assert r.status == "rejected" and r.state == "finished"
        assert r.generated == []
    eng.run(max_steps=200)
    assert kept[0].status == "completed" and kept[0].generated == want[0]
    s = eng.stats()
    assert s["queue_rejected"] == 3 and s["faults_rejected"] == 3
    assert s["faults_completed"] == 1
    # rejected requests never enter the finished ledger — the census
    # counts them from the scheduler's rejection counter instead
    assert all(r not in eng.sched.finished for r in rejected)


# ----------------------------------------------------------------------
# deadlines
def test_step_deadline_deterministic(tiny_moe_cfg, tiny_moe_params):
    """deadline_steps is wall-clock-free: two identical runs must
    expire the same requests at the same points with identical token
    prefixes."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, (6, 5), seed=4)

    def run():
        eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                               eos_id=None, check_invariants=True)
        reqs = [eng.submit(p, 20, deadline_steps=3) for p in prompts]
        eng.run(max_steps=100)
        return [(r.status, list(r.generated)) for r in reqs]

    a, b = run(), run()
    assert a == b
    assert all(status == "deadline_exceeded" for status, _ in a)
    assert all(len(toks) < 20 for _, toks in a)


def test_wallclock_deadline_via_slow_step(tiny_moe_cfg, tiny_moe_params):
    """slow_step stalls push real time past a millisecond deadline; the
    expiry must fire without the requests reaching their token budget."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, (6, 5), seed=4)
    faults = FaultInjector([FaultSpec(site="slow_step", rate=1.0,
                                      stall_ms=30.0)], seed=0)
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, faults=faults, deadline_ms=5.0,
                           check_invariants=True)
    reqs = [eng.submit(p, 50) for p in prompts]
    eng.run(max_steps=100)
    assert all(r.status == "deadline_exceeded" for r in reqs)
    s = eng.stats()
    assert s["faults_fired_slow_step"] > 0
    assert s["faults_deadline_exceeded"] == 2
    eng.check_invariants()


# ----------------------------------------------------------------------
# NaN/Inf quarantine — plain and packed planes
def _packed_engine(cfg, params, **kw):
    from repro.configs.base import OffloadSpec
    from repro.core.offload_engine import OffloadEngine, quantize_for_offload
    spec = OffloadSpec(cache_size=4, num_speculative=2, expert_bits=3,
                       attn_bits=4)
    qdeq, _ = quantize_for_offload(params, cfg, spec)
    off = OffloadEngine(params, cfg, spec, quantized=True)
    eng = ContinuousEngine(None, cfg, max_slots=3, slot_len=48,
                           eos_id=None, offload=off, check_invariants=True,
                           **kw)
    return eng, qdeq


@pytest.mark.parametrize("plane", ["plain", "packed"])
def test_nan_quarantine_fails_only_poisoned_row(plane, tiny_moe_cfg,
                                                tiny_moe_params):
    """``nan_logits@1`` poisons exactly one decode row; that request
    alone ends ``failed`` and every other row's stream is bitwise the
    fault-free oracle — on the plain plane AND over HQQ-packed
    offloaded experts."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    faults = FaultInjector.parse("nan_logits@1", seed=0)
    if plane == "plain":
        eng = ContinuousEngine(params, cfg, max_slots=3, slot_len=64,
                               eos_id=None, faults=faults,
                               check_invariants=True)
        want = _oracles(params, cfg, prompts, MAX_NEWS)
    else:
        eng, qdeq = _packed_engine(cfg, params, faults=faults)
        want = _oracles(qdeq, cfg, prompts, MAX_NEWS, key="packed")
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    eng.run(max_steps=400)
    failed = [r for r in reqs if r.status == "failed"]
    assert len(failed) == 1, \
        f"exactly one row must fail, got {[r.status for r in reqs]}"
    _check_rows(reqs, want, victims={failed[0].rid})
    s = eng.stats()
    assert s["faults_fired_nan_logits"] == 1
    assert s["faults_nan_quarantined"] == 1 and s["faults_failed"] == 1
    assert s["faults_completed"] == 2
    eng.check_invariants()


# ----------------------------------------------------------------------
# expert-fetch retry ladder on the packed plane
def test_expert_fetch_transient_retry_is_invisible(tiny_moe_cfg,
                                                   tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    faults = FaultInjector.parse("expert_fetch@0", seed=0)
    eng, qdeq = _packed_engine(cfg, params, faults=faults)
    want = _oracles(qdeq, cfg, prompts, MAX_NEWS, key="packed")
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    eng.run(max_steps=400)
    _check_rows(reqs, want)
    s = eng.stats()
    assert s["faults_fired_expert_fetch"] == 1
    assert s["faults_fetch_retries"] >= 1
    assert s["faults_fetch_degraded"] == 0, \
        "one transient failure must be absorbed by retry, not degrade"


def test_expert_fetch_permanent_degrades_bitwise(tiny_moe_cfg,
                                                 tiny_moe_params):
    """rate=1.0: every fetch and every retry fails, so every MoE layer
    degrades to store-direct streaming — slower, but the token streams
    must STILL be bitwise identical (same quantized weights, same
    math)."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    faults = FaultInjector([FaultSpec(site="expert_fetch", rate=1.0)],
                           seed=0)
    eng, qdeq = _packed_engine(cfg, params, faults=faults)
    want = _oracles(qdeq, cfg, prompts, MAX_NEWS, key="packed")
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    eng.run(max_steps=400)
    _check_rows(reqs, want)
    s = eng.stats()
    assert s["faults_fetch_degraded"] > 0
    assert s["faults_fetch_retries"] >= 2 * s["faults_fetch_degraded"]


# ----------------------------------------------------------------------
# preemption-path faults (swap d2h/h2d, pool exhaustion, swapped cancel)
PREEMPT_LENS, PREEMPT_MAX_NEW = (12, 14, 10, 12), 10


def _preempt_engine(params, cfg, faults=None):
    """Pool sized so the workload MUST preempt (13 pages < 3 slots x 6
    pages worst case) — the clean run takes at least one swap-out."""
    return ContinuousEngine(params, cfg, max_slots=3, slot_len=64,
                            eos_id=None, kv_page=4, kv_pages_total=13,
                            preemption=True, kv_host_pages=12,
                            faults=faults, check_invariants=True)


def _preempt_workload(params, cfg):
    prompts = make_prompts(cfg, PREEMPT_LENS)
    max_news = [PREEMPT_MAX_NEW] * len(prompts)
    return prompts, max_news, _oracles(params, cfg, prompts, max_news)


@pytest.mark.parametrize("spec", [
    FaultSpec(site="swap_out", rate=1.0),   # d2h always fails -> recompute
    FaultSpec(site="swap_in", at=(0,)),     # first h2d fails -> recompute
    FaultSpec(site="page_pool", rate=0.5, max_fires=6),  # admission stalls
], ids=lambda s: s.site)
def test_preemption_faults_degrade_to_recompute(spec, tiny_moe_cfg,
                                                tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts, max_news, want = _preempt_workload(params, cfg)
    eng = _preempt_engine(params, cfg, faults=FaultInjector([spec], seed=0))
    reqs = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    eng.run(max_steps=400)
    _check_rows(reqs, want)
    s = eng.stats()
    assert s[f"faults_fired_{spec.site}"] >= 1, \
        f"workload never reached the {spec.site} boundary"
    assert eng.kv.host.in_use == 0, "host pool leaked staged pages"
    eng.check_invariants()


def test_cancel_while_swapped_out(tiny_moe_cfg, tiny_moe_params):
    """Cancel a request whose KV currently lives in the host pool: the
    staged blob must be discarded (host occupancy back to zero) and the
    survivors must stay bitwise."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts, max_news, want = _preempt_workload(params, cfg)
    eng = _preempt_engine(params, cfg)
    reqs = [eng.submit(p, m) for p, m in zip(prompts, max_news)]
    for _ in range(200):
        if eng._swapped:
            break
        eng.step()
    assert eng._swapped, "pool sizing no longer forces a preemption"
    victim_rid = eng._swapped[0].req.rid
    assert eng.cancel(victim_rid)
    eng.run(max_steps=400)
    _check_rows(reqs, want, victims={victim_rid})
    assert eng.kv.host.in_use == 0
    s = eng.stats()
    assert s["faults_cancelled"] == 1
    eng.check_invariants()


# ----------------------------------------------------------------------
# the invariant checker itself
def test_invariant_checker_catches_corruption(tiny_moe_cfg,
                                              tiny_moe_params):
    """Positive control for ``check_invariants``: it must pass on a
    live engine and FAIL loudly once the page-pool ledger is corrupted
    — otherwise every green audit above proves nothing."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, kv_page=4, check_invariants=True)
    for p, m in zip(prompts, MAX_NEWS):
        eng.submit(p, m)
    eng.step(), eng.step()
    eng.check_invariants()  # green on the healthy engine
    heapq.heappop(eng.kv.pool._free)  # leak one page from the free heap
    with pytest.raises(AssertionError):
        eng.check_invariants()


# ----------------------------------------------------------------------
# clean-run schema: the faults namespace is always present, all zeros
def test_clean_run_carries_zeroed_faults_namespace(tiny_moe_cfg,
                                                   tiny_moe_params):
    from repro.obs.schema import FAULTS_KEYS
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = make_prompts(cfg, LENS)
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, check_invariants=True)
    reqs = [eng.submit(p, m) for p, m in zip(prompts, MAX_NEWS)]
    eng.run(max_steps=400)
    _check_rows(reqs, _oracles(params, cfg, prompts, MAX_NEWS))
    s = eng.stats()
    assert {k for k in s if k.startswith("faults_")} == \
        {f"faults_{k}" for k in FAULTS_KEYS}
    assert s["faults_enabled"] == 0 and s["faults_injected"] == 0
    assert s["faults_completed"] == len(reqs)
    for k in ("fetch_retries", "fetch_degraded", "nan_quarantined",
              "cancelled", "deadline_exceeded", "rejected", "failed"):
        assert s[f"faults_{k}"] == 0, f"clean run bumped faults_{k}"
