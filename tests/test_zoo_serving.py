"""One runtime for the whole config zoo (DESIGN.md §12).

The per-layer-kind state-plane refactor's acceptance bar: every arch
family — recurrent (rg_lru: recurrentgemma), pure-recurrent
(mlstm/slstm: xlstm), encoder-decoder (whisper) and plain dense
(stablelm) — decodes through the SAME ContinuousEngine, and each
request's continuous/chunked greedy stream is BITWISE its own
single-request ``generate_plain`` oracle.

Also pinned here:

* zero-page admission: a pure-recurrent stack under the paged manager
  reserves no pool pages, so admission can never stall on the pool —
  a one-page pool serves any number of xlstm requests;
* chunked prefill ≡ whole prefill bitwise on every state plane for
  recurrent stacks (the exact-carry chunk forms of
  tests/test_recurrent.py, lifted through the executor);
* speculative decoding on recurrent stacks: rollback is
  snapshot-and-restore of the pre-round row state (mirroring the paged
  page-table trim), and the emitted streams stay bitwise the plain
  engine's;
* enc-dec admission: ``extras["audio_embeds"]`` is encoded ONCE into
  the read-only shared encoder-KV plane; submitting without it is an
  error, not a hang.

This module is in conftest.PROPERTY_MODULES: a skip here silently
retires the zoo acceptance bar, so CI fails on skips.
"""
import jax
import numpy as np
import pytest

import parity
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving.engine import ContinuousEngine
from repro.serving.kv_manager import PagedKVManager, StateManager

ZOO = ("recurrentgemma-9b", "xlstm-1.3b", "whisper-medium",
       "stablelm-1.6b")

_cache = {}


def _model(name):
    if name not in _cache:
        cfg = get_config(name).reduced()
        _cache[name] = (cfg, T.init_model(jax.random.key(0), cfg))
    return _cache[name]


def _workload(cfg, lens=(5, 9, 13), news=(6, 5, 4), seed=1):
    prompts = parity.make_prompts(cfg, lens, seed=seed)
    extras = parity.make_extras(cfg, len(prompts))
    return prompts, list(news), extras


# ----------------------------------------------------------------------
# the zoo x KV-layout matrix, every cell bitwise vs the B=1 oracle
@pytest.mark.parametrize("variant", ["dense", "dense_chunked", "paged"])
@pytest.mark.parametrize("arch", ZOO)
def test_zoo_continuous_matches_oracle(arch, variant):
    cfg, params = _model(arch)
    kw = dict(parity.CONTINUOUS_KV_VARIANTS[variant])
    if variant == "paged" and not cfg.has_kv_layers:
        # pure-recurrent: exercise the ZERO-page path hard — a pool of
        # one page must serve all requests (none are ever reserved)
        kw["kv_pages_total"] = 1
    prompts, max_news, extras = _workload(cfg)
    want = parity.oracle_streams(params, cfg, prompts, max_news, extras)
    got, eng = parity.run_continuous(params, cfg, prompts, max_news,
                                     extras=extras, **kw)
    parity.assert_tokens_equal(got, want, f"{arch}/{variant}")
    assert eng.sched.joins == len(prompts) > eng.max_slots  # churn happened


# ----------------------------------------------------------------------
def test_zero_page_admission_not_refused():
    """Regression (the pre-§12 engine reserved prompt+max_new pages for
    EVERY arch): a pure-recurrent request bigger than the whole page
    pool must still admit — it needs zero pages."""
    cfg, params = _model("xlstm-1.3b")
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None, kv_page=16, kv_pages_total=1)
    need = 40  # prompt + max_new >> pool capacity (16 positions)
    assert eng.kv.can_admit(need)
    req = eng.submit(parity.make_prompts(cfg, [30])[0], 10)
    eng.run(max_steps=100)
    assert req.state == "finished" and len(req.generated) == 10
    assert eng.kv.pool.owned.get(req.slot, None) in (None, [])


def test_statemanager_facade_dispatch():
    cfg, _ = _model("xlstm-1.3b")
    dense = StateManager.create(cfg, 2, 32)
    paged = StateManager.create(cfg, 2, 32, kv_page=8)
    assert not isinstance(dense, PagedKVManager)
    assert isinstance(paged, PagedKVManager) and not paged.has_kv
    with pytest.raises(ValueError, match="kv_page"):
        StateManager.create(cfg, 2, 32, kv_pages_total=4)


# ----------------------------------------------------------------------
def test_recurrent_chunked_prefill_bitwise_every_plane():
    """Chunked ≡ whole prefill, bitwise on every carry — the
    chunkwise==recurrent oracle of tests/test_recurrent.py driven
    through the executor for both recurrent families.  Chunkings avoid
    size-1 tails: the dense MLP's S=1 GEMV path folds ~1e-7 off its
    GEMM path, so only C >= 2 chunks of MLP-bearing stacks are bitwise
    (xlstm has no MLP and is immune)."""
    for arch, chunks in (("recurrentgemma-9b", (3, 4)),
                         ("xlstm-1.3b", (1, 3, 4))):
        cfg, params = _model(arch)
        from repro.runtime import Executor
        ex = Executor(params, cfg)
        prompt = parity.make_prompts(cfg, [11], seed=4)[0][None]
        whole_l, whole_s, _ = ex.prefill(prompt, 24)
        for c in chunks:  # 11 -> 3,3,3,2 / 4,4,3 (no 1-tails on MLP)
            l, s, _ = ex.prefill(prompt, 24, chunk=c)
            np.testing.assert_array_equal(np.asarray(whole_l[:, -1]),
                                          np.asarray(l[:, -1]))
            for a, b in zip(jax.tree.leaves(whole_s), jax.tree.leaves(s)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# speculative decoding over recurrent state: snapshot-and-restore
def test_speculative_recurrent_bitwise():
    dcfg = get_config("tiny-draft")
    dparams = T.init_model(jax.random.key(1), dcfg)
    for arch, lens, news in (("xlstm-1.3b", (5, 9, 13), (7, 5, 6)),
                             ("recurrentgemma-9b", (5, 7, 9), (4, 3, 4))):
        cfg, params = _model(arch)
        assert dcfg.vocab_size == cfg.vocab_size
        prompts = parity.make_prompts(cfg, lens)
        want = parity.oracle_streams(params, cfg, prompts, list(news))
        got, eng = parity.run_continuous(
            params, cfg, prompts, list(news), draft_params=dparams,
            draft_cfg=dcfg, num_draft_tokens=2)
        parity.assert_tokens_equal(got, want, f"{arch}/speculative")
        assert eng.obs.snapshot()["spec"]["rounds"] > 0  # spec path ran


def test_speculative_recurrent_rejects_paged():
    cfg, params = _model("xlstm-1.3b")
    dcfg = get_config("tiny-draft")
    dparams = T.init_model(jax.random.key(1), dcfg)
    with pytest.raises(ValueError, match="snapshot"):
        ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                         kv_page=16, draft_params=dparams,
                         draft_cfg=dcfg, num_draft_tokens=2)


# ----------------------------------------------------------------------
def test_encdec_submit_requires_audio():
    cfg, params = _model("whisper-medium")
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=64,
                           eos_id=None)
    with pytest.raises(ValueError, match="audio_embeds"):
        eng.submit(np.arange(1, 6, dtype=np.int32), 4)
    with pytest.raises(ValueError, match="audio_embeds"):
        eng.submit(np.arange(1, 6, dtype=np.int32), 4,
                   extras={"audio_embeds": np.zeros(
                       (cfg.encoder_seq + 1, cfg.d_model), np.float32)})


@pytest.fixture(scope="module", autouse=True)
def _drop_compiled():
    yield
    _cache.clear()
    T.cached_jit_clear()
    jax.clear_caches()
