"""MoE dispatch correctness: scatter-dispatch == dense oracle == gather.
Property-tested via ``hypothesis`` when installed, with a seeded fallback
sweep that always runs (the dispatch==dense equivalence must not vanish
with an optional dependency)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on hosts w/o the extra
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.models import moe as M


def _cfg(E=4, K=2, cf=None, d=64, f=96):
    cfg = get_config("tiny-moe").replace(d_model=d, d_ff=f)
    moe = dataclasses.replace(cfg.moe, num_experts=E, top_k=K,
                              capacity_factor=cf or float(E))
    return cfg.replace(moe=moe)


@pytest.mark.parametrize("E,K", [(4, 1), (4, 2), (8, 2), (8, 8)])
def test_dispatch_equals_dense(E, K):
    cfg = _cfg(E, K)  # capacity_factor=E -> no drops possible
    p = M.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (33, cfg.d_model))
    yd, auxd = M.moe_apply_dense(p, cfg, x)
    ys, auxs = M.moe_apply_dispatch(p, cfg, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(auxd["load_balance"]),
                               float(auxs["load_balance"]), rtol=1e-5)


@pytest.mark.parametrize("groups", [2, 4, 8])
def test_grouped_dispatch_equals_dense(groups):
    """Per-group local dispatch (production EP semantics) stays exact when
    per-group capacity is ample."""
    cfg = _cfg(4, 2)
    p = M.init_moe(jax.random.key(10), cfg)
    x = jax.random.normal(jax.random.key(11), (64, cfg.d_model))
    yd, _ = M.moe_apply_dense(p, cfg, x)
    yg, _ = M.moe_apply_dispatch(p, cfg, x, groups=groups)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                               rtol=2e-4, atol=2e-5)


def test_gather_equals_dense():
    cfg = _cfg(4, 2)
    p = M.init_moe(jax.random.key(2), cfg)
    x = jax.random.normal(jax.random.key(3), (5, cfg.d_model))
    yd, _ = M.moe_apply_dense(p, cfg, x)
    yg, route = M.moe_apply_gather(p, cfg, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                               rtol=2e-4, atol=2e-5)
    assert route["ids"].shape == (5, 2)


def test_capacity_drops_tokens():
    """With tight capacity some token-slots must drop (GShard semantics)."""
    cfg = _cfg(4, 2, cf=0.3)
    p = M.init_moe(jax.random.key(4), cfg)
    x = jax.random.normal(jax.random.key(5), (64, cfg.d_model))
    ys, _ = M.moe_apply_dispatch(p, cfg, x)
    yd, _ = M.moe_apply_dense(p, cfg, x)
    # dropped slots make dispatch != dense, but never NaN and never larger
    assert bool(jnp.isfinite(ys).all())
    assert float(jnp.abs(ys - yd).max()) > 1e-4


def test_load_balance_uniform_router_is_one():
    """Perfectly uniform routing gives load_balance == E * E*(1/E*1/E) = 1."""
    cfg = _cfg(8, 2)
    p = M.init_moe(jax.random.key(6), cfg)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    x = jax.random.normal(jax.random.key(7), (512, cfg.d_model))
    _, aux = M.moe_apply_dense(p, cfg, x)
    # probs uniform -> frac_probs = 1/E; assignment ~uniform by tie-break
    assert abs(float(aux["load_balance"]) - 1.0) < 0.35


def _check_dispatch_equals_dense(T, E, seed):
    cfg = _cfg(E, min(2, E))
    p = M.init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (T, cfg.d_model)) * 0.5
    yd, _ = M.moe_apply_dense(p, cfg, x)
    ys, _ = M.moe_apply_dispatch(p, cfg, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               rtol=5e-4, atol=5e-5)


@pytest.mark.parametrize("seed", range(4))
def test_dispatch_dense_seeded(seed):
    """Always-on fallback of the property test: (T, E, seed) drawn from a
    seeded generator, so the equivalence runs without ``hypothesis``."""
    rng = np.random.default_rng(500 + seed)
    _check_dispatch_equals_dense(T=int(rng.integers(4, 49)),
                                 E=int(rng.choice([2, 4, 8])),
                                 seed=int(rng.integers(2**16)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(T=st.integers(4, 48), E=st.sampled_from([2, 4, 8]),
           seed=st.integers(0, 2**16))
    def test_dispatch_dense_property(T, E, seed):
        _check_dispatch_equals_dense(T, E, seed)


def test_router_weights_renormalized():
    cfg = _cfg(4, 2)
    p = M.init_moe(jax.random.key(8), cfg)
    x = jax.random.normal(jax.random.key(9), (7, cfg.d_model))
    w, ids, probs = M.route_topk(p, cfg.moe, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert bool((ids >= 0).all()) and bool((ids < 4).all())


# NOTE: the packed MoE path (moe_apply_packed / packed_expert_ffn,
# DESIGN.md §6) is unit-tested in tests/test_offload.py, which does not
# gate on the optional hypothesis dependency this module skips without.
