"""Prefix caching + KV host-swap (DESIGN.md §13).

Two layers of assurance:

* an allocator-level property driver over ``PagePool`` + ``PrefixCache``
  (no jax) that models page *contents* and checks, across interleaved
  admit / adopt / diverge / release / evict schedules, that no page is
  freed while referenced, no request ever observes another request's
  divergent pages, and free + referenced always partitions the pool;
* engine-level parity: prefix-hit admissions, swap-resumed and
  recompute-resumed requests must emit bitwise the cold-start oracle
  stream — on the plain plane and (with identical h2d counters) on the
  packed offloaded plane — plus the feature-gating and no-leakage
  regressions.

Property tests run under hypothesis when available with a seeded
stdlib-random fallback that ALWAYS runs (see tests/conftest.py).
"""
import random

import numpy as np
import pytest

import parity
from repro.serving.engine import ContinuousEngine
from repro.serving.kv_manager import PagePool
from repro.serving.prefix_cache import PrefixCache

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ======================================================================
# Allocator-level property: PagePool refcounts x PrefixCache chains.
def _drive_prefix_pool(n_pages, page_size, cache_pages, n_ops, seed):
    """Interleaved admit(adopt)/finish(insert)/grow/evict schedule with
    a page-content model.

    ``content[pid]`` is the int32 token-block bytes the page's KV was
    (notionally) prefilled from, or ``("tail", slot)`` for a private
    partially-written tail page.  The load-bearing checks:

    * every page a request adopts at admission holds EXACTLY its own
      prompt's block for that ordinal (no divergent-page leakage);
    * refcount(pid) == #slots holding pid + (1 if the cache holds pid),
      and a page leaves the content model exactly when its last
      reference drops (freed => scrubbed, never before);
    * free + referenced partitions the pool after every op.
    """
    rng = random.Random(seed)
    ps = page_size
    pool = PagePool(n_pages, ps)
    cache = PrefixCache(ps, cache_pages)
    content = {}
    prompts = {}
    next_slot = 0

    def block(prompt, o):
        return np.ascontiguousarray(
            prompt[o * ps:(o + 1) * ps], dtype=np.int32).tobytes()

    def free_evicted(pids):
        for pid in pids:
            if pool.decref(pid):
                del content[pid]  # freed -> scrubbed before reuse

    def check():
        expect = {}
        for pids in pool.owned.values():
            for pid in pids:
                expect[pid] = expect.get(pid, 0) + 1
        for nd in cache._nodes.values():
            expect[nd.page] = expect.get(nd.page, 0) + 1
        assert pool.refs == expect, \
            f"refcounts drifted: {pool.refs} vs holders {expect}"
        free, live = set(pool._free), set(pool.refs)
        assert not (free & live), f"freed-while-referenced: {free & live}"
        assert len(free) + len(live) == n_pages, "pool partition broken"
        assert live == set(content), "content model out of sync"
        # a cached page is immutable full-prompt KV: its content is the
        # very block bytes its node is keyed by, and nodes never alias
        pages = [nd.page for nd in cache._nodes.values()]
        assert len(pages) == len(set(pages)), "cache nodes share a page"
        for nd in cache._nodes.values():
            assert content[nd.page] == nd.key[1], \
                "cached page content diverged from its token block"

    for _ in range(n_ops):
        op = rng.random()
        if op < 0.45 and len(prompts) < 6:
            # admit: tiny alphabet so prompts collide, then diverge
            prompt = np.array([rng.randrange(1, 4) for _ in
                               range(rng.randrange(1, 4 * ps + 1))],
                              np.int32)
            base, pids = cache.lookup(prompt)
            assert base == len(pids) * ps
            for o, pid in enumerate(pids):
                assert content[pid] == block(prompt, o), \
                    "adopted another request's divergent page"
            need = len(prompt) + rng.randrange(0, ps + 1)
            if not pool.can_reserve(
                    max(0, pool.pages_for(need) - len(pids))):
                check()
                continue
            s = next_slot
            next_slot += 1
            pool.reserve(s, need, prealloc_pages=len(pids))
            pool.adopt_shared(s, pids)
            n_full = len(prompt) // ps
            for pid in pool.ensure(s, len(prompt)):
                o = pool.owned[s].index(pid)
                content[pid] = (block(prompt, o) if o < n_full
                                else ("tail", s))
            prompts[s] = prompt
        elif op < 0.65 and prompts:
            # finish: publish the full-page prefix chain, then release
            s = rng.choice(sorted(prompts))
            prompt = prompts.pop(s)
            n_full = len(prompt) // ps
            if n_full and rng.random() < 0.8:
                registered, evicted = cache.insert(
                    prompt, pool.owned[s][:n_full])
                for pid in registered:  # incref BEFORE freeing evicted
                    pool.incref(pid)
                free_evicted(evicted)
            for pid in pool.release(s):
                del content[pid]
        elif op < 0.8 and prompts:
            # decode growth: fill the reservation with private tails
            s = rng.choice(sorted(prompts))
            for pid in pool.ensure(s, pool.reserved[s] * ps):
                content[pid] = ("tail", s)
        else:
            free_evicted(cache.evict_lru())
        check()

    for s in list(prompts):
        prompts.pop(s)
        for pid in pool.release(s):
            del content[pid]
    while cache.n_pages:
        free_evicted(cache.evict_lru())
    assert pool.n_free == n_pages and not pool.refs and not content, \
        "drain leaked pages"


PREFIX_FALLBACK_CASES = [
    (8, 2, 4, 120, 0),
    (6, 1, 3, 100, 1),
    (16, 4, 8, 150, 2),
    (4, 2, 1, 80, 3),
    (12, 3, 6, 140, 4),
]


@pytest.mark.parametrize("n_pages,page_size,cache_pages,n_ops,seed",
                         PREFIX_FALLBACK_CASES)
def test_prefix_pool_seeded_fallback(n_pages, page_size, cache_pages,
                                     n_ops, seed):
    _drive_prefix_pool(n_pages, page_size, cache_pages, n_ops, seed)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(n_pages=st.integers(2, 24), page_size=st.integers(1, 6),
           cache_pages=st.integers(1, 12), n_ops=st.integers(10, 160),
           seed=st.integers(0, 2**32 - 1))
    def test_prefix_pool_property(n_pages, page_size, cache_pages,
                                  n_ops, seed):
        _drive_prefix_pool(n_pages, page_size, cache_pages, n_ops, seed)


# ======================================================================
# Engine parity: prefix hits must be invisible in the token stream.
def _shared_prefix_prompts(cfg, sys_len, tails, seed=7):
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(1, cfg.vocab_size, sys_len).astype(np.int32)
    return [np.concatenate([sys_p, rng.integers(
        1, cfg.vocab_size, int(n)).astype(np.int32)]) for n in tails]


def test_prefix_hit_bitwise_and_skips_prefill(tiny_moe_cfg,
                                              tiny_moe_params):
    """Three prompts sharing a 24-token system prefix, serialized
    through one slot so the first admission has published its pages
    before the others look up: requests 2 and 3 must adopt all three
    full prefix pages (24 hit tokens each) and still emit bitwise the
    cold-start oracle stream."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = _shared_prefix_prompts(cfg, 24, (5, 3, 6))
    max_news = [6, 5, 4]
    base = parity.oracle_streams(params, cfg, prompts, max_news)
    toks, eng = parity.run_continuous(params, cfg, prompts, max_news,
                                      max_slots=1, slot_len=64,
                                      kv_page=8, prefix_cache_pages=12)
    parity.assert_tokens_equal(toks, base, "prefix-hit")
    assert eng._prefills_skipped == 2
    assert eng._prefix_hit_tokens == 48  # 2 hits x 3 full pages x 8
    s = eng.stats()
    assert s["kv_pages_free"] + eng._prefix.n_pages == s["kv_pages_total"]


def test_prefix_hit_packed_plane_counter_parity(tiny_moe_cfg,
                                                tiny_moe_params):
    """Packed offloaded plane: with the expert buffer sized to hold
    every expert (cache_size == num_experts, no eviction) the set of
    demand-loaded (layer, expert) pairs is identical whether or not
    shared prefills are skipped — the cache-warming cold prefill touches
    exactly the experts the skipped re-prefills would have.  Tokens AND
    h2d counters must match the no-cache run."""
    from repro.configs.base import OffloadSpec
    from repro.core.offload_engine import OffloadEngine

    cfg = tiny_moe_cfg
    spec = OffloadSpec(cache_size=cfg.moe.num_experts, num_speculative=0,
                       expert_bits=3, attn_bits=4)
    off = OffloadEngine(tiny_moe_params, cfg, spec, quantized=True)
    prompts = _shared_prefix_prompts(cfg, 16, (4, 6, 3), seed=13)
    max_news = [5, 6, 4]

    def run(**kw):
        toks, eng = parity.run_continuous(
            None, cfg, prompts, max_news, max_slots=1, slot_len=48,
            max_steps=400, offload=off, kv_page=8, **kw)
        return toks, parity.continuous_counters(eng), eng

    base, base_c, _ = run()
    toks, c, eng = run(prefix_cache_pages=8)
    parity.assert_tokens_equal(toks, base, "packed prefix-hit")
    assert c == base_c, f"h2d counters diverged: {c} vs {base_c}"
    assert eng._prefills_skipped == 2


def _run_preempted(params, cfg, prompts, max_news, *, kv_host_pages,
                   kv_pages_total, kv_page=4, max_slots=3):
    eng = ContinuousEngine(params, cfg, max_slots=max_slots, slot_len=48,
                           eos_id=None, kv_page=kv_page,
                           kv_pages_total=kv_pages_total,
                           preemption=True, kv_host_pages=kv_host_pages)
    reqs = [eng.submit(p, m, priority=pr) for p, m, pr in
            zip(prompts, max_news, (0, 0, 5))]
    eng.run(max_steps=800)
    unfinished = [r.rid for r in reqs if r.state != "finished"]
    assert not unfinished, f"requests never finished: {unfinished}"
    return [r.generated for r in reqs], eng


def test_preempt_swap_resume_bitwise(tiny_moe_cfg, tiny_moe_params):
    """Growth-squeeze on a starved pool: the worst cases sum past the
    pool, so mid-decode growth must preempt a low-priority victim; with
    a host pool its pages round-trip d2h/h2d and the resumed stream is
    bitwise the uninterrupted oracle."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = parity.make_prompts(cfg, (9, 7, 8), seed=11)
    max_news = [8, 8, 8]
    base = parity.oracle_streams(params, cfg, prompts, max_news)
    toks, eng = _run_preempted(params, cfg, prompts, max_news,
                               kv_host_pages=8, kv_pages_total=10)
    parity.assert_tokens_equal(toks, base, "swap-resume")
    assert eng.sched.preemptions >= 1 and eng.sched.resumes >= 1
    assert eng._recomputes == 0, "host pool sized to fit: must swap"
    hs = eng.kv.host_stats()
    assert hs["swap_out_bytes"] > 0
    assert hs["swap_out_bytes"] == hs["swap_in_bytes"]


def test_preempt_recompute_resume_bitwise(tiny_moe_cfg, tiny_moe_params):
    """Same squeeze with kv_host_pages=0: the victim's KV is dropped and
    rebuilt by re-prefilling prompt + generated — still bitwise."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = parity.make_prompts(cfg, (9, 7, 8), seed=11)
    max_news = [8, 8, 8]
    base = parity.oracle_streams(params, cfg, prompts, max_news)
    toks, eng = _run_preempted(params, cfg, prompts, max_news,
                               kv_host_pages=0, kv_pages_total=10)
    parity.assert_tokens_equal(toks, base, "recompute-resume")
    assert eng.sched.preemptions >= 1 and eng.sched.resumes >= 1
    assert eng._recomputes == eng.sched.resumes
    assert eng.kv.host_stats()["swap_out_bytes"] == 0


def test_priority_admission_preempts_lower(tiny_moe_cfg,
                                           tiny_moe_params):
    """Admission-stall preemption: a pool too small to co-run all three
    requests admits the late high-priority one by swapping out a
    strictly-lower-priority victim instead of queueing behind it."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = parity.make_prompts(cfg, (9, 7, 8), seed=11)
    max_news = [8, 8, 8]
    base = parity.oracle_streams(params, cfg, prompts, max_news)
    toks, eng = _run_preempted(params, cfg, prompts, max_news,
                               kv_host_pages=8, kv_pages_total=6)
    parity.assert_tokens_equal(toks, base, "priority admission")
    assert eng.sched.preemptions >= 1
    s = eng.stats()
    assert s["kv_pages_free"] == s["kv_pages_total"]


def test_exhaustion_without_preemption_serializes(tiny_moe_cfg,
                                                  tiny_moe_params):
    """Satellite guard: page exhaustion with preemption DISABLED must
    keep the PR-5 discipline — admissions stall and serialize, nothing
    is refused or evicted, and the streams match the oracle bitwise
    (prefix cache on, so cached pages must also yield to admissions)."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    prompts = parity.make_prompts(cfg, (9, 8, 7), seed=5)
    max_news = [8, 8, 8]
    base = parity.oracle_streams(params, cfg, prompts, max_news)
    # 5 pages of 4 = exactly one request's worst case (9+8 -> 5 pages)
    toks, eng = parity.run_continuous(params, cfg, prompts, max_news,
                                      max_slots=3, slot_len=48,
                                      kv_page=4, kv_pages_total=5,
                                      prefix_cache_pages=4)
    parity.assert_tokens_equal(toks, base, "serialized exhaustion")
    assert eng.sched.preemptions == 0
    assert eng.stats()["kv_pages_peak_committed"] <= 5


def test_no_leakage_through_cache_eviction(tiny_moe_cfg,
                                           tiny_moe_params):
    """Capacity-1 cache thrash: B's insert evicts A's chain and A's
    pages get scrubbed and reused; resubmitting A must re-prefill from
    scratch (or a partial hit) and still match the oracle — no stale KV
    survives the cache."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    a, b = _shared_prefix_prompts(cfg, 20, (4,)), \
        parity.make_prompts(cfg, (23,), seed=99)
    prompts = [a[0], b[0], a[0]]
    max_news = [6, 6, 6]
    base = parity.oracle_streams(params, cfg, prompts, max_news)
    toks, eng = parity.run_continuous(params, cfg, prompts, max_news,
                                      max_slots=1, slot_len=64,
                                      kv_page=8, prefix_cache_pages=1)
    parity.assert_tokens_equal(toks, base, "cache eviction reuse")
    assert eng._prefix.evicted_pages > 0


def test_feature_gating_validation(tiny_moe_cfg, tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    with pytest.raises(ValueError, match="block-paged"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         prefix_cache_pages=4)
    with pytest.raises(ValueError, match="block-paged"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         preemption=True)
    with pytest.raises(ValueError, match="preemption"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         kv_page=8, kv_host_pages=4)
    with pytest.raises(ValueError, match="draft-and-verify"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         kv_page=8, preemption=True, num_draft_tokens=2)
