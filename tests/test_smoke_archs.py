"""Per-arch smoke tests (deliverable f): instantiate the REDUCED variant of
each assigned family and run one forward + one train step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training.trainer import make_train_step

from conftest import make_batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.key(0), cfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, aux = T.forward_train(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    if cfg.moe is not None:
        assert bool(jnp.isfinite(aux["load_balance"]))
        assert float(aux["load_balance"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.key(1), cfg)
    batch = make_batch(cfg, 2, 32, seed=1)
    step = jax.jit(make_train_step(cfg, O.OptimizerConfig(lr=1e-3,
                                                          total_steps=10)))
    opt_state = O.init_opt_state(params)
    new_params, opt_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "command-r-plus-104b"])
def test_microbatched_step_matches_plain(arch):
    """Gradient accumulation must be loss-equivalent to the full batch."""
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.key(2), cfg)
    batch = make_batch(cfg, 4, 16, seed=2)
    opt = O.OptimizerConfig(lr=1e-3, total_steps=10)
    s1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    s2 = jax.jit(make_train_step(cfg, opt, microbatches=2, remat=True))
    p1, _, m1 = s1(params, O.init_opt_state(params), batch)
    p2, _, m2 = s2(params, O.init_opt_state(params), batch)
    # MoE dispatch capacity depends on per-call token count, so allow a
    # small tolerance for routed archs; dense must match tightly.
    tol = 0.05 if cfg.moe else 1e-3
    assert abs(float(m1["ce"]) - float(m2["ce"])) < tol
