"""Token-level draft-and-verify decoding (DESIGN.md §11).

Four layers of guarantees, all driven through the shared
``tests/parity.py`` harness:

* **Acceptance rule** (property-based + seeded stdlib fallback that
  ALWAYS runs): the accepted prefix is exactly the longest matching
  prefix, the round emits ``target[:a+1]`` (so ≤ k+1 tokens), and the
  draft never influences *which* tokens are emitted — only how many per
  verify chunk.
* **Rollback** (property-based + fallback): ``KVSlotManager.truncate``
  is a pure pos reset; ``PagedKVManager.truncate`` leaves the kept page
  prefix bitwise intact, clears the table suffix to −1, returns the
  freed pages to the pool, and a subsequent regrow reuses them — the
  slot looks exactly as if the rejected positions never happened.
* **Engine matrix**: speculative greedy output is BITWISE identical to
  non-speculative greedy on every OffloadEngine plane (packed
  pipelined / vectorized / sync / accounting) and every ContinuousEngine
  KV layout (dense / paged / exact / chunked), for a real dense draft
  AND for replay drafts at pinned acceptance — including k=1 and the
  offloaded continuous composition.  On the always-accept replay draft
  the packed planes' h2d bytes must not exceed the non-speculative
  baseline (the paper's amortization claim; low-acceptance drafts may
  legitimately exceed it — wasted verify chunks re-fetch experts).
* **Guards**: greedy-only, draft/vocab validation, and the SWA ring cap
  (a wrapped ring cannot roll back a rejected verify chunk).
"""
import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on hosts w/o the extra
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.configs.base import OffloadSpec
from repro.core.draft import (DenseDraft, ReplayDraft, accept_length,
                              verify_round)
from repro.core.offload_engine import OffloadEngine, quantize_for_offload
from repro.models import transformer as T
from repro.serving.engine import ContinuousEngine
from repro.serving.kv_manager import KVSlotManager, PagePool, PagedKVManager

import parity

K = 3  # draft tokens per round throughout the matrix


@pytest.fixture(scope="module")
def draft_model():
    cfg = get_config("tiny-draft")
    return T.init_model(jax.random.key(7), cfg), cfg


# ======================================================================
# acceptance rule: property + fallback (conftest PROPERTY_MODULES)
def _check_acceptance(draft, target):
    assert len(target) == len(draft) + 1
    a = accept_length(draft, target)
    emitted, a2 = verify_round(draft, target)
    assert a2 == a and 0 <= a <= len(draft)
    # emission is the accepted prefix plus the target's bonus token —
    # never more than k+1, and drawn from the TARGET stream only
    assert emitted == [int(t) for t in target[: a + 1]]
    assert len(emitted) == a + 1 <= len(target)
    # a really is the longest matching prefix
    assert all(int(d) == int(t) for d, t in zip(draft[:a], target[:a]))
    if a < len(draft):
        assert int(draft[a]) != int(target[a])


ACCEPT_FALLBACK_CASES = [
    ([], [9]),                      # k = 0 degenerate: bonus token only
    ([5], [5, 7]),                  # full accept
    ([5], [6, 7]),                  # immediate reject
    ([1, 2, 3], [1, 2, 3, 4]),      # full accept, k = 3
    ([1, 2, 3], [1, 2, 9, 4]),      # partial
    ([0, 0, 0, 0], [0, 0, 0, 0, 0]),
    ([3, 1, 4, 1, 5], [3, 1, 4, 2, 5, 9]),
]


def test_acceptance_rule_fallback():
    """Seeded stdlib fallback that always runs (property-module guard)."""
    for draft, target in ACCEPT_FALLBACK_CASES:
        _check_acceptance(draft, target)
    rng = np.random.default_rng(0)
    for _ in range(200):
        k = int(rng.integers(0, 6))
        draft = rng.integers(0, 4, k).tolist()
        target = rng.integers(0, 4, k + 1).tolist()
        _check_acceptance(draft, target)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, 8).flatmap(
        lambda k: st.tuples(st.lists(st.integers(0, 5), min_size=k,
                                     max_size=k),
                            st.lists(st.integers(0, 5), min_size=k + 1,
                                     max_size=k + 1))))
    def test_acceptance_rule_property(case):
        _check_acceptance(*case)


# ======================================================================
# rollback: dense pos reset + paged page-table trim
def test_dense_truncate_is_pos_reset_only(tiny_moe_cfg):
    kv = KVSlotManager(tiny_moe_cfg, 2, 32)
    s = kv.allocate("r")
    kv.state = dict(kv.state, pos=kv.state["pos"].at[s].set(19))
    before = {k: np.asarray(v) for k, v in kv.state.items() if k != "pos"}
    kv.truncate(s, 12)
    assert int(np.asarray(kv.state["pos"])[s]) == 12
    # nothing but pos moves: ring entries past pos are dead by the
    # attention validity mask and get overwritten by the real tokens
    for k, v in before.items():
        np.testing.assert_array_equal(np.asarray(kv.state[k]), v)
    with pytest.raises(AssertionError):
        kv.truncate(s, 13)  # cannot truncate forward


def _check_paged_trim(page_size, n_pages, lengths, seed):
    """One slot through a random grow/truncate trajectory vs the
    allocator invariants: owned == pages_for(len), freed pages return to
    the pool, the reservation survives trims."""
    pool = PagePool(n_pages, page_size)
    pool.reserve("r", max(lengths))  # admission reserves the worst case
    cur = 0
    rng = np.random.default_rng(seed)
    for n in lengths:
        if n >= cur:
            pool.ensure("r", n)
        else:
            freed = pool.trim("r", n)
            # trim pops exactly the suffix beyond pages_for(n)
            assert len(freed) == pool.pages_for(cur) - pool.pages_for(n)
            assert not set(freed) & set(pool.owned["r"])
        cur = n
        assert len(pool.owned["r"]) == pool.pages_for(cur)
        assert len(pool.owned["r"]) + pool.n_free == n_pages
        assert "r" in pool.reserved, "trim must keep the reservation"
        # regrowing into trimmed space always succeeds (pages came back)
        if rng.integers(0, 2):
            pool.ensure("r", cur)
    pool.release("r")
    assert pool.n_free == n_pages


PAGED_FALLBACK_CASES = [
    (4, 8, (7, 3, 9, 1, 12), 0),
    (1, 16, (5, 5, 2, 9, 9, 1), 1),
    (8, 4, (10, 2, 17, 16, 3), 2),
    (3, 6, (1, 13, 4, 18, 6), 3),
]


def test_paged_trim_fallback():
    for case in PAGED_FALLBACK_CASES:
        _check_paged_trim(*case)


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(page_size=st.integers(1, 8), extra=st.integers(0, 8),
           lengths=st.lists(st.integers(1, 40), min_size=1, max_size=12),
           seed=st.integers(0, 2**16))
    def test_paged_trim_property(page_size, extra, lengths, seed):
        n_pages = -(-max(lengths) // page_size) + extra
        _check_paged_trim(page_size, n_pages, tuple(lengths), seed)


def test_paged_manager_truncate_rolls_back_table(tiny_moe_cfg):
    """Manager-level rollback: after truncate the kept table prefix is
    bitwise intact, the suffix reads −1, pos and the host length mirror
    agree, and regrowth reuses the freed pages — the slot is
    indistinguishable from one that never speculated past ``n``."""
    kv = PagedKVManager(tiny_moe_cfg, 2, 4, 16, 8)
    s = kv.allocate("r", n_tokens=30)
    kv.ensure(s, 23)            # 6 pages: canonical 19 + rejected chunk
    kv.note_tokens(s, 23)
    kept = np.asarray(kv._pages_np[s, : kv.pool.pages_for(14)]).copy()
    kv.truncate(s, 14)          # roll back to the canonical stream
    assert kv._len[s] == 14
    assert int(np.asarray(kv.state["pos"])[s]) == 14
    table = np.asarray(kv._pages_np[s])
    np.testing.assert_array_equal(table[: kept.size], kept)
    assert (table[kept.size:] == -1).all()
    assert len(kv.pool.owned[s]) == kv.pool.pages_for(14)
    free_after_trim = kv.pool.n_free
    kv.ensure(s, 23)            # the next verify chunk regrows the slot
    assert kv.pool.n_free == free_after_trim - 2
    with pytest.raises(AssertionError):
        kv.truncate(s, 24)      # cannot truncate forward


# ======================================================================
# OffloadEngine matrix: every plane x every draft, bitwise
@pytest.fixture(scope="module")
def offload_setup(tiny_moe_cfg, tiny_moe_params):
    spec = OffloadSpec(cache_size=4, num_speculative=2, lookahead=1,
                       expert_bits=3, attn_bits=4)
    qdeq = quantize_for_offload(tiny_moe_params, tiny_moe_cfg, spec)[0]
    engines = parity.offload_plane_engines(tiny_moe_params, qdeq,
                                           tiny_moe_cfg, spec)
    prompt = parity.make_prompts(tiny_moe_cfg, (9,), seed=3)[0]
    return tiny_moe_cfg, engines, prompt


def test_offload_planes_speculative_bitwise(offload_setup, draft_model):
    """Tentpole invariant: on every offload plane, draft-and-verify
    greedy output == non-speculative greedy output, for a real dense
    draft and replay drafts at acceptance 1.0 and ~0.67.  At acceptance
    1.0 the measured h2d bytes must not exceed the baseline's."""
    cfg, engines, prompt = offload_setup
    dparams, dcfg = draft_model
    max_new = 12

    base = {name: parity.run_offload_generate(eng, prompt, max_new)
            for name, eng in engines.items()}
    streams = set(tuple(t) for t, _ in base.values())
    assert len(streams) == 1, "planes disagree before speculation"
    ref_stream = np.concatenate([prompt, base["packed_pipelined"][0]])

    drafts = {
        "dense": lambda: DenseDraft(dparams, dcfg),
        "replay_hit": lambda: ReplayDraft(ref_stream,
                                          vocab_size=cfg.vocab_size),
        "replay_miss3": lambda: ReplayDraft(ref_stream, miss_every=3,
                                            vocab_size=cfg.vocab_size),
    }
    for dname, mk in drafts.items():
        for pname, eng in engines.items():
            toks, stats = parity.run_offload_generate(
                eng, prompt, max_new, draft=mk(), num_draft_tokens=K)
            parity.assert_tokens_equal(toks, base[pname][0],
                                       f"{pname}/{dname}/k={K}")
            if dname == "replay_hit":
                # perfect drafts amortize expert fetches across chunks
                assert stats.bytes_h2d <= base[pname][1].bytes_h2d, \
                    f"{pname}: h2d grew under always-accept speculation"
    # k=1 boundary: single-token chunks, C=2 verify
    toks, _ = parity.run_offload_generate(
        engines["packed_pipelined"], prompt, max_new,
        draft=ReplayDraft(ref_stream, vocab_size=cfg.vocab_size),
        num_draft_tokens=1)
    parity.assert_tokens_equal(toks, base["packed_pipelined"][0], "k=1")


def test_offload_spec_metrics_account_rounds(offload_setup, draft_model):
    """The ``spec`` namespace carries the rounds/acceptance accounting
    after a speculative generation (schema-checked in test_obs)."""
    cfg, engines, prompt = offload_setup
    eng = engines["packed_pipelined"]
    ref = np.concatenate(
        [prompt, parity.run_offload_generate(eng, prompt, 8)[0]])
    parity.run_offload_generate(
        eng, prompt, 8, draft=ReplayDraft(ref, vocab_size=cfg.vocab_size),
        num_draft_tokens=K)
    spec = eng.obs.snapshot().get("spec")
    assert spec is not None and spec["rounds"] > 0
    assert 0.0 <= spec["acceptance_rate"] <= 1.0
    assert spec["proposed"]["count"] == spec["rounds"]


# ======================================================================
# ContinuousEngine matrix: every KV layout, plain + offloaded, bitwise
def test_continuous_speculative_matrix(tiny_moe_cfg, tiny_moe_params,
                                       draft_model):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    dparams, dcfg = draft_model
    prompts = parity.make_prompts(cfg, (5, 11, 3, 8), seed=21)
    max_news = [6, 4, 8, 5]
    base, _ = parity.run_continuous(params, cfg, prompts, max_news)
    parity.assert_tokens_equal(
        base, parity.oracle_streams(params, cfg, prompts, max_news),
        "continuous vs oracle")
    for name, kw in parity.CONTINUOUS_KV_VARIANTS.items():
        toks, eng = parity.run_continuous(
            params, cfg, prompts, max_news, draft_params=dparams,
            draft_cfg=dcfg, num_draft_tokens=K, **kw)
        parity.assert_tokens_equal(toks, base, f"spec {name}")
        spec = eng.obs.snapshot()["spec"]
        assert spec["rounds"] > 0, f"{name}: no verify rounds ran"
    # k=1 boundary on the dense layout
    toks, _ = parity.run_continuous(params, cfg, prompts, max_news,
                                    draft_params=dparams, draft_cfg=dcfg,
                                    num_draft_tokens=1)
    parity.assert_tokens_equal(toks, base, "spec dense k=1")


def test_continuous_offloaded_speculative_matches(tiny_moe_cfg,
                                                  tiny_moe_params,
                                                  draft_model):
    """Speculation composes with the packed offload plane on both KV
    layouts (token parity only: an untrained dense draft's acceptance is
    near zero, so h2d may legitimately exceed the baseline here)."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    dparams, dcfg = draft_model
    spec = OffloadSpec(cache_size=4, num_speculative=2, expert_bits=3,
                       attn_bits=4)
    off = OffloadEngine(params, cfg, spec, quantized=True)
    prompts = parity.make_prompts(cfg, (5, 8, 6), seed=33)
    max_news = [5, 7, 4]
    base, _ = parity.run_continuous(None, cfg, prompts, max_news,
                                    slot_len=48, offload=off)
    for kw in ({}, dict(kv_page=16), dict(prefill_chunk=4)):
        toks, eng = parity.run_continuous(
            None, cfg, prompts, max_news, slot_len=48, offload=off,
            draft_params=dparams, draft_cfg=dcfg, num_draft_tokens=K, **kw)
        parity.assert_tokens_equal(toks, base, f"offloaded spec {kw}")
        assert eng.obs.snapshot()["spec"]["rounds"] > 0


# ======================================================================
# guards: validation + the SWA ring cap
def test_speculation_guards(tiny_moe_cfg, tiny_moe_params, draft_model):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    dparams, dcfg = draft_model
    # k >= 1 without a draft model
    with pytest.raises(ValueError, match="draft_params"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         num_draft_tokens=2)
    # vocab mismatch
    bad_cfg = dataclasses.replace(dcfg, vocab_size=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         draft_params=dparams, draft_cfg=bad_cfg,
                         num_draft_tokens=2)
    # greedy-only (both engines)
    from repro.serving.sampler import SamplerConfig
    with pytest.raises(ValueError, match="greedy"):
        ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                         sampler=SamplerConfig(kind="categorical"),
                         draft_params=dparams, draft_cfg=dcfg,
                         num_draft_tokens=2)
    eng = OffloadEngine(params, cfg)
    prompt = parity.make_prompts(cfg, (5,), seed=1)[0][None]
    with pytest.raises(ValueError, match="greedy"):
        eng.generate(prompt, 4, greedy=False,
                     draft=DenseDraft(dparams, dcfg), num_draft_tokens=2)
    # a draft must be dense and attention-only (tiny-moe is neither)
    with pytest.raises(ValueError, match="dense"):
        DenseDraft(params, cfg)


def test_swa_ring_cap(tiny_moe_cfg, tiny_moe_params, draft_model):
    """tiny-moe is an all-SWA stack (window 256): a dense-KV slot wider
    than the window would wrap its ring, and a wrapped ring cannot roll
    back a rejected verify chunk — so speculative engines cap requests
    at min(slot_len, window) instead of admitting them."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    dparams, dcfg = draft_model
    win = cfg.sliding_window
    assert win and win == 256
    eng = ContinuousEngine(params, cfg, max_slots=1, slot_len=win + 44,
                           draft_params=dparams, draft_cfg=dcfg,
                           num_draft_tokens=2)
    assert eng._spec_cap == win
    prompt = parity.make_prompts(cfg, (win - 10,), seed=2)[0]
    with pytest.raises(ValueError, match="speculative ring cap"):
        eng.submit(prompt, 20)  # 246 + 20 > 256
    # the one-shot engine enforces the same bound
    off = OffloadEngine(params, cfg)
    with pytest.raises(ValueError, match="window"):
        off.generate(prompt[None], win, draft=DenseDraft(dparams, dcfg),
                     num_draft_tokens=2)
