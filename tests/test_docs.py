"""Docs consistency: DESIGN.md § references must resolve (the same check
CI runs via tools/check_design_refs.py), and the README's documented
entry points must exist."""
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def test_design_refs_resolve():
    r = subprocess.run([sys.executable,
                        str(ROOT / "tools" / "check_design_refs.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr or r.stdout


def test_readme_exists_and_commands_reference_real_modules():
    readme = ROOT / "README.md"
    assert readme.exists(), "top-level README.md missing"
    text = readme.read_text()
    # every repo-local `python -m <module>` / `python <script>` command
    # the README documents must point at a file that exists (external
    # tools like pytest are out of scope)
    for mod in re.findall(r"python -m ([\w.]+)", text):
        top = mod.split(".")[0]
        if not (ROOT / top).is_dir() or top in ("pytest",):
            continue
        p = ROOT / (mod.replace(".", "/") + ".py")
        assert p.exists() or (ROOT / mod.replace(".", "/")).is_dir(), \
            f"README documents missing module {mod}"
    for script in re.findall(r"python ((?:examples|tools|benchmarks)/\S+\.py)",
                             text):
        assert (ROOT / script).exists(), \
            f"README documents missing script {script}"
