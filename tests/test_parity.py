"""THE core correctness invariant: teacher-forced decode (token-by-token,
with KV caches / recurrent states) must reproduce the parallel training
forward exactly, for every architecture family.  This is what makes the
offload engine a *pure scheduling* layer (paper section 3.2: speculative
loading "does not change the final model predictions")."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T

from conftest import make_batch

TOL = 2e-4  # f32 reduced configs; accumulated over layers


def _nodrop(cfg):
    if cfg.moe is None:
        return cfg
    moe = dataclasses.replace(cfg.moe,
                              capacity_factor=float(cfg.moe.num_experts))
    return cfg.replace(moe=moe)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_train_forward(arch):
    cfg = _nodrop(get_config(arch).reduced())
    params = T.init_model(jax.random.key(7), cfg)
    B, S = 2, 24
    batch = make_batch(cfg, B, S, seed=7)
    full, _ = T.forward_train(params, cfg, batch)

    if cfg.num_image_tokens:
        # VLM: image positions only exist via prefill — prefill the image
        # span, then decode the text tail and compare that region
        S0 = cfg.num_image_tokens
        pb = dict(batch)
        pb["tokens"] = batch["tokens"][:, :S0]
        pre_logits, state = T.prefill(params, cfg, pb, max_len=S)
        outs = [pre_logits[:, -1]] if False else []
        for t in range(S0, S):
            logits, state = T.decode_step(params, cfg, state,
                                          batch["tokens"][:, t: t + 1],
                                          moe_mode="gather")
            outs.append(logits[:, 0])
        dec = jnp.stack(outs, axis=1)
        err = float(jnp.abs(dec - full[:, S0:]).max())
        assert err < TOL, f"{arch}: vlm decode/train divergence {err}"
        return

    state = T.init_decode_state(cfg, B, max_len=S)
    if cfg.is_encoder_decoder:
        _, st = T.prefill(params, cfg, batch, max_len=S)
        state["enc_kv"] = st["enc_kv"]
    outs = []
    for t in range(S):
        logits, state = T.decode_step(params, cfg, state,
                                      batch["tokens"][:, t: t + 1],
                                      moe_mode="gather")
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(dec - full).max())
    assert err < TOL, f"{arch}: decode/train divergence {err}"


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "smollm-360m",
                                  "recurrentgemma-9b", "xlstm-1.3b"])
def test_prefill_then_decode_matches_full_decode(arch):
    """prefill(prompt) + decode must equal decoding from scratch."""
    cfg = _nodrop(get_config(arch).reduced())
    params = T.init_model(jax.random.key(8), cfg)
    B, S, S0 = 2, 20, 12
    batch = make_batch(cfg, B, S, seed=8)
    toks = batch["tokens"]
    max_len = S

    # path A: full scratch decode
    state = T.init_decode_state(cfg, B, max_len=max_len)
    if cfg.is_encoder_decoder:
        _, st = T.prefill(params, cfg, batch, max_len=max_len)
        state["enc_kv"] = st["enc_kv"]
    la = None
    for t in range(S):
        la, state = T.decode_step(params, cfg, state, toks[:, t: t + 1],
                                  moe_mode="gather")
    # path B: prefill first S0 then decode the rest
    pb = dict(batch)
    pb["tokens"] = toks[:, :S0]
    _, stateb = T.prefill(params, cfg, pb, max_len=max_len)
    lb = None
    for t in range(S0, S):
        lb, stateb = T.decode_step(params, cfg, stateb, toks[:, t: t + 1],
                                   moe_mode="gather")
    err = float(jnp.abs(la - lb).max())
    assert err < TOL, f"{arch}: prefill-path divergence {err}"


def test_sliding_window_decode_rolls(tiny_moe_cfg):
    """Rolling SWA cache: decoding past the window must stay exact."""
    cfg = _nodrop(tiny_moe_cfg).replace(sliding_window=8)
    params = T.init_model(jax.random.key(9), cfg)
    B, S = 1, 32  # 4x window
    batch = make_batch(cfg, B, S, seed=9)
    full, _ = T.forward_train(params, cfg, batch)
    state = T.init_decode_state(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        lg, state = T.decode_step(params, cfg, state,
                                  batch["tokens"][:, t: t + 1],
                                  moe_mode="gather")
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    # cache W == window == 8 << S: rolling buffer must still be exact
    assert state["stack"][0]["kv"]["k"].shape[-3] == 8
    err = float(jnp.abs(dec - full).max())
    assert err < TOL, f"SWA rolling cache divergence {err}"
