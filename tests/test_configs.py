"""Config registry + parameter-count sanity for every assigned arch."""
import math

import pytest

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, SKIPS,
                           config_for_shape, get_config, list_archs)
from repro.models.transformer import count_params_analytic

EXPECTED_PARAMS = {
    "smollm-360m": (0.36e9, 0.10),
    "recurrentgemma-9b": (9.0e9, 0.15),
    "command-r-plus-104b": (104e9, 0.05),
    "granite-moe-1b-a400m": (1.3e9, 0.10),
    "stablelm-1.6b": (1.6e9, 0.10),
    "whisper-medium": (0.76e9, 0.15),
    "phi-3-vision-4.2b": (4.2e9, 0.15),
    "mixtral-8x7b": (46.7e9, 0.02),
    "xlstm-1.3b": (1.3e9, 0.15),
    "qwen1.5-4b": (4.0e9, 0.10),
}


def test_all_assigned_archs_registered():
    archs = list_archs(assigned_only=True)
    assert len(archs) == 10
    for a in archs:
        cfg = get_config(a)
        assert cfg.name == a


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    n = count_params_analytic(cfg)
    target, tol = EXPECTED_PARAMS[arch]
    assert abs(n - target) / target <= tol, f"{arch}: {n/1e9:.2f}B"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_variants(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= max(2, cfg.pattern_period)
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    assert cfg.n_heads % cfg.n_kv_heads == 0 or cfg.n_kv_heads == 1
    # same family structure preserved
    assert cfg.block_pattern == get_config(arch).block_pattern


def test_layer_kinds_cover_all_layers():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        kinds = cfg.layer_kinds()
        assert len(kinds) == cfg.n_layers
        assert (cfg.n_periods * cfg.pattern_period + cfg.n_tail_layers
                == cfg.n_layers)


def test_long500k_policy():
    # dense archs get the SWA variant; whisper is the single noted skip
    cfg = config_for_shape("command-r-plus-104b", "long_500k")
    assert cfg.sliding_window == 4096
    assert cfg.block_pattern[0].startswith("swa")
    cfg = config_for_shape("xlstm-1.3b", "long_500k")
    assert cfg.sliding_window is None  # attention-free, native
    assert ("whisper-medium", "long_500k") in SKIPS


def test_mixtral_matches_paper_expert_fraction():
    """Paper: 45.1B of 46.7B params (96.6%) live in the experts."""
    cfg = get_config("mixtral-8x7b")
    expert_params = (cfg.moe_layer_count * cfg.moe.num_experts
                     * 3 * cfg.d_model * cfg.d_ff)
    total = count_params_analytic(cfg)
    assert abs(expert_params / 1e9 - 45.1) < 0.2
    assert 0.955 < expert_params / total < 0.975
