"""Dry-run machinery smoke tests.

The full 40x2 matrix runs via ``python -m repro.launch.dryrun --all``
(results under experiments/dryrun); here we spawn a few representative
combos as subprocesses (XLA device-count must be set before jax init, so
it cannot run in-process with the other tests)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(arch, shape, multi_pod=False, tmp=None):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", str(tmp)]
    if multi_pod:
        cmd.append("--multi-pod")
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin"}
    return subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=560)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mp", [
    ("granite-moe-1b-a400m", "train_4k", False),
    ("smollm-360m", "decode_32k", True),
])
def test_dryrun_combo(arch, shape, mp, tmp_path):
    r = _run(arch, shape, mp, tmp_path)
    assert r.returncode == 0, r.stderr[-2000:]
    mesh = "pod2x16x16" if mp else "pod16x16"
    data = json.loads((tmp_path / f"{arch}__{shape}__{mesh}.json").read_text())
    assert data["status"] == "ok"
    assert data["roofline"]["flops_per_chip"] > 0
    assert data["roofline"]["bottleneck"] in ("compute", "memory",
                                              "collective")
    assert data["memory_analysis"]["peak_estimate_bytes"] < 17.2e9  # 16 GiB


def test_skip_marker(tmp_path):
    r = _run("whisper-medium", "long_500k", False, tmp_path)
    assert r.returncode == 0
    data = json.loads(
        (tmp_path / "whisper-medium__long_500k__pod16x16.json").read_text())
    assert data["status"] == "skipped"
