"""Dry-run machinery smoke tests.

The full 40x2 matrix runs via ``python -m repro.launch.dryrun --all``
(results under experiments/dryrun); here we spawn a few representative
combos as subprocesses (XLA device-count must be set before jax init, so
it cannot run in-process with the other tests)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _spawn(arch, shape, multi_pod=False, tmp=None):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", str(tmp)]
    if multi_pod:
        cmd.append("--multi-pod")
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin"}
    return subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True, env=env)


def _run(arch, shape, multi_pod=False, tmp=None):
    p = _spawn(arch, shape, multi_pod, tmp)
    out, err = p.communicate(timeout=560)
    return subprocess.CompletedProcess(p.args, p.returncode, out, err)


COMBOS = [
    ("granite-moe-1b-a400m", "train_4k", False),
    ("smollm-360m", "decode_32k", True),
]


@pytest.mark.slow
def test_dryrun_combos(tmp_path):
    """Representative (arch, shape, mesh) combos.  The subprocesses are
    independent single-threaded-ish XLA traces, so they run CONCURRENTLY
    — serial execution doubled the tier-1 suite's slowest module
    (runtime guard, DESIGN.md §7)."""
    procs = [(arch, shape, mp, _spawn(arch, shape, mp, tmp_path))
             for arch, shape, mp in COMBOS]
    for arch, shape, mp, p in procs:
        out, err = p.communicate(timeout=560)
        assert p.returncode == 0, (arch, shape, err[-2000:])
        mesh = "pod2x16x16" if mp else "pod16x16"
        data = json.loads(
            (tmp_path / f"{arch}__{shape}__{mesh}.json").read_text())
        assert data["status"] == "ok"
        assert data["roofline"]["flops_per_chip"] > 0
        assert data["roofline"]["bottleneck"] in ("compute", "memory",
                                                  "collective")
        assert data["memory_analysis"]["peak_estimate_bytes"] < 17.2e9


def test_skip_marker(tmp_path):
    r = _run("whisper-medium", "long_500k", False, tmp_path)
    assert r.returncode == 0
    data = json.loads(
        (tmp_path / "whisper-medium__long_500k__pod16x16.json").read_text())
    assert data["status"] == "skipped"
