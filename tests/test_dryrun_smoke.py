"""Dry-run machinery smoke tests.

The full 40x2 matrix runs via ``python -m repro.launch.dryrun --all``
(results under experiments/dryrun); here we spawn a few representative
combos as subprocesses (XLA device-count must be set before jax init, so
it cannot run in-process with the other tests).

Subprocess hygiene: each dryrun runs in its OWN process group with
``PR_SET_PDEATHSIG=SIGKILL`` (kernel kills it if pytest dies first) and
every exit path — timeout, assertion, Ctrl-C — kills the whole group.
Before the fix, a cancelled pytest left the 512-fake-device XLA traces
running and silently pinning both cores of this box.
"""
import ctypes
import json
import os
import signal
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

HARD_TIMEOUT_S = 560
PR_SET_PDEATHSIG = 1  # linux/prctl.h


def _preexec():
    """Child-side setup: new process group (so one killpg reaps the
    dryrun AND anything XLA forks) + parent-death signal (so an
    uncancellable pytest death still cannot orphan it)."""
    os.setsid()
    try:
        libc = ctypes.CDLL("libc.so.6", use_errno=True)
        libc.prctl(PR_SET_PDEATHSIG, signal.SIGKILL, 0, 0, 0)
    except OSError:  # pragma: no cover - non-glibc hosts
        pass


def _kill_group(p: subprocess.Popen) -> None:
    try:
        os.killpg(os.getpgid(p.pid), signal.SIGKILL)
    except (ProcessLookupError, PermissionError):
        pass


def _spawn(arch, shape, multi_pod=False, tmp=None):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", str(tmp)]
    if multi_pod:
        cmd.append("--multi-pod")
    env = {"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin:/usr/local/bin"}
    return subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True, env=env,
                            preexec_fn=_preexec)


def _communicate(p: subprocess.Popen, timeout=HARD_TIMEOUT_S):
    """communicate() with a hard timeout that reaps the process group —
    a hung XLA trace must die, not outlive the suite."""
    try:
        return p.communicate(timeout=timeout)
    except (subprocess.TimeoutExpired, KeyboardInterrupt):
        _kill_group(p)
        raise


def _run(arch, shape, multi_pod=False, tmp=None):
    p = _spawn(arch, shape, multi_pod, tmp)
    try:
        out, err = _communicate(p)
    except BaseException:
        _kill_group(p)
        raise
    return subprocess.CompletedProcess(p.args, p.returncode, out, err)


COMBOS = [
    ("granite-moe-1b-a400m", "train_4k", False),
    ("smollm-360m", "decode_32k", True),
]


@pytest.mark.slow
def test_dryrun_combos(tmp_path):
    """Representative (arch, shape, mesh) combos.  The subprocesses are
    independent single-threaded-ish XLA traces, so they run CONCURRENTLY
    — serial execution doubled the tier-1 suite's slowest module
    (runtime guard, DESIGN.md §7)."""
    procs = [(arch, shape, mp, _spawn(arch, shape, mp, tmp_path))
             for arch, shape, mp in COMBOS]
    try:
        for arch, shape, mp, p in procs:
            out, err = _communicate(p)
            assert p.returncode == 0, (arch, shape, err[-2000:])
            mesh = "pod2x16x16" if mp else "pod16x16"
            data = json.loads(
                (tmp_path / f"{arch}__{shape}__{mesh}.json").read_text())
            assert data["status"] == "ok"
            assert data["roofline"]["flops_per_chip"] > 0
            assert data["roofline"]["bottleneck"] in ("compute", "memory",
                                                      "collective")
            assert data["memory_analysis"]["peak_estimate_bytes"] < 17.2e9
    finally:
        # any failure above must not leave the OTHER combo running
        for _, _, _, p in procs:
            if p.poll() is None:
                _kill_group(p)


def test_skip_marker(tmp_path):
    r = _run("whisper-medium", "long_500k", False, tmp_path)
    assert r.returncode == 0
    data = json.loads(
        (tmp_path / "whisper-medium__long_500k__pod16x16.json").read_text())
    assert data["status"] == "skipped"


def test_spawned_dryrun_dies_with_its_group():
    """The hygiene itself: killing the process group reaps the dryrun
    before it finishes (no orphan keeps burning CPU)."""
    p = _spawn("smollm-360m", "decode_32k", False, "/tmp/_dryrun_kill_test")
    assert p.poll() is None
    _kill_group(p)
    try:
        p.wait(timeout=30)
    except subprocess.TimeoutExpired:  # pragma: no cover
        p.kill()
        pytest.fail("process group kill did not reap the dryrun")
    assert p.returncode != 0  # killed, not a clean exit
