"""LRU cache + speculative staging: jittable state machine vs python
oracle (property-based when ``hypothesis`` is installed, with a seeded
stdlib-random fallback that ALWAYS runs — the eviction-sequence oracle
equivalence is the invariant the packed buffer pool rests on, so it must
not silently vanish with an optional dependency), plus paper-semantics
unit checks and the whole-batch plan (DESIGN.md §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on hosts w/o the extra
    HAVE_HYPOTHESIS = False

from repro.core import lru_cache as L


def test_basic_hit_miss():
    s = L.init_layer_state(k=2, n_spec=2)
    s, st1 = L.access(s, jnp.array([3, 5], jnp.int32))
    assert int(st1.demand_loads) == 2 and int(st1.hits) == 0
    s, st2 = L.access(s, jnp.array([3, 5], jnp.int32))
    assert int(st2.hits) == 2 and int(st2.demand_loads) == 0
    # new expert evicts LRU (3 was touched before 5 in the second access)
    s, st3 = L.access(s, jnp.array([7, 5], jnp.int32))
    assert int(st3.demand_loads) == 1
    assert set(np.asarray(s.cache_ids).tolist()) == {5, 7}


def test_speculative_hit_promotes():
    """Paper: a used speculative expert replaces the LRU cache entry."""
    s = L.init_layer_state(k=2, n_spec=2)
    s, _ = L.access(s, jnp.array([0, 1], jnp.int32))
    s, n = L.stage_speculative(s, jnp.array([4, 5], jnp.int32))
    assert int(n) == 2  # both staged experts transferred
    s, st = L.access(s, jnp.array([4, 1], jnp.int32))
    assert int(st.spec_hits) == 1  # 4 came from staging, no blocking load
    assert int(st.hits) == 1       # 1 was cached
    assert int(st.demand_loads) == 0
    assert 4 in np.asarray(s.cache_ids).tolist()  # promoted into LRU


def test_stage_skips_resident():
    s = L.init_layer_state(k=2, n_spec=2)
    s, _ = L.access(s, jnp.array([0, 1], jnp.int32))
    s, n = L.stage_speculative(s, jnp.array([0, 3], jnp.int32))
    assert int(n) == 1  # 0 already cached -> only 3 transferred


# ----------------------------------------------------------------------
def _check_matches_python_oracle(k, n_spec, n_experts, seed, n_steps):
    """PyLRU and the jit state machine produce identical hit/evict
    sequences on one random trace (the claim ``core/offload_engine``'s
    docstring points here for).  Shared body of the hypothesis property
    test and the always-on seeded fallback."""
    rng = np.random.default_rng(seed)
    top_k = min(2, n_experts)
    n_spec = min(n_spec, n_experts)
    js = L.init_layer_state(k, n_spec)
    py = L.PyLRU(k, n_spec)
    tot = {"hits": 0, "spec_hits": 0, "demand": 0, "spec_loads": 0}
    evictions = []
    for _ in range(n_steps):
        needed = rng.choice(n_experts, size=top_k, replace=False)
        js, stats, plan = L.access_plan(js, jnp.asarray(needed, jnp.int32))
        py.access(needed.tolist())
        tot["hits"] += int(stats.hits)
        tot["spec_hits"] += int(stats.spec_hits)
        tot["demand"] += int(stats.demand_loads)
        # the plan must place every needed expert in the slot table
        for j, e in enumerate(needed):
            assert int(np.asarray(js.cache_ids)[int(plan.slots[j])]) in \
                set(needed[j:].tolist()) | {int(e)}
        evictions.extend(int(v) for v in np.asarray(plan.evicted)
                         if int(v) >= 0)
        pred = rng.choice(n_experts, size=n_spec, replace=False)
        js, n = L.stage_speculative(js, jnp.asarray(pred, jnp.int32))
        py.stage(pred.tolist())
        tot["spec_loads"] += int(n)
        # cache CONTENTS must agree (ordering differs by representation)
        assert set(np.asarray(js.cache_ids).tolist()) - {-1} \
            == set(py.cache)
    assert tot["hits"] == py.hits
    assert tot["spec_hits"] == py.spec_hits
    assert tot["demand"] == py.demand
    assert tot["spec_loads"] == py.spec_loads
    # identical EVICT sequence, not just counts: the buffer pool replaces
    # exactly the experts the python oracle would
    assert evictions == py.evictions


@pytest.mark.parametrize("seed", range(6))
def test_jnp_matches_python_oracle_seeded(seed):
    """Always-on fallback of the property test: the (k, n_spec, E, trace)
    space is drawn from a seeded generator, so the oracle equivalence is
    verified even without the optional ``hypothesis`` dependency."""
    rng = np.random.default_rng(1000 + seed)
    _check_matches_python_oracle(
        k=int(rng.integers(1, 7)), n_spec=int(rng.integers(1, 4)),
        n_experts=int(rng.integers(2, 13)), seed=int(rng.integers(2**31)),
        n_steps=int(rng.integers(8, 41)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        k=st.integers(1, 6),
        n_spec=st.integers(1, 3),
        n_experts=st.integers(2, 12),
        seed=st.integers(0, 2**31),
        n_steps=st.integers(1, 40),
    )
    def test_jnp_matches_python_oracle(k, n_spec, n_experts, seed, n_steps):
        _check_matches_python_oracle(k, n_spec, n_experts, seed, n_steps)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("T,active", [(1, None), (3, None),
                                      (3, (True, False, True))])
def test_access_plan_batch_matches_sequential(T, active):
    """The whole-batch plan (DESIGN.md §7) must leave exactly the state
    and counters of T sequential ``access_plan`` calls, and its
    slot/survivor/written tables must describe the sequential swap
    sequence's final pool contents."""
    rng = np.random.default_rng(7)
    k, K, E = 2, 2, 8
    sj = L.init_layer_state(k, 2)
    sb = L.init_layer_state(k, 2)
    for step in range(8):
        ids = rng.integers(0, E, (T, K)).astype(np.int32)
        act = None if active is None else jnp.asarray(active)
        # sequential reference (with the active-row masking acquire does)
        tot = np.zeros(4, np.int64)
        written_ref = np.zeros(k, bool)
        owners = {}  # slot -> expert of the last insert
        for t in range(T):
            new, stats, plan = L.access_plan(sj, jnp.asarray(ids[t]))
            if active is None or active[t]:
                for j in range(K):
                    if not bool(plan.in_cache[j]):
                        s = int(plan.slots[j])
                        written_ref[s] = True
                        owners[s] = int(ids[t, j])
                tot += np.array([int(stats.hits), int(stats.spec_hits),
                                 int(stats.demand_loads), 0])
                sj = new
        sb, delta, bplan = L.access_plan_batch(sb, jnp.asarray(ids), act)
        for a, b in zip(jax.tree.leaves(sj), jax.tree.leaves(sb)):
            assert (np.asarray(a) == np.asarray(b)).all()
        assert (np.asarray(delta) == tot).all()
        assert (np.asarray(bplan.written) == written_ref).all()
        for s, e in owners.items():
            assert int(np.asarray(sb.cache_ids)[s]) == e
        # survivors: the expert still owns its serving slot afterwards
        ids_final = np.asarray(sb.cache_ids)
        surv = np.asarray(bplan.survives)
        slots = np.asarray(bplan.slots)
        for t in range(T):
            for j in range(K):
                assert surv[t, j] == (ids_final[slots[t, j]] == ids[t, j])


def test_access_is_jittable():
    s = L.init_layer_state(4, 2)
    f = jax.jit(L.access)
    s, stats = f(s, jnp.array([1, 2], jnp.int32))
    s, stats = f(s, jnp.array([2, 3], jnp.int32))
    assert int(stats.hits) == 1


def test_policy_comparison_bounds():
    """Belady must dominate LRU and LFU at every k (it is the optimum)."""
    rng = np.random.default_rng(3)
    trace = rng.zipf(1.7, size=(150, 3, 2)) % 8
    comp = L.policy_comparison(trace, [2, 4])
    for k in (2, 4):
        assert comp[("belady", k)] >= comp[("lru", k)] - 1e-9
        assert comp[("belady", k)] >= comp[("lfu_decay", k)] - 1e-9


def test_hit_curve_monotone_in_k():
    rng = np.random.default_rng(0)
    # zipf-ish reuse pattern over 8 experts
    trace = rng.zipf(1.5, size=(200, 4, 2)) % 8
    curve = L.lru_hit_curve(trace, [1, 2, 4, 8])
    vals = [curve[k] for k in (1, 2, 4, 8)]
    assert all(b >= a - 1e-9 for a, b in zip(vals, vals[1:]))
    assert curve[8] > 0.9  # k=E caches everything after warmup
