"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the single
real CPU device; only launch/dryrun.py forces 512 placeholder devices
(tests that need a mesh spawn dryrun in a subprocess)."""
import os

import jax
import numpy as np
import pytest

import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T

# modules whose property tests guard load-bearing invariants (the
# PyLRU<->state-machine eviction oracle, dispatch==dense, pack/unpack
# roundtrips); with REPRO_FAIL_ON_SKIP=1 (CI) any skip in them fails
# the session — an optional-dependency skip must never silently retire
# those invariants
PROPERTY_MODULES = ("test_lru.py", "test_moe.py", "test_paged_kv.py",
                    "test_prefix_swap.py", "test_quant.py",
                    "test_recurrent.py", "test_runtime.py",
                    "test_spec_decode.py", "test_zoo_serving.py")
_skipped_property_tests = []


def pytest_runtest_logreport(report):
    mod = report.nodeid.split("::")[0].rsplit("/", 1)[-1]
    if report.skipped and mod in PROPERTY_MODULES:
        _skipped_property_tests.append(report.nodeid)


def pytest_collectreport(report):
    # a module-level importorskip surfaces as a *collection* skip
    mod = str(report.nodeid).split("::")[0].rsplit("/", 1)[-1]
    if report.skipped and mod in PROPERTY_MODULES:
        _skipped_property_tests.append(report.nodeid)


def pytest_sessionfinish(session, exitstatus):
    if os.environ.get("REPRO_FAIL_ON_SKIP") and _skipped_property_tests:
        print("\n[conftest] REPRO_FAIL_ON_SKIP=1: property-test modules "
              "reported skips (invariants not verified):")
        for nid in _skipped_property_tests:
            print(f"  SKIPPED {nid}")
        session.exitstatus = 1


@pytest.fixture(autouse=True)
def _clear_jax_caches(request):
    """Drop compiled executables after memory-heavy tests: the suite
    compiles hundreds of XLA programs and the accumulated JIT mappings can
    exhaust process memory late in the run (LLVM 'Cannot allocate
    memory').  Function-scoped for the big-model smoke/parity modules,
    which compile a full train step per architecture.  The engine-level
    cache empties through its explicit hook (``cached_jit_clear``) so the
    jitted wrappers stop pinning their closures too — jax.clear_caches()
    alone cannot reach those references."""
    yield
    if request.module.__name__ in ("test_smoke_archs", "test_parity"):
        T.cached_jit_clear()
        jax.clear_caches()


@pytest.fixture(scope="session")
def tiny_moe_cfg():
    return get_config("tiny-moe")


@pytest.fixture(scope="session")
def tiny_moe_params(tiny_moe_cfg):
    return T.init_model(jax.random.key(0), tiny_moe_cfg)


def make_batch(cfg, B=2, S=24, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)}
    batch["labels"] = np.roll(batch["tokens"], -1, axis=1)
    if cfg.is_encoder_decoder:
        batch["audio_embeds"] = rng.standard_normal(
            (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    if cfg.num_image_tokens:
        batch["image_embeds"] = rng.standard_normal(
            (B, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in batch.items()}
