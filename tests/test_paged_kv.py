"""Paged KV blocks + ragged decode attention (DESIGN.md §9).

Four layers of guarantees:

* **Allocator invariants** (property-based + seeded stdlib fallback that
  ALWAYS runs): no page double-use, free+owned partitions the pool,
  per-slot tables are gapless in ordinal order, release returns every
  page, reservations never over-commit.
* **Kernel parity**: the page-gather reference is BITWISE the dense
  ``attention_core`` ring at matched width across per-row lengths,
  windows and chunk sizes; the Pallas work-list kernel matches the
  reference and its grid scales with live pages (window pages skipped).
* **Engine parity**: the paged ``ContinuousEngine`` (plain and packed
  planes, chunked and unchunked admission) emits bitwise the dense
  engine's greedy tokens — with ``ragged_bucket=False`` through
  bitwise-identical logits, with bucketing through live-horizon slicing.
* **Roofline**: the KV read-bytes term makes tokens/s monotone in live
  context and respects the sliding-window cap.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on hosts w/o the extra
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.core.offload_engine import generate_plain
from repro.kernels import ragged_attention as RA
from repro.models import transformer as T
from repro.models.layers import attention_core
from repro.serving.engine import ContinuousEngine
from repro.serving.kv_manager import PagedKVManager, PagePool

import parity


# ======================================================================
# PagePool allocator invariants (property + seeded fallback)
def _drive_pool(n_pages, page_size, n_ops, seed):
    rng = random.Random(seed)
    pool = PagePool(n_pages, page_size)
    live = {}   # slot -> reserved token budget
    lens = {}   # slot -> tokens ensured so far
    next_slot = 0
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.35:  # admission attempt
            need = rng.randint(1, n_pages * page_size)
            if pool.can_reserve(pool.pages_for(need)):
                s = next_slot
                next_slot += 1
                pool.reserve(s, need)
                live[s] = need
                lens[s] = 0
            else:
                with pytest.raises(ValueError):
                    pool.reserve(("over", next_slot), need)
        elif roll < 0.75 and live:  # grow a slot within its reservation
            s = rng.choice(sorted(live))
            lens[s] = min(live[s], lens[s] + rng.randint(1, 2 * page_size))
            if lens[s]:
                new = pool.ensure(s, lens[s])
                assert all(isinstance(p, int) for p in new)
        elif live:  # release
            s = rng.choice(sorted(live))
            owned_before = list(pool.owned[s])
            returned = pool.release(s)
            assert sorted(returned) == sorted(owned_before), \
                "release must return ALL owned pages"
            del live[s], lens[s]
        # --- invariants after every op --------------------------------
        all_owned = [p for s in live for p in pool.owned[s]]
        assert len(all_owned) == len(set(all_owned)), "page double-use"
        assert len(all_owned) + pool.n_free == n_pages, \
            "free + owned must partition the pool"
        assert 0 <= pool.n_reserved_unallocated <= pool.n_free
        for s in live:
            # gapless ordinal coverage of everything ensured so far
            assert len(pool.owned[s]) >= pool.pages_for(lens[s]) \
                if lens[s] else True
            assert len(pool.owned[s]) <= pool.reserved[s]
    for s in sorted(live):
        pool.release(s)
    assert pool.n_free == n_pages and pool.n_reserved_unallocated == 0


POOL_FALLBACK_CASES = [(8, 4, 60, 0), (3, 2, 40, 1), (16, 8, 80, 2),
                       (1, 16, 30, 3), (12, 1, 70, 4)]


def test_page_pool_invariants_fallback():
    """Seeded stdlib fallback that always runs (property-module guard)."""
    for case in POOL_FALLBACK_CASES:
        _drive_pool(*case)


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(n_pages=st.integers(1, 24), page_size=st.integers(1, 16),
           n_ops=st.integers(1, 80), seed=st.integers(0, 2**16))
    def test_page_pool_invariants_property(n_pages, page_size, n_ops, seed):
        _drive_pool(n_pages, page_size, n_ops, seed)


def test_page_pool_overgrow_rejected():
    pool = PagePool(8, 4)
    pool.reserve("a", 10)  # 3 pages
    with pytest.raises(AssertionError):
        pool.ensure("a", 13)  # 4 pages > reservation


def test_paged_manager_table_gapless_and_scrubbed(tiny_moe_cfg):
    kv = PagedKVManager(tiny_moe_cfg, n_slots=2, page_size=4,
                        pages_total=8, max_pages_per_slot=4)
    s = kv.allocate("req", n_tokens=10)
    kv.ensure(s, 10)  # 3 pages
    row = kv._pages_np[s]
    assert (row[:3] >= 0).all() and (row[3:] == -1).all(), \
        "per-slot table must be gapless in ordinal order"
    owned = list(kv.pool.owned[s])
    # dirty a page's ppos as a decode write would, then release
    st_ = kv.view()
    blk = st_["stack"][0]["kv"]
    assert blk["ppos"].shape[-2:] == (8, 4)
    kv.state["stack"][0]["kv"]["ppos"] = \
        blk["ppos"].at[:, owned[0]].set(7)
    kv.release(s)
    for blk in kv.state["stack"]:
        pp = np.asarray(blk["kv"]["ppos"])
        assert (pp[:, owned] == -1).all(), \
            "released pages must scrub ppos (stale positions leak into " \
            "the next owner's attention mask)"
    assert kv.n_free == 2 and kv.pool.n_free == 8


# ======================================================================
# Ragged attention parity: paged gather == dense ring, bitwise
def _paired_layouts(rng, lens, W, ps, Hkv, hd):
    """Dense ring + paged pool holding the SAME per-row KV entries;
    pages are handed out in shuffled order to exercise indirection."""
    B = len(lens)
    T = W // ps
    P = B * T + 1  # spare page so unallocated gathers hit a real row
    kd = np.zeros((B, W, Hkv, hd), np.float32)
    vd = np.zeros((B, W, Hkv, hd), np.float32)
    posd = np.full((B, W), -1, np.int32)
    kp = rng.standard_normal((P, ps, Hkv, hd)).astype(np.float32)  # junk
    vp = rng.standard_normal((P, ps, Hkv, hd)).astype(np.float32)
    ppos = np.full((P, ps), -1, np.int32)
    pages = np.full((B, T), -1, np.int32)
    ids = rng.permutation(P - 1) + 1
    nxt = 0
    for b in range(B):
        for o in range(-(-int(lens[b]) // ps)):
            pages[b, o] = ids[nxt]
            nxt += 1
        for p_ in range(int(lens[b])):
            val_k = rng.standard_normal((Hkv, hd)).astype(np.float32)
            val_v = rng.standard_normal((Hkv, hd)).astype(np.float32)
            kd[b, p_], vd[b, p_], posd[b, p_] = val_k, val_v, p_
            pid = pages[b, p_ // ps]
            kp[pid, p_ % ps] = val_k
            vp[pid, p_ % ps] = val_v
            ppos[pid, p_ % ps] = p_
    return (kd, vd, posd), (kp, vp, ppos, pages)


@pytest.mark.parametrize("window", [None, 6])
@pytest.mark.parametrize("C", [1, 3])
def test_ragged_reference_bitwise_vs_dense_ring(window, C):
    """Acceptance: the paged fallback is bitwise ``attention_core`` for
    every (per-row length, window, chunk size) — same softmax set, same
    index order, unallocated slots masked exactly like empty ring
    slots."""
    rng = np.random.default_rng(0)
    lens = [5, 16, 9, 3]
    W, ps, Hkv, G, hd = 16, 4, 2, 2, 8
    (kd, vd, posd), (kp, vp, ppos, pages) = _paired_layouts(
        rng, lens, W, ps, Hkv, hd)
    q = rng.standard_normal((len(lens), C, Hkv * G, hd)).astype(np.float32)
    qpos = (np.asarray(lens)[:, None] - C + np.arange(C)[None]).astype(
        np.int32)
    dense = attention_core(jnp.asarray(q), jnp.asarray(kd), jnp.asarray(vd),
                           jnp.asarray(qpos), jnp.asarray(posd),
                           causal=True, window=window, q_chunk=C)
    paged = RA.ragged_attention_reference(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(ppos),
        jnp.asarray(pages), jnp.asarray(qpos), window=window, q_chunk=C)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))


@pytest.mark.parametrize("window", [None, 6])
def test_ragged_pallas_kernel_matches_reference(window):
    rng = np.random.default_rng(3)
    lens = [5, 16, 9]
    W, ps, Hkv, G, hd, C = 16, 4, 2, 2, 8, 2
    _, (kp, vp, ppos, pages) = _paired_layouts(rng, lens, W, ps, Hkv, hd)
    q = rng.standard_normal((len(lens), C, Hkv * G, hd)).astype(np.float32)
    qpos = (np.asarray(lens)[:, None] - C + np.arange(C)[None]).astype(
        np.int32)
    ref = RA.ragged_attention_reference(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(ppos),
        jnp.asarray(pages), jnp.asarray(qpos), window=window, q_chunk=C)
    wl = RA.build_page_worklist(pages, lens, qpos[:, 0], qpos[:, -1], ps,
                                window=window)
    out = RA.ragged_attention(jnp.asarray(q), jnp.asarray(kp),
                              jnp.asarray(vp), jnp.asarray(ppos),
                              jnp.asarray(pages), jnp.asarray(qpos),
                              window=window, worklist=wl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_worklist_scales_with_live_tokens_and_skips_window():
    """The kernel grid is the work list: its length equals the rows'
    live page count (not batch x table width), and a sliding window
    drops the pages it can never read."""
    ps = 4
    lens = np.array([5, 16, 9, 1])
    pages = np.full((4, 8), -1, np.int32)  # table width 8 = 32 positions
    nxt = 0
    for b, n in enumerate(lens):
        for o in range(-(-int(n) // ps)):
            pages[b, o] = nxt
            nxt += 1
    q_lo = q_hi = lens - 1  # C = 1 decode
    wrow, _, wflags = RA.build_page_worklist(pages, lens, q_lo, q_hi, ps)
    live_pages = sum(-(-int(n) // ps) for n in lens)
    assert len(wrow) == live_pages < pages.size
    assert wflags[:, 2].sum() == live_pages
    # per-row first/last flags are consistent
    for b in range(4):
        mine = [i for i in range(len(wrow)) if wrow[i] == b and
                wflags[i, 2]]
        assert wflags[mine[0], 0] == 1 and wflags[mine[-1], 1] == 1
    # a window covering only the last page skips the rest of row 1
    wrow_w, _, wflags_w = RA.build_page_worklist(
        pages, lens, q_lo, q_hi, ps, window=ps)
    assert wflags_w[:, 2].sum() < live_pages


# ======================================================================
# decode_step: paged plane is bitwise the dense plane at matched width
def test_decode_step_paged_bitwise(tiny_moe_cfg, tiny_moe_params):
    cfg, params = tiny_moe_cfg, tiny_moe_params
    B, slot_len, ps = 2, 32, 8
    maxp = slot_len // ps
    dense = T.init_decode_state(cfg, B, slot_len)
    dense["pos"] = jnp.zeros((B,), jnp.int32)
    paged = T.init_decode_state(cfg, B, slot_len, kv_pages=B * maxp,
                                kv_page=ps, kv_max_pages=maxp)
    paged["pos"] = jnp.zeros((B,), jnp.int32)
    tbl = np.asarray(
        np.random.default_rng(0).permutation(B * maxp), np.int32
    ).reshape(B, maxp)
    paged["pages"] = jnp.asarray(tbl)
    toks = np.random.default_rng(1).integers(
        1, cfg.vocab_size, (B, 5)).astype(np.int32)
    act = jnp.ones((B,), bool)
    ld, dense = T.decode_step(params, cfg, dense, jnp.asarray(toks),
                              moe_mode="gather")
    lp, paged = T.decode_step(params, cfg, paged, jnp.asarray(toks),
                              moe_mode="gather", active=act)
    np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
    tok = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(3):
        ld, dense = T.decode_step(params, cfg, dense, tok,
                                  moe_mode="gather")
        lp, paged = T.decode_step(params, cfg, paged, tok,
                                  moe_mode="gather", active=act)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
        tok = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(dense["pos"]),
                                  np.asarray(paged["pos"]))


def test_decode_step_row_chunks_bitwise(tiny_moe_cfg, tiny_moe_params):
    """B=1 row chunks against the shared pool == the same chunks through
    a private B=1 dense state (the admission path's program)."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    slot_len, ps = 32, 8
    maxp = slot_len // ps
    paged = T.init_decode_state(cfg, 2, slot_len, kv_pages=2 * maxp,
                                kv_page=ps, kv_max_pages=maxp)
    paged["pos"] = jnp.zeros((2,), jnp.int32)
    paged["pages"] = jnp.asarray(
        np.arange(2 * maxp, dtype=np.int32).reshape(2, maxp))
    dense = T.init_decode_state(cfg, 1, slot_len)
    dense["pos"] = jnp.zeros((1,), jnp.int32)
    toks = np.random.default_rng(5).integers(
        1, cfg.vocab_size, (1, 7)).astype(np.int32)
    for lo in (0, 3, 6):
        hi = min(lo + 3, 7)
        lp, paged = T.decode_step(params, cfg, paged,
                                  jnp.asarray(toks[:, lo:hi]),
                                  moe_mode="gather", row=1)
        ld, dense = T.decode_step(params, cfg, dense,
                                  jnp.asarray(toks[:, lo:hi]),
                                  moe_mode="gather")
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))
    assert np.asarray(paged["pos"]).tolist() == [0, 7]  # only row 1 moved


def test_paged_state_recurrent_planes_stay_dense():
    """Per-layer-kind state planes (DESIGN.md §12): a paged hybrid keeps
    its recurrent layers' fixed-size carries in the dense batch layout —
    only GROWING kv planes take the page-pool layout — and a
    pure-recurrent stack's paged manager reserves ZERO pool pages."""
    cfg = get_config("recurrentgemma-9b").reduced()
    st = T.init_decode_state(cfg, 2, 16, kv_pages=4, kv_page=4,
                             kv_max_pages=4)
    kinds = [k.split("+")[0] for k in cfg.block_pattern]
    for kind, d in zip(kinds, st["stack"]):
        if kind == "rglru":
            assert "rec" in d and "kv" not in d
            assert d["rec"]["h"].shape[1] == 2  # (periods, B, ...)
        if kind == "swa":
            assert "kv" in d and d["kv"]["kp"].shape[1] == 4  # pool pages
    mgr = PagedKVManager(cfg, 2, 4, 8, 4)
    assert mgr.has_kv  # hybrid: swa layers still page
    xcfg = get_config("xlstm-1.3b").reduced()
    xmgr = PagedKVManager(xcfg, 2, 4, 2, 4)
    assert not xmgr.has_kv
    assert xmgr.can_admit(10 ** 6)  # pool never gates pure-rec admission
    s = xmgr.allocate("r0", 10 ** 6)
    assert xmgr.pool.owned.get(s, []) == []  # zero pages reserved


# ======================================================================
# Engine parity: paged continuous serving == dense, token for token
@pytest.fixture(scope="module")
def _workload(tiny_moe_cfg):
    return (parity.make_prompts(tiny_moe_cfg, (5, 12, 3, 9, 17, 7), seed=1),
            [5, 9, 3, 8, 6, 11])


def test_paged_engine_bitwise_matches_dense(tiny_moe_cfg, tiny_moe_params,
                                            _workload):
    """Acceptance: with the table horizon pinned (``ragged_bucket=
    False``) the paged engine's logits are bitwise the dense engine's —
    so greedy token streams match exactly; bucketed slicing (the perf
    mode), chunked admission and a tight page pool keep the same
    streams.  Drives the shared ``tests/parity.py`` KV-variant grid."""
    prompts, max_news = _workload
    base, _ = parity.run_continuous(tiny_moe_params, tiny_moe_cfg,
                                    prompts, max_news)
    variants = {k: v for k, v in parity.CONTINUOUS_KV_VARIANTS.items()
                if k.startswith("paged")}
    variants["paged_small_pool"] = dict(kv_page=8, kv_pages_total=10)
    for name, kw in variants.items():
        toks, eng = parity.run_continuous(tiny_moe_params, tiny_moe_cfg,
                                          prompts, max_news, **kw)
        parity.assert_tokens_equal(toks, base, name)
        s = eng.stats()
        assert s["kv_layout"] == "paged"
        # at drain every page is either back in the free heap or pinned
        # by exactly one prefix-cache node (each node holds one distinct
        # page's reference) — free + cached partitions the pool
        cached = eng._prefix.n_pages if eng._prefix is not None else 0
        assert s["kv_pages_free"] + cached == s["kv_pages_total"], \
            "all pages must return to the pool (or the cache) at drain"
    # and the dense baseline still matches the B=1 oracle
    parity.assert_tokens_equal(
        base, parity.oracle_streams(tiny_moe_params, tiny_moe_cfg,
                                    prompts, max_news),
        "dense vs oracle")


def test_paged_small_pool_serializes_admissions(tiny_moe_cfg,
                                                tiny_moe_params):
    """A pool too small for two concurrent requests must gate admission
    on page reservations (no deadlock, no mid-decode failure) and still
    produce oracle tokens."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(3)]
    eng = ContinuousEngine(params, cfg, max_slots=2, slot_len=32,
                           eos_id=None, kv_page=8, kv_pages_total=3)
    reqs = [eng.submit(p, 8) for p in prompts]  # each needs 3 pages
    peak = 0
    for _ in range(400):
        eng.step()
        peak = max(peak, eng.sched.n_running)
        if not (eng.sched.has_waiting or eng.sched.n_running):
            break
    assert all(r.state == "finished" for r in reqs)
    assert peak == 1, "3-page pool must serialize 3-page requests"
    for p, r in zip(prompts, reqs):
        assert r.generated == generate_plain(params, cfg, p[None],
                                             8)[0].tolist()


def test_paged_slot_reuse_no_leakage(tiny_moe_cfg, tiny_moe_params):
    """A request decoded in reused pages matches a fresh engine — the
    release-time ppos scrub really isolates successive owners."""
    cfg, params = tiny_moe_cfg, tiny_moe_params
    rng = np.random.default_rng(11)
    p1, p2 = (rng.integers(1, cfg.vocab_size, n).astype(np.int32)
              for n in (14, 9))
    eng = ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                           eos_id=None, kv_page=8)
    r1 = eng.submit(p1, 8)
    eng.run(max_steps=100)
    assert r1.state == "finished" and eng.kv.n_free == 1
    r2 = eng.submit(p2, 8)
    eng.run(max_steps=100)
    fresh = ContinuousEngine(params, cfg, max_slots=1, slot_len=32,
                             eos_id=None, kv_page=8)
    r2f = fresh.submit(p2, 8)
    fresh.run(max_steps=100)
    assert r2.generated == r2f.generated, "state leaked across page reuse"


def test_paged_offloaded_matches_dense_offloaded(tiny_moe_cfg,
                                                 tiny_moe_params):
    """Packed plane: paged KV composes with the expert buffer pool —
    tokens AND h2d counters identical to the dense-KV offloaded engine
    (attention layout cannot change expert routing)."""
    from repro.configs.base import OffloadSpec
    from repro.core.offload_engine import OffloadEngine

    cfg, params = tiny_moe_cfg, tiny_moe_params
    spec = OffloadSpec(cache_size=4, num_speculative=2, expert_bits=3,
                       attn_bits=4)
    off = OffloadEngine(params, cfg, spec, quantized=True)
    prompts = parity.make_prompts(cfg, (5, 8, 6, 7), seed=13)
    max_news = [5, 8, 3, 6]

    def run(**kw):
        toks, eng = parity.run_continuous(None, cfg, prompts, max_news,
                                          slot_len=48, max_steps=400,
                                          offload=off, **kw)
        return toks, parity.continuous_counters(eng)

    base, base_c = run()
    for name in ("paged", "paged_exact"):
        toks, c = run(**parity.CONTINUOUS_KV_VARIANTS[name])
        parity.assert_tokens_equal(toks, base, f"packed {name}")
        assert c == base_c, f"packed {name} h2d counters diverged: " \
            f"{c} vs {base_c}"


def test_paged_capacity_and_flag_validation(tiny_moe_cfg, tiny_moe_params):
    eng = ContinuousEngine(tiny_moe_params, tiny_moe_cfg, max_slots=1,
                           slot_len=16, eos_id=None, kv_page=8)
    with pytest.raises(ValueError):
        eng.submit(np.arange(1, 10, dtype=np.int32), 8)  # 9 + 8 > 16
    with pytest.raises(ValueError):
        ContinuousEngine(tiny_moe_params, tiny_moe_cfg, max_slots=1,
                         slot_len=16, kv_pages_total=4)  # needs kv_page
    # dense engines surface their KV stats too
    s = ContinuousEngine(tiny_moe_params, tiny_moe_cfg, max_slots=2,
                         slot_len=16, eos_id=None).stats()
    assert s["kv_layout"] == "dense" and s["kv_slots_free"] == 2


# ======================================================================
# Roofline: KV read traffic term
def test_cost_model_monotone_in_context(tiny_moe_cfg):
    from repro.core.cost_model import (HARDWARE, TokenStats,
                                       kv_read_bytes_per_token,
                                       tokens_per_second)
    cfg = tiny_moe_cfg
    stats = TokenStats(demand_loads=2.0, spec_loads=1.0, hits=10.0,
                       spec_hits=1.0)
    hw = HARDWARE["a100"]
    prev = None
    for ctx in (0, 128, 512, 2048, 16384):
        tps = tokens_per_second(cfg, hw, stats, expert_bits=2,
                                context_len=ctx)
        if prev is not None:
            assert tps <= prev, "tokens/s must be monotone non-increasing " \
                                "in live context (KV reads grow)"
        prev = tps
    # context 0 reproduces the weight-only model exactly
    assert tokens_per_second(cfg, hw, stats, expert_bits=2) == \
        tokens_per_second(cfg, hw, stats, expert_bits=2, context_len=0)
    # the all-SWA tiny-moe caps its span at the window
    w = cfg.sliding_window
    assert kv_read_bytes_per_token(cfg, 10 * w) == \
        kv_read_bytes_per_token(cfg, w)
    assert kv_read_bytes_per_token(cfg, 0) == 0.0
    # a global-attention variant keeps growing past the window
    gcfg = cfg.replace(block_pattern=("attn+moe",), sliding_window=None)
    assert kv_read_bytes_per_token(gcfg, 10 * w) > \
        kv_read_bytes_per_token(gcfg, w)


def test_cost_model_recurrent_flat_in_context():
    """The rec plane holds O(1) state, so a pure-recurrent stack's
    predicted decode cost must not move with context length AT ALL
    (DESIGN.md §12) — the structural opposite of the attention tax
    above."""
    from repro.configs import get_config
    from repro.core.cost_model import (HARDWARE, TokenStats,
                                       kv_read_bytes_per_token,
                                       recurrent_state_bytes,
                                       tokens_per_second)
    cfg = get_config("xlstm-1.3b").reduced()
    stats = TokenStats(0.0, 0.0, 0.0, 0.0)
    hw = HARDWARE["t4"]
    assert recurrent_state_bytes(cfg) > 0
    assert kv_read_bytes_per_token(cfg, 10000) == 0.0
    base = tokens_per_second(cfg, hw, stats, expert_bits=16, attn_bits=16)
    for ctx in (128, 2048, 10000):
        assert tokens_per_second(cfg, hw, stats, expert_bits=16,
                                 attn_bits=16, context_len=ctx) == base
    # hybrid check: recurrentgemma's swa layer makes cost grow up to its
    # window then plateau, while the rec layers contribute a flat term
    hcfg = get_config("recurrentgemma-9b").reduced()
    assert recurrent_state_bytes(hcfg) > 0
    w = hcfg.sliding_window
    assert kv_read_bytes_per_token(hcfg, w // 2) < \
        kv_read_bytes_per_token(hcfg, w)
    assert kv_read_bytes_per_token(hcfg, 10 * w) == \
        kv_read_bytes_per_token(hcfg, w)


def test_cost_model_encoder_kv_and_dense_terms():
    """xattn layers pay the precomputed encoder-KV read every token even
    at zero decoded context; dense archs are the E=1 case — they cost
    out without a MoE spec and refuse the naive-offload model."""
    import pytest as _pytest

    from repro.configs import get_config
    from repro.core.cost_model import (HARDWARE, TokenStats,
                                       kv_read_bytes_per_token,
                                       tokens_per_second)
    wcfg = get_config("whisper-medium").reduced()
    per_pos = 2 * wcfg.n_kv_heads * wcfg.head_dim * 2.0  # 16-bit K+V
    n_x = sum(1 for k in wcfg.layer_kinds() if k.startswith("xattn"))
    assert kv_read_bytes_per_token(wcfg, 0) == \
        n_x * wcfg.encoder_seq * per_pos
    # decoded self-KV stacks on top of the constant encoder term
    assert kv_read_bytes_per_token(wcfg, 64) == \
        kv_read_bytes_per_token(wcfg, 0) + n_x * 64 * per_pos
    dcfg = get_config("stablelm-1.6b").reduced()
    stats = TokenStats(0.0, 0.0, 0.0, 0.0)
    hw = HARDWARE["t4"]
    assert tokens_per_second(dcfg, hw, stats, expert_bits=16,
                             attn_bits=16) > 0
    with _pytest.raises(ValueError, match="dense"):
        tokens_per_second(dcfg, hw, stats, expert_bits=16, naive=True)
